"""The unified ``repro.api`` facade (PR 3).

Contracts under test:

  * ``FSGLD.sample`` is BIT-IDENTICAL to the ``run_vmap`` oracle for all
    three methods across all three executors (the facade routes every
    workload through the chain engine and adds nothing to the math);
  * odd chain counts run on multi-device data axes (pad + mask) with the
    REAL chains' RNG streams equal to the oracle's;
  * ``kernel='sghmc'`` routes federated SGHMC through the same engine;
  * declarative surrogate fitting (refresh / fisher / local_sgld) and the
    bf16 storage option produce working banks.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, make_bank,
                        analytic_gaussian_likelihood_surrogate)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _legacy(method, data, bank, use_kernel=False, local=5, step=1e-4):
    cfg = SamplerConfig(method=method, step_size=step, num_shards=5,
                        local_updates=local, prior_precision=1.0)
    return FederatedSampler(log_lik, cfg, data, minibatch=8,
                            bank=bank if method == "fsgld" else None,
                            use_kernel=use_kernel)


def _facade(method, data, bank, executor="vmap", local=5, step=1e-4,
            rounds=4, n_chains=4, **kw):
    return api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=step, method=method,
        surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                   if method == "fsgld"
                   else api.SurrogateSpec(kind="none")),
        schedule=api.Schedule(rounds=rounds, local_steps=local,
                              n_chains=n_chains),
        execution=api.Execution(executor=executor), **kw)


# ---------------------------------------------------------------------------
# bit-exactness against the run_vmap oracle (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["sgld", "dsgld", "fsgld"])
@pytest.mark.parametrize("executor", ["vmap", "per_leaf", "packed"])
def test_facade_bitmatches_oracle(method, executor):
    data, bank = _problem(jax.random.PRNGKey(0))
    got = _facade(method, data, bank, executor=executor).sample(
        jax.random.PRNGKey(7), jnp.zeros(3))
    ref = _legacy(method, data, bank,
                  use_kernel=(executor != "vmap")).run_vmap(
        jax.random.PRNGKey(7), jnp.zeros(3), 4, n_chains=4)
    assert got.shape == ref.shape == (4, 20, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_facade_permutation_and_thinning_match_oracle():
    data, bank = _problem(jax.random.PRNGKey(1))
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=3, local_steps=4, n_chains=4,
                              reassign="permutation", thin=2))
    got = f.sample(jax.random.PRNGKey(3), jnp.zeros(3))
    ref = _legacy("fsgld", data, bank, local=4).run_vmap(
        jax.random.PRNGKey(3), jnp.zeros(3), 3, n_chains=4,
        reassign="permutation", collect_every=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_facade_ragged_client_list_input():
    """A list of per-client pytrees is padded with pad_shards; NaN pad
    rows stay provably dead (finite trace)."""
    key = jax.random.PRNGKey(2)
    base = jax.random.normal(key, (4, 64, 3))
    per = [{"x": base[s, : 10 + 7 * s]} for s in range(4)]
    f = api.FSGLD(api.Posterior(log_lik), per, minibatch=6,
                  step_size=1e-4, method="dsgld",
                  schedule=api.Schedule(rounds=2, local_steps=3,
                                        n_chains=2))
    tr = f.sample(jax.random.PRNGKey(3), jnp.zeros(3))
    assert tr.shape == (2, 6, 3)
    assert bool(jnp.all(jnp.isfinite(tr)))


# ---------------------------------------------------------------------------
# odd chain counts: pad over the data axis instead of raising
# ---------------------------------------------------------------------------

def test_odd_chain_count_host_mesh_bitmatches_oracle():
    data, bank = _problem(jax.random.PRNGKey(0))
    got = _facade("fsgld", data, bank, n_chains=3).sample(
        jax.random.PRNGKey(7), jnp.zeros(3))
    ref = _legacy("fsgld", data, bank).run_vmap(
        jax.random.PRNGKey(7), jnp.zeros(3), 4, n_chains=3)
    assert got.shape == (3, 20, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_odd_chain_count_multidevice_subprocess():
    """3 chains on a 2-way data axis: padded to 4, pad chain discarded.
    The real chains' RNG streams equal the oracle's; numerics agree to
    compiler tolerance (XLA may fuse the differently-shaped programs
    with one-ulp differences)."""
    script = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, make_bank,
                        analytic_gaussian_likelihood_surrogate)
from repro.launch.mesh import make_sim_mesh

def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

key = jax.random.PRNGKey(0)
S, n, d = 5, 24, 3
x = jax.random.normal(key, (S, n, d)) + jnp.arange(S)[:, None, None]
mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
bank = make_bank(mu_s, prec_s, "diag")
cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                    local_updates=3, prior_precision=1.0)
samp = FederatedSampler(log_lik, cfg, {"x": x}, minibatch=6, bank=bank)
for C in (1, 3):
    for re in ("categorical", "permutation"):
        f = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), {"x": x},
            minibatch=6, step_size=1e-4,
            surrogate=api.SurrogateSpec(kind="diag", bank=bank),
            schedule=api.Schedule(rounds=3, local_steps=3, n_chains=C,
                                  reassign=re),
            execution=api.Execution(mesh=make_sim_mesh(data=2, model=1)))
        got = f.sample(jax.random.PRNGKey(7), jnp.zeros(d))
        ref = samp.run_vmap(jax.random.PRNGKey(7), jnp.zeros(d), 3,
                            n_chains=C, reassign=re)
        assert got.shape == ref.shape == (C, 9, d), got.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-8)
# packed SGHMC with an odd chain count: pad masking + the momentum
# segment under real SPMD (PR 4)
from repro.core.sghmc import SGHMCConfig
hsamp = FederatedSampler(log_lik, cfg, {"x": x}, minibatch=6, bank=bank,
                         use_kernel=True, dynamics="sghmc",
                         sghmc=SGHMCConfig(friction=0.1))
f = api.FSGLD(
    api.Posterior(log_lik, prior_precision=1.0), {"x": x}, minibatch=6,
    step_size=1e-4, kernel="sghmc", friction=0.1,
    surrogate=api.SurrogateSpec(kind="diag", bank=bank),
    schedule=api.Schedule(rounds=3, local_steps=3, n_chains=3),
    execution=api.Execution(mesh=make_sim_mesh(data=2, model=1),
                            executor="packed"))
got = f.sample(jax.random.PRNGKey(7), jnp.zeros(d))
ref = hsamp.run_vmap(jax.random.PRNGKey(7), jnp.zeros(d), 3, n_chains=3)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-6, atol=1e-8)
print("ODD_CHAINS_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "ODD_CHAINS_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


# ---------------------------------------------------------------------------
# kernel='sghmc': the orphaned module becomes a facade option
# ---------------------------------------------------------------------------

def test_sghmc_kernel_runs_multichain_through_engine():
    data, bank = _problem(jax.random.PRNGKey(0))
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4, kernel="sghmc", friction=0.1,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=3, local_steps=5, n_chains=4))
    tr = f.sample(jax.random.PRNGKey(7), jnp.zeros(3))
    assert tr.shape == (4, 15, 3)
    assert bool(jnp.all(jnp.isfinite(tr)))
    # the chains moved and differ from the Langevin kernel's output
    ref = _facade("fsgld", data, bank).sample(jax.random.PRNGKey(7),
                                              jnp.zeros(3))
    assert float(jnp.abs(tr).max()) > 0.0
    assert not np.array_equal(np.asarray(tr[:, :15]), np.asarray(ref[:, :15]))


def test_sghmc_converges_on_conjugate_gaussian():
    """Statistical check (the engine SGHMC has no legacy oracle): the
    posterior mean lands, matching FederatedSGHMC's contract."""
    key = jax.random.PRNGKey(0)
    S, n, d = 10, 200, 2
    data, bank = _problem(key, S=S, n=n, d=d)
    post_mean = data["x"].reshape(-1, d).sum(0) / (1 + S * n)
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=10,
        step_size=2e-5, kernel="sghmc",
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=150, local_steps=100, thin=10))
    tr = f.sample(jax.random.PRNGKey(1), jnp.zeros(d))[0]
    tr = tr[tr.shape[0] // 2:]
    mse = float(jnp.sum((tr.mean(0) - post_mean) ** 2))
    assert mse < 5e-3, mse


@pytest.mark.parametrize("executor", ["per_leaf", "packed"])
def test_sghmc_composes_with_kernel_executors(executor):
    """kernel='sghmc' now rides the fused executors (the PR 3 guard is
    gone): packed/per-leaf SGHMC bit-match the run_vmap oracle with the
    matching use_kernel + dynamics (the full grid lives in
    tests/test_parity_matrix.py)."""
    from repro.core.sghmc import SGHMCConfig
    data, bank = _problem(jax.random.PRNGKey(0))
    f = api.FSGLD(api.Posterior(log_lik, prior_precision=1.0), data,
                  minibatch=8, step_size=1e-4, kernel="sghmc",
                  friction=0.1,
                  surrogate=api.SurrogateSpec(kind="diag", bank=bank),
                  schedule=api.Schedule(rounds=3, local_steps=5,
                                        n_chains=4),
                  execution=api.Execution(executor=executor))
    got = f.sample(jax.random.PRNGKey(7), jnp.zeros(3))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=5, prior_precision=1.0)
    ref = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=bank,
                           use_kernel=True, dynamics="sghmc",
                           sghmc=SGHMCConfig(friction=0.1)).run_vmap(
        jax.random.PRNGKey(7), jnp.zeros(3), 3, n_chains=4)
    assert got.shape == (4, 15, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# declarative surrogate fitting + storage dtype
# ---------------------------------------------------------------------------

def test_fit_refresh_gradient_matching():
    """fit='refresh' reproduces repro.core.refresh_bank at theta0."""
    from repro.core import refresh_bank
    data, _ = _problem(jax.random.PRNGKey(3))
    theta0 = jnp.array([0.2, -0.4, 0.1])
    f = api.FSGLD(api.Posterior(log_lik), data, minibatch=8,
                  surrogate=api.SurrogateSpec(kind="diag", fit="refresh"),
                  schedule=api.Schedule(rounds=1, local_steps=2))
    bank = f.fit(jax.random.PRNGKey(0), theta0)
    ref = refresh_bank(log_lik, data, theta0)
    np.testing.assert_array_equal(np.asarray(bank.means),
                                  np.asarray(ref.means))
    np.testing.assert_array_equal(np.asarray(bank.precs),
                                  np.asarray(ref.precs))


def test_fit_local_sgld_scalar_pytree_with_bf16_storage():
    """'scalar' local-SGLD fitting on a multi-leaf posterior + bf16 bank
    storage (Execution.dtype) — the large-model phase-1 path in generic
    form. Sampling through the engine stays finite."""
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (4, 24, 2))
    w = jax.random.normal(ks[1], (2, 5))
    y = x @ w + 0.1 * jax.random.normal(ks[2], (4, 24, 5))

    def ll(theta, batch):
        pred = batch["x"] @ theta["w"] + theta["b"]
        return -0.5 * jnp.sum((batch["y"] - pred) ** 2)

    t0 = {"b": jnp.zeros(5), "w": jnp.zeros((2, 5))}
    f = api.FSGLD(
        api.Posterior(ll), {"x": x, "y": y}, minibatch=6, step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="scalar", fit="local_sgld",
                                    fit_steps=20, fit_minibatch=6),
        schedule=api.Schedule(rounds=2, local_steps=3, n_chains=2),
        execution=api.Execution(dtype=jnp.bfloat16))
    tr = f.sample(jax.random.PRNGKey(5), t0)
    assert f.bank.kind == "scalar"
    assert jax.tree.leaves(f.bank.means)[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(f.bank.precs)[0].dtype == jnp.float32
    assert all(bool(jnp.all(jnp.isfinite(t)))
               for t in jax.tree.leaves(tr))


def test_bank_astype_roundtrip_and_gradients():
    from repro.core import Gaussian  # noqa: F401
    data, bank = _problem(jax.random.PRNGKey(0))
    b16 = bank.astype(jnp.bfloat16)
    assert b16.means.dtype == jnp.bfloat16
    assert b16.global_.mean.dtype == jnp.bfloat16
    assert b16.precs.dtype == jnp.float32
    g = b16.shard(0).grad_log(jnp.zeros(3))
    assert g.dtype == jnp.float32 and bool(jnp.all(jnp.isfinite(g)))


def test_method_surrogate_validation():
    data, bank = _problem(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        api.FSGLD(api.Posterior(log_lik), data, minibatch=8,
                  method="fsgld", surrogate=api.SurrogateSpec(kind="none"))
