"""Suite-wide setup.

Gates the optional ``hypothesis`` dependency: the container image this repo
targets does not ship it (and installing packages is not always possible),
so when the real package is missing we register the deterministic stand-in
from ``tests/_mini_hypothesis.py`` under the ``hypothesis`` module name
before test modules import it. CI installs the real package via
``pip install -e .[dev]`` and takes priority automatically.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:  # pragma: no cover - exercised implicitly by every property test
    import hypothesis  # noqa: F401
except ImportError:
    import _mini_hypothesis

    sys.modules["hypothesis"] = _mini_hypothesis
    sys.modules["hypothesis.strategies"] = _mini_hypothesis.strategies
