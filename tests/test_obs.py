"""Host-side observability primitives: ``repro.obs.trace`` spans/events
and the ``MetricsFrame`` exporters.

Contracts:

  * span nesting is recorded (depth + parent from a thread-local stack)
    and the JSONL sink round-trips every record;
  * a disabled tracer is a true no-op — shared null span, no file, no
    output — so instrumented code paths cost nothing by default;
  * ``configure()`` swaps the process tracer and back;
  * MetricsFrame JSONL round-trips bitwise at fp32, the Prometheus
    textfile parses back to floats, concat/summary/last_round behave.
"""
import json
import time

import numpy as np
import pytest

from repro.obs import (MetricsFrame, Telemetry, parse_prometheus,
                       read_metrics_jsonl, trace, write_metrics_jsonl,
                       write_prometheus)


# ---------------------------------------------------------------------------
# tracing spans + events
# ---------------------------------------------------------------------------

def test_span_nesting_and_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = trace.Tracer(path)
    with tr.span("outer", run="x"):
        time.sleep(0.01)
        with tr.span("inner", step=1):
            pass
        tr.event("tick", round=3)
    tr.close()
    recs = trace.read_jsonl(path)
    by = {}
    for r in recs:
        by.setdefault(r["name"], []).append(r)
    # spans are emitted at EXIT: inner closes before outer
    assert [r["name"] for r in recs] == ["inner", "tick", "outer"]
    inner, tick, outer = by["inner"][0], by["tick"][0], by["outer"][0]
    assert outer["type"] == "span" and outer["depth"] == 0
    assert outer["parent"] is None and outer["run"] == "x"
    assert inner["depth"] == 1 and inner["parent"] == "outer"
    assert inner["step"] == 1
    assert tick["type"] == "event" and tick["parent"] == "outer"
    assert tick["round"] == 3 and "dur_s" not in tick
    # monotonic durations: the outer span contains the sleep
    assert outer["dur_s"] >= 0.01 > inner["dur_s"] >= 0.0
    assert outer["ts"] <= inner["ts"]


def test_disabled_tracer_is_noop(tmp_path, capsys):
    tr = trace.Tracer()
    assert not tr.enabled
    s1 = tr.span("a")
    s2 = tr.span("b", k=1)
    assert s1 is s2  # the shared null span: zero allocation per call
    with s1:
        tr.event("nothing", x=1)
    assert capsys.readouterr().out == ""
    assert list(tmp_path.iterdir()) == []


def test_echo_tracer_prints_compact_lines(capsys):
    tr = trace.Tracer(echo=True)
    assert tr.enabled
    tr.event("engine.progress", round=4, steps_per_s=123.0)
    out = capsys.readouterr().out
    assert "engine.progress" in out
    assert "round=4" in out and "steps_per_s=123.0" in out
    assert out.startswith("[")  # [HH:MM:SS] prefix


def test_configure_swaps_module_tracer(tmp_path):
    path = str(tmp_path / "mod.jsonl")
    assert not trace.enabled()
    try:
        trace.configure(path)
        assert trace.enabled()
        with trace.span("seg", i=0):
            trace.event("e")
    finally:
        trace.configure()
    assert not trace.enabled()
    names = [r["name"] for r in trace.read_jsonl(path)]
    assert names == ["e", "seg"]
    # back to disabled: nothing more is written
    trace.event("after")
    assert [r["name"] for r in trace.read_jsonl(path)] == ["e", "seg"]


def test_span_exception_still_emits_and_pops(tmp_path):
    path = str(tmp_path / "exc.jsonl")
    tr = trace.Tracer(path)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    with tr.span("next"):
        pass
    tr.close()
    recs = trace.read_jsonl(path)
    assert [r["name"] for r in recs] == ["boom", "next"]
    assert all(r["depth"] == 0 for r in recs)  # stack popped on error


# ---------------------------------------------------------------------------
# MetricsFrame + exporters
# ---------------------------------------------------------------------------

def _frame(rounds=3, chains=2, names=("a_norm", "b_rate")):
    rng = np.random.RandomState(0)
    return MetricsFrame({
        n: rng.rand(rounds, chains).astype(np.float32) for n in names})


def test_metrics_jsonl_roundtrip_bitwise(tmp_path):
    fr = _frame()
    path = str(tmp_path / "m.jsonl")
    write_metrics_jsonl(fr, path)
    back = read_metrics_jsonl(path)
    assert back.names == fr.names
    for n in fr.names:
        np.testing.assert_array_equal(back.metrics[n], fr.metrics[n])
        assert back.metrics[n].dtype == np.float32
    head = json.loads(open(path).readline())
    assert head["schema"] == "repro-metrics-v1"
    assert head["rounds"] == 3 and head["chains"] == 2


def test_prometheus_export_parses(tmp_path):
    fr = _frame()
    path = str(tmp_path / "m.prom")
    write_prometheus(fr, path)
    got = parse_prometheus(path)
    assert got["fsgld_rounds_total"] == fr.rounds
    for n in fr.names:
        for c in range(fr.n_chains):
            key = f'fsgld_{n}{{chain="{c}"}}'
            assert got[key] == pytest.approx(
                float(fr.metrics[n][-1, c]), rel=1e-6)
        assert got[f"fsgld_{n}_mean"] == pytest.approx(
            float(fr.metrics[n].mean()), rel=1e-6)
    # textfile format: HELP/TYPE comment pairs present
    text = open(path).read()
    assert "# HELP fsgld_a_norm" in text and "# TYPE fsgld_a_norm gauge" \
        in text


def test_frame_summary_last_round_concat():
    fr = _frame(rounds=4)
    assert fr.rounds == 4 and fr.n_chains == 2
    s = fr.summary()
    assert set(s) == set(fr.names)
    assert s["a_norm"] == pytest.approx(float(fr.metrics["a_norm"].mean()))
    np.testing.assert_array_equal(fr.last_round()["b_rate"],
                                  fr.metrics["b_rate"][-1])
    cat = MetricsFrame.concat([_frame(rounds=2), _frame(rounds=3)])
    assert cat.rounds == 5 and cat.names == fr.names


def test_frame_shape_validation():
    with pytest.raises(AssertionError):
        MetricsFrame({})
    with pytest.raises(AssertionError):
        MetricsFrame({"a": np.zeros((2, 2), np.float32),
                      "b": np.zeros((3, 2), np.float32)})


def test_telemetry_spec_names_sorted_and_validated():
    full, lean = Telemetry(), Telemetry(probe=False)
    assert full.names == tuple(sorted(full.names))
    assert set(full.names) - set(lean.names) == {"grad_norm", "log_post"}
    assert "bytes_per_round" in lean.names
    with pytest.raises(ValueError, match="log_every"):
        Telemetry(log_every=0)
    assert hash(Telemetry()) == hash(Telemetry())  # executor cache key
