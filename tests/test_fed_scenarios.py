"""CI ``scenario-matrix`` lane: every registry scenario x executor.

Contracts (the PR 5 acceptance criteria):

  * the IDENTITY scenario is BIT-IDENTICAL to the ``run_vmap`` oracle on
    every executor (vmap / per_leaf / packed) — the federation plumbing
    adds nothing to the math when the scenario is trivial;
  * every registry scenario produces finite traces on the vmap AND
    packed executors (engine scenarios on standard shards; partition
    scenarios on pooled labeled data);
  * schedules and compression are applied IN-SCAN: the executor jaxpr
    for a scheduled + compressed + partial scenario contains exactly ONE
    rounds-scan (no per-round dispatch), the packed path still issues
    exactly one ``pallas_call``, and no ``pad`` primitive sneaks into
    any scan body;
  * the README "## Federation scenarios" snippet runs verbatim.
"""
import dataclasses
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, MeshChainEngine, make_bank,
                        analytic_gaussian_likelihood_surrogate)
from repro.fed import (CommSchedule, Compression, Federation,
                       get_scenario, scenario_names)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXECUTORS = ("vmap", "packed")


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _pooled(key, N=240, d=3, classes=4):
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (N,), 0, classes)
    x = jax.random.normal(k2, (N, d)) + 1.5 * y[:, None]
    return {"x": x, "y": y}


# ---------------------------------------------------------------------------
# identity scenario == run_vmap oracle, bitwise, on every executor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["vmap", "per_leaf", "packed"])
def test_identity_scenario_bitwise_vs_oracle(executor):
    data, bank = _problem(jax.random.PRNGKey(0))
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=4, local_steps=5, n_chains=4),
        execution=api.Execution(executor=executor),
        federation="identity")
    got = f.sample(jax.random.PRNGKey(7), jnp.zeros(3))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=5, prior_precision=1.0)
    ref = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=bank,
                           use_kernel=(executor != "vmap")).run_vmap(
        jax.random.PRNGKey(7), jnp.zeros(3), 4, n_chains=4)
    assert got.shape == ref.shape == (4, 20, 3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# the matrix: every registry scenario x {vmap, packed} -> finite traces
# ---------------------------------------------------------------------------

def _run_scenario(name, executor):
    sc = get_scenario(name)
    if sc.partition is not None:
        # pooled labeled data; shrink the client count to smoke scale
        sc = dataclasses.replace(
            sc, partition=dataclasses.replace(sc.partition, num_shards=4))
        data = _pooled(jax.random.PRNGKey(0))
        f = api.FSGLD(
            api.Posterior(log_lik), data, minibatch=6, step_size=1e-4,
            method="dsgld",
            schedule=api.Schedule(rounds=2, local_steps=3, n_chains=2),
            execution=api.Execution(executor=executor), federation=sc)
        return f.sample(jax.random.PRNGKey(1), jnp.zeros(3))
    data, bank = _problem(jax.random.PRNGKey(0))
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=3, local_steps=4, n_chains=4),
        execution=api.Execution(executor=executor))
    return f.sample(jax.random.PRNGKey(7), jnp.zeros(3), federation=sc)


@pytest.mark.parametrize("executor", EXECUTORS)
@pytest.mark.parametrize("name", scenario_names())
def test_scenario_matrix_finite(name, executor):
    tr = _run_scenario(name, executor)
    assert all(bool(jnp.all(jnp.isfinite(t)))
               for t in jax.tree.leaves(tr)), (name, executor)


# ---------------------------------------------------------------------------
# in-scan lowering: one scan, one pallas_call, no pad, no per-round
# dispatch (jaxpr-asserted — the acceptance criterion)
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _all_eqns(sub)


def _subjaxprs(v):
    if hasattr(v, "jaxpr"):           # ClosedJaxpr
        return [v.jaxpr]
    if hasattr(v, "eqns"):            # raw Jaxpr
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def test_scheduled_compressed_rounds_lower_into_one_scan():
    """Delayed + partial + straggler + top-k compression, packed
    executor: the WHOLE R-round program is ONE rounds-scan (length R —
    schedules never unroll or dispatch per round), still exactly one
    pallas_call, and no pad primitive in any scan body."""
    data, bank = _problem(jax.random.PRNGKey(2))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=4, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=6, bank=bank,
                          use_kernel=True)
    fed = Federation(
        schedule=CommSchedule(delay=3, participation=0.5,
                              straggler_prob=0.1),
        compression=Compression(kind="topk", frac=0.1))
    num_rounds = 6
    layout = eng._layout_for(jnp.zeros(3))
    execute = eng._executor(num_rounds=num_rounds, n_chains=4,
                            reassign="categorical", collect=True,
                            collect_every=2, layout=layout, federation=fed)
    chains = jnp.zeros((4, 3))
    sids0 = jnp.zeros((4,), jnp.int32)
    ref0 = jnp.zeros((4, 3), jnp.float32)
    jaxpr = jax.make_jaxpr(execute)(
        jax.random.PRNGKey(0), chains, data, bank,
        jnp.asarray(0, jnp.int32), (sids0, (ref0, ref0)), None)

    eqns = list(_all_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if "pallas" in e.primitive.name]
    assert len(pallas) == 1, [e.primitive.name for e in pallas]
    round_scans = [e for e in eqns if e.primitive.name == "scan"
                   and e.params["length"] == num_rounds]
    assert len(round_scans) == 1, "rounds loop not a single scan"
    for s in (e for e in eqns if e.primitive.name == "scan"):
        body = [e.primitive.name
                for e in _all_eqns(s.params["jaxpr"].jaxpr)]
        assert "pad" not in body, "pad op inside a scan body"
        assert body.count("pallas_call") <= 1


def test_scenarios_share_one_executor_cache_entry_per_spec():
    """Same spec twice -> one cached executor (no retrace per run);
    the identity spec shares the federation=None entry."""
    data, bank = _problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=3, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=6, bank=bank)
    fed = Federation(schedule=CommSchedule(delay=2))
    for _ in range(2):
        eng.run(jax.random.PRNGKey(0), jnp.zeros(3), 2, n_chains=2,
                federation=fed)
    assert len(eng._executors) == 1
    eng.run(jax.random.PRNGKey(0), jnp.zeros(3), 2, n_chains=2)
    eng.run(jax.random.PRNGKey(0), jnp.zeros(3), 2, n_chains=2,
            federation=Federation())   # identity -> the same None entry
    assert len(eng._executors) == 2


# ---------------------------------------------------------------------------
# real SPMD: scheduled/compressed rounds on a 2-way data axis
# ---------------------------------------------------------------------------

def test_federation_multidevice_subprocess():
    """Delayed / partial / compressed / straggler scenarios under a real
    2-device data mesh: the participation and straggler masks derive
    from the replicated round key and slice per device block (like sids),
    the carried assignment survives the odd-chain pad, and the identity
    scenario still matches the oracle to compiler tolerance."""
    script = r"""
import warnings
warnings.simplefilter("ignore")
import jax, jax.numpy as jnp, numpy as np
from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, make_bank,
                        analytic_gaussian_likelihood_surrogate)
from repro.launch.mesh import make_sim_mesh

def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

key = jax.random.PRNGKey(0)
S, n, d = 5, 24, 3
x = jax.random.normal(key, (S, n, d)) + jnp.arange(S)[:, None, None]
mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
bank = make_bank(mu_s, prec_s, "diag")
mesh = make_sim_mesh(data=2, model=1)
for ex in ("vmap", "packed"):
    for name in ("delayed-5x", "partial-50%", "topk-1%", "straggler-10%"):
        f = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), {"x": x},
            minibatch=6, step_size=1e-4,
            surrogate=api.SurrogateSpec(kind="diag", bank=bank),
            schedule=api.Schedule(rounds=3, local_steps=3, n_chains=3),
            execution=api.Execution(mesh=mesh, executor=ex))
        tr = f.sample(jax.random.PRNGKey(7), jnp.zeros(d), federation=name)
        assert tr.shape == (3, 9, d), (ex, name, tr.shape)
        assert bool(jnp.all(jnp.isfinite(tr))), (ex, name)
cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                    local_updates=3, prior_precision=1.0)
ref = FederatedSampler(log_lik, cfg, {"x": x}, minibatch=6,
                       bank=bank).run_vmap(
    jax.random.PRNGKey(7), jnp.zeros(d), 3, n_chains=4)
f = api.FSGLD(api.Posterior(log_lik, prior_precision=1.0), {"x": x},
              minibatch=6, step_size=1e-4,
              surrogate=api.SurrogateSpec(kind="diag", bank=bank),
              schedule=api.Schedule(rounds=3, local_steps=3, n_chains=4),
              execution=api.Execution(mesh=mesh), federation="identity")
got = f.sample(jax.random.PRNGKey(7), jnp.zeros(d))
np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                           rtol=1e-6, atol=1e-8)
print("FED_MULTIDEVICE_OK")
"""
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "FED_MULTIDEVICE_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


# ---------------------------------------------------------------------------
# README "## Federation scenarios" snippet runs verbatim
# ---------------------------------------------------------------------------

def _readme_fed_block() -> str:
    text = open(os.path.join(REPO, "README.md")).read()
    m = re.search(r"^## Federation scenarios$(.*?)^## ", text, re.M | re.S)
    assert m, "README has no '## Federation scenarios' section"
    code = re.search(r"```python\n(.*?)```", m.group(1), re.S)
    assert code, "README federation section has no python snippet"
    return code.group(1)


def test_readme_federation_snippet_runs():
    src = _readme_fed_block()
    assert "federation=" in src
    exec(compile(src, "README.md:<federation-snippet>", "exec"), {})
