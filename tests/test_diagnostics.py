"""Dedicated diagnostics tests (PR 5 satellite): golden AR(1) values,
identical-chains R-hat property, and the short-trace / odd-N guards.

The AR(1) process x_t = rho x_{t-1} + sqrt(1 - rho^2) eps_t has unit
variance and integrated autocorrelation time tau = (1+rho)/(1-rho), so
ESS over C chains of N samples should land near C*N*(1-rho)/(1+rho) —
an analytic golden value, not a snapshot of the implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.diagnostics import _split_chains, ess, rhat, summarize


def _ar1(key, C, N, rho, d=1):
    eps = jax.random.normal(key, (N, C, d))

    def step(x, e):
        x = rho * x + jnp.sqrt(1.0 - rho ** 2) * e
        return x, x

    _, xs = jax.lax.scan(step, jnp.zeros((C, d)), eps)
    return xs.transpose(1, 0, 2)  # (C, N, d)


# ---------------------------------------------------------------------------
# golden AR(1) values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho,tol", [(0.0, 0.15), (0.5, 0.2), (0.9, 0.3)])
def test_ess_matches_ar1_analytic_tau(rho, tol):
    C, N = 4, 4000
    chains = _ar1(jax.random.PRNGKey(0), C, N, rho)
    golden = C * N * (1.0 - rho) / (1.0 + rho)
    got = float(ess(chains)[0])
    assert abs(got - golden) / golden < tol, (got, golden)


def test_rhat_ar1_well_mixed_near_one():
    chains = _ar1(jax.random.PRNGKey(1), 4, 2000, 0.5)
    assert float(jnp.abs(rhat(chains) - 1.0).max()) < 0.02


def test_rhat_detects_mean_shifted_ar1():
    chains = _ar1(jax.random.PRNGKey(2), 4, 500, 0.5) \
        + jnp.arange(4.0)[:, None, None]
    assert float(rhat(chains).min()) > 1.5


# ---------------------------------------------------------------------------
# identical chains -> R-hat ~ 1 (property test)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n_chains=st.integers(2, 8),
       n=st.integers(500, 3000))
def test_identical_chains_rhat_is_one(seed, n_chains, n):
    """C identical copies of one iid chain: between-CHAIN variance is
    exactly zero, so only the split's between-HALF mean wobble (the
    O(1/sqrt(N)) term split-R-hat exists to detect) remains — R-hat ~ 1
    for any chain count and any seed."""
    one = jax.random.normal(jax.random.PRNGKey(seed), (1, n, 2))
    chains = jnp.tile(one, (n_chains, 1, 1))
    r = rhat(chains)
    assert float(jnp.abs(r - 1.0).max()) < 0.05
    # duplicating identical chains never signals divergence
    assert float(r.max()) < 1.1


# ---------------------------------------------------------------------------
# short traces and odd N (the PR 5 guards)
# ---------------------------------------------------------------------------

def test_split_chains_odd_n_drops_first_sample():
    x = jnp.arange(2 * 7, dtype=jnp.float32).reshape(2, 7)[..., None]
    split = _split_chains(x)
    assert split.shape == (4, 3, 1)
    # documented truncation: the FIRST (burn-in-side) sample goes, both
    # halves stay contiguous
    np.testing.assert_array_equal(np.asarray(split[0, :, 0]), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(split[2, :, 0]), [4, 5, 6])


def test_rhat_odd_n_equals_truncated_even_n():
    chains = jax.random.normal(jax.random.PRNGKey(3), (3, 101, 2))
    np.testing.assert_array_equal(np.asarray(rhat(chains)),
                                  np.asarray(rhat(chains[:, 1:])))


def test_rhat_refuses_too_short_traces():
    with pytest.raises(ValueError, match=">= 4 samples"):
        rhat(jnp.zeros((2, 3, 1)))


def test_ess_clamps_max_lag_for_short_traces():
    chains = _ar1(jax.random.PRNGKey(4), 2, 20, 0.3)
    # the default max_lag=200 must clamp to N//2 - 1 = 9, not N - 1
    np.testing.assert_array_equal(np.asarray(ess(chains)),
                                  np.asarray(ess(chains, max_lag=9)))
    # finite and bounded on traces down to the clamp floor (N <= 4 uses
    # lag 1 only)
    for n in (4, 5, 6):
        tiny = ess(chains[:, :n])
        assert bool(jnp.all(jnp.isfinite(tiny)))
        assert float(tiny.max()) <= 2 * n + 1e-6


def test_summarize_headline_keys():
    chains = _ar1(jax.random.PRNGKey(5), 2, 200, 0.2, d=3)
    s = summarize(chains)
    assert set(s) == {"max_rhat", "min_ess", "mean_ess"}
    assert s["min_ess"] <= s["mean_ess"]


# ---------------------------------------------------------------------------
# fault discipline (PR 7): refuse non-finite traces, accept a health mask
# ---------------------------------------------------------------------------

def test_nonfinite_trace_is_refused_loudly():
    """A NaN R-hat reads exactly like a converged one in a `< 1.01`
    assertion — so every entry point refuses poisoned traces instead."""
    chains = _ar1(jax.random.PRNGKey(6), 3, 50, 0.2)
    poisoned = chains.at[1, 7].set(jnp.nan)
    for fn in (rhat, ess, summarize):
        with pytest.raises(ValueError, match="non-finite"):
            fn(poisoned)
    with pytest.raises(ValueError, match="non-finite"):
        rhat(chains.at[0, 0].set(jnp.inf))


def test_health_mask_excludes_quarantined_chains():
    chains = _ar1(jax.random.PRNGKey(7), 3, 50, 0.2)
    poisoned = chains.at[1].set(jnp.nan)  # a diverged, quarantined chain
    mask = np.array([True, False, True])
    # masked statistics == statistics over the healthy subset, exactly
    np.testing.assert_array_equal(np.asarray(rhat(poisoned, mask=mask)),
                                  np.asarray(rhat(chains[np.array([0, 2])])))
    np.testing.assert_array_equal(np.asarray(ess(poisoned, mask=mask)),
                                  np.asarray(ess(chains[np.array([0, 2])])))
    s = summarize(poisoned, mask=mask)
    assert s["n_healthy"] == 2 and s["n_excluded"] == 1


def test_health_mask_validation():
    chains = _ar1(jax.random.PRNGKey(8), 3, 50, 0.2)
    with pytest.raises(ValueError, match="mask shape"):
        rhat(chains, mask=np.ones(4, bool))
    with pytest.raises(ValueError, match="excludes every chain"):
        ess(chains, mask=np.zeros(3, bool))
