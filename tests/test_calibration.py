"""Calibration metrics (repro/eval/calibration.py) against ANALYTIC
goldens: every metric is checked on inputs whose value is known in
closed form, plus the structural facts the bench gates rely on (ensemble
NLL beats the mean single-draw NLL by Jensen; coverage brackets the
nominal level for a correct posterior)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.eval import (ece_binary, ece_from_probs, interval_coverage,
                        nll_categorical, nll_gaussian_mixture)


# ---------------------------------------------------------------------------
# NLL
# ---------------------------------------------------------------------------

def test_nll_categorical_analytic():
    # predictive puts 0.8 on the true class for every example:
    # NLL == -log 0.8 exactly
    probs = np.array([[[0.8, 0.2]] * 5])  # (K=1, N=5, C=2)
    labels = np.zeros(5, np.int64)
    assert nll_categorical(probs, labels) == pytest.approx(
        -np.log(0.8), rel=1e-12)


def test_nll_categorical_is_bma_not_mean_of_nlls():
    # two draws, p_true 0.9 and 0.1: BMA NLL = -log 0.5, NOT
    # mean(-log .9, -log .1)
    probs = np.array([[[0.9, 0.1]], [[0.1, 0.9]]])  # (2, 1, 2)
    labels = np.zeros(1, np.int64)
    assert nll_categorical(probs, labels) == pytest.approx(
        -np.log(0.5), rel=1e-12)


def test_ensemble_nll_beats_mean_single_draw_nll():
    """Jensen: -log p̄ <= mean_k(-log p_k) — the inequality the whole
    K-draw serving stack banks on, on random simplex points."""
    key = jax.random.PRNGKey(0)
    K, N, C = 8, 64, 10
    logits = jax.random.normal(key, (K, N, C)) * 3
    probs = jax.nn.softmax(logits, -1)
    labels = jax.random.randint(jax.random.PRNGKey(1), (N,), 0, C)
    ens = nll_categorical(probs, labels)
    singles = [nll_categorical(probs[k:k + 1], labels) for k in range(K)]
    assert ens <= np.mean(singles) + 1e-12


def test_nll_gaussian_mixture_k1_analytic():
    # K=1, y == mu, sigma=1: NLL = 0.5*log(2*pi)
    mu = np.zeros((1, 7))
    sig = np.ones((1, 7))
    y = np.zeros(7)
    assert nll_gaussian_mixture(mu, sig, y) == pytest.approx(
        0.5 * np.log(2 * np.pi), rel=1e-12)


def test_nll_gaussian_mixture_two_components_analytic():
    # mixture of N(-1,1) and N(+1,1) scored at y=0:
    # p = exp(-0.5)/sqrt(2*pi) for both components -> same as K=1 at
    # distance 1
    mu = np.array([[-1.0], [1.0]])
    sig = np.ones((2, 1))
    want = 0.5 + 0.5 * np.log(2 * np.pi)
    assert nll_gaussian_mixture(mu, sig, np.zeros(1)) == pytest.approx(
        want, rel=1e-12)


# ---------------------------------------------------------------------------
# ECE
# ---------------------------------------------------------------------------

def test_ece_perfectly_calibrated_is_zero():
    # conf 0.75 everywhere, exactly 75% correct -> ECE 0
    probs = np.array([[[0.75, 0.25]] * 4])
    labels = np.array([0, 0, 0, 1])
    assert ece_from_probs(probs, labels) == pytest.approx(0.0, abs=1e-12)


def test_ece_fully_overconfident_analytic():
    # conf 1.0 everywhere, 50% correct -> ECE = |0.5 - 1.0| = 0.5
    probs = np.array([[[1.0, 0.0]] * 4])
    labels = np.array([0, 0, 1, 1])
    assert ece_from_probs(probs, labels) == pytest.approx(0.5, rel=1e-12)


def test_ece_two_bin_weighted_mix_analytic():
    # bin A: 2 examples at conf .95, both correct -> |1 - .95| = .05
    # bin B: 2 examples at conf .55, none correct -> |0 - .55| = .55
    # ECE = .5*.05 + .5*.55 = 0.30
    probs = np.array([[[0.95, 0.05], [0.95, 0.05],
                       [0.55, 0.45], [0.55, 0.45]]])
    labels = np.array([0, 0, 1, 1])
    assert ece_from_probs(probs, labels) == pytest.approx(0.30, rel=1e-12)


def test_ece_binary_matches_two_column():
    key = jax.random.PRNGKey(2)
    p1 = jax.nn.sigmoid(jax.random.normal(key, (3, 50)))
    labels = jax.random.randint(jax.random.PRNGKey(3), (50,), 0, 2)
    p1_64 = np.asarray(p1, np.float64)
    two_col = np.stack([1.0 - p1_64, p1_64], -1)
    assert ece_binary(p1, labels) == pytest.approx(
        ece_from_probs(two_col, labels), rel=1e-12)


def test_ece_averaging_disagreeing_draws_calibrates():
    """Two overconfident draws that disagree average to a calibrated
    predictive: ensemble ECE < each draw's ECE (the mechanism by which
    BMA fixes calibration)."""
    # draw 1 says class 0 w.p. .99, draw 2 says class 1 w.p. .99;
    # truth is 50/50
    N = 40
    d1 = np.tile([[0.99, 0.01]], (N, 1))
    d2 = np.tile([[0.01, 0.99]], (N, 1))
    probs = np.stack([d1, d2])  # (2, N, 2)
    labels = np.array([0, 1] * (N // 2))
    ens = ece_from_probs(probs, labels)
    singles = [ece_from_probs(probs[k:k + 1], labels) for k in range(2)]
    assert ens < min(singles) - 0.2


# ---------------------------------------------------------------------------
# predictive-interval coverage
# ---------------------------------------------------------------------------

def test_coverage_exact_posterior_near_nominal():
    # targets drawn from the same distribution as the samples: central
    # 90% interval must cover ~90%
    key = jax.random.PRNGKey(4)
    s = jax.random.normal(key, (4000, 500))
    y = jax.random.normal(jax.random.PRNGKey(5), (500,))
    cov = interval_coverage(s, y, level=0.9)
    assert 0.85 < cov < 0.95, cov


def test_coverage_degenerate_interval_analytic():
    # all samples equal 0: the interval is the point {0} -> covers
    # exactly the targets equal to 0
    s = np.zeros((10, 4))
    y = np.array([0.0, 0.0, 1.0, -1.0])
    assert interval_coverage(s, y, level=0.9) == pytest.approx(0.5)


def test_coverage_overconfident_posterior_undercovers():
    # posterior 10x too narrow: coverage collapses far below nominal
    key = jax.random.PRNGKey(6)
    s = 0.1 * jax.random.normal(key, (2000, 400))
    y = jax.random.normal(jax.random.PRNGKey(7), (400,))
    assert interval_coverage(s, y, level=0.9) < 0.3


def test_nll_and_ece_accept_jax_arrays():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(8),
                                             (2, 30, 5)), -1)
    labels = jnp.zeros((30,), jnp.int32)
    assert np.isfinite(nll_categorical(probs, labels))
    assert np.isfinite(ece_from_probs(probs, labels))
