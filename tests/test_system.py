"""End-to-end system behaviour: trainer/server drivers, federated shard_map
round (ppermute chain exchange), data pipeline properties, checkpointing,
HLO roofline analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SamplerConfig, get_smoke_config
from repro.data import (gaussian_shards, linreg_datasets, metric_pairs,
                        susy_shards, token_shards)
from repro import checkpoint
from repro.models import init_params


@pytest.mark.slow  # full smoke train driver, ~40s on the CPU container
def test_train_driver_runs(tmp_path):
    from repro.launch.train import main
    rc = main(["--arch", "rwkv6-7b", "--smoke", "--rounds", "2",
               "--local-updates", "1", "--fit-steps", "6", "--seq", "32",
               "--shard-size", "16", "--batch", "4",
               "--ckpt", str(tmp_path / "ck")])
    assert rc == 0
    params = init_params(get_smoke_config("rwkv6-7b"), jax.random.PRNGKey(0))
    restored, step, extra = checkpoint.restore(str(tmp_path / "ck"), params)
    assert step == 2 and extra["method"] == "fsgld"


def test_dsgld_train_driver_runs():
    from repro.launch.train import main
    assert main(["--arch", "qwen3-1.7b", "--smoke", "--method", "dsgld",
                 "--rounds", "1", "--local-updates", "1", "--seq", "16",
                 "--shard-size", "8", "--batch", "2"]) == 0


def test_serve_driver_runs():
    from repro.launch.serve import main
    assert main(["--arch", "recurrentgemma-2b", "--smoke", "--batch", "2",
                 "--prompt-len", "4", "--gen", "3"]) == 0


def test_train_draw_bank_then_ensemble_serve(tmp_path):
    """The streaming chain→server path end to end: train writes
    DrawMeta-enveloped draws into a versioned bank, serve answers with
    the K-draw ensemble from the same directory."""
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main
    bank = str(tmp_path / "bank")
    rc = train_main(["--arch", "h2o-danube-1.8b", "--smoke", "--method",
                     "dsgld", "--rounds", "2", "--local-updates", "1",
                     "--seq", "16", "--shard-size", "8", "--batch", "2",
                     "--draw-bank", bank, "--bank-every", "1"])
    assert rc == 0
    draws = checkpoint.list_draws(bank)
    assert len(draws) == 2
    meta = checkpoint.read_meta(draws[-1])
    assert meta.method == "dsgld" and meta.round == 2
    assert meta.arch == "h2o-danube-1.8b"
    assert serve_main(["--arch", "h2o-danube-1.8b", "--smoke", "--batch",
                       "2", "--prompt-len", "4", "--gen", "3",
                       "--draws", "2", "--bank", bank]) == 0


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("gemma-7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    checkpoint.save(str(tmp_path / "c"), params, step=7, extra={"k": 1})
    restored, step, extra = checkpoint.restore(str(tmp_path / "c"), params)
    assert step == 7 and extra == {"k": 1}
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data pipeline structure
# ---------------------------------------------------------------------------

def test_susy_shards_noniid_vs_iid():
    key = jax.random.PRNGKey(0)
    noniid, pi_n = susy_shards(key, num_shards=20, shard_size=500,
                               beta_a=0.5)
    iid, pi_i = susy_shards(key, num_shards=20, shard_size=500,
                            beta_a=100.0)
    # non-IID: label proportions spread out; IID: concentrated at 1/2
    assert float(jnp.std(pi_n)) > 5 * float(jnp.std(pi_i))
    assert noniid["x"].shape == (20, 500, 18)
    # shard label means track pi
    emp = noniid["y"].mean(axis=1)
    assert float(jnp.corrcoef(emp, pi_n)[0, 1]) > 0.95


def test_metric_pairs_class_disjoint():
    key = jax.random.PRNGKey(0)
    data, centers = metric_pairs(key, num_classes=26, dim=8, num_shards=13,
                                 pairs_per_shard=40)
    assert data["xi"].shape == (13, 40, 8)
    assert set(np.unique(np.asarray(data["y"]))) == {0.0, 1.0}


def test_token_shards_heterogeneous():
    key = jax.random.PRNGKey(0)
    d = token_shards(key, num_shards=4, shard_size=32, seq_len=16,
                     vocab_size=64, alpha=0.05)
    assert d["tokens"].shape == (4, 32, 16)
    # labels are next-token shifts of the same stream
    # per-client unigram distributions differ (non-IID)
    hists = [np.bincount(np.asarray(d["tokens"][s]).ravel(), minlength=64)
             for s in range(4)]
    cos = np.dot(hists[0], hists[1]) / (np.linalg.norm(hists[0])
                                        * np.linalg.norm(hists[1]))
    assert cos < 0.9, cos


def test_linreg_datasets_shapes():
    out = linreg_datasets(jax.random.PRNGKey(0))
    assert set(out) == {"concrete", "noise", "conductivity"}
    assert out["conductivity"]["x"].shape == (17389, 81)


# ---------------------------------------------------------------------------
# roofline analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_scales_loops():
    from repro.roofline.hlo_analysis import analyze_text

    def f_scan(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    one = 2 * 64 ** 3
    for f, want in [(f_scan, 10 * one), (f_nested, 20 * one)]:
        c = jax.jit(f).lower(x, w).compile()
        got = analyze_text(c.as_text())["flops"]
        assert abs(got - want) / want < 0.01, (got, want)


def test_hlo_analyzer_matches_xla_on_loop_free():
    from repro.roofline.hlo_analysis import analyze_text

    def f(w1, w2, x):
        return jnp.sum(jnp.tanh(x @ w1) @ w2)

    g = jax.grad(f, argnums=(0, 1))
    xs = [jax.ShapeDtypeStruct(s, jnp.float32)
          for s in [(64, 128), (128, 32), (16, 64)]]
    c = jax.jit(g).lower(*xs).compile()
    got = analyze_text(c.as_text())["flops"]
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per program
        cost = cost[0]
    want = cost["flops"]
    assert abs(got - want) / want < 0.05, (got, want)
