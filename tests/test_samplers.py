"""Core FSGLD invariants: estimator unbiasedness (Lemma 1), conducive
gradient zero-mean, surrogate products, posterior-moment recovery on
conjugate models, and the paper's Sec 5.1 qualitative claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (Gaussian, ShardScheme,
                        analytic_gaussian_likelihood_surrogate,
                        conducive_gradient, fit_gaussian, make_bank,
                        make_drift_fn)


def _gaussian_problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    return x, bank


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


# ---------------------------------------------------------------------------
# Lemma 1: conducive gradients are zero-mean; FSGLD estimator stays unbiased
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), S=st.integers(2, 8))
def test_conducive_gradient_zero_mean(seed, S):
    key = jax.random.PRNGKey(seed)
    d = 4
    mus = jax.random.normal(key, (S, d))
    precs = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                      (S, d))) + 0.1
    bank = make_bank(mus, precs, "diag")
    theta = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    f = jnp.full((S,), 1.0 / S)
    total = sum(
        f[s] * conducive_gradient(theta, bank.global_, bank.shard(s), f[s])
        for s in range(S))
    np.testing.assert_allclose(np.asarray(total), 0.0, atol=1e-4)


def test_fsgld_estimator_unbiased():
    """E_{s, minibatch}[FSGLD drift] == full-data gradient (Lemma 1)."""
    key = jax.random.PRNGKey(0)
    S, n, d = 5, 40, 3
    x, bank = _gaussian_problem(key, S, n, d)
    theta = jnp.array([0.3, -1.0, 2.0])
    cfg_d = SamplerConfig(method="dsgld", num_shards=S, prior_precision=1.0)
    cfg_f = SamplerConfig(method="fsgld", num_shards=S, prior_precision=1.0)
    scheme = ShardScheme(sizes=(n,) * S, probs=(1.0 / S,) * S)
    exact = -theta + jnp.sum(x.reshape(-1, d) - theta, axis=0)

    for cfg in (cfg_d, cfg_f):
        drift_fn = make_drift_fn(log_lik, cfg, scheme,
                                 bank if cfg.method == "fsgld" else None)
        # enumerate shard x exhaustive single-point minibatches: exact E
        acc = jnp.zeros(d)
        for s in range(S):
            for i in range(n):
                batch = {"x": x[s, i:i + 1]}
                acc = acc + (1.0 / S) * (1.0 / n) * drift_fn(
                    theta, batch, s, 1)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(exact),
                                   rtol=1e-3, atol=1e-3)


def test_fsgld_estimator_variance_below_dsgld():
    """The point of the paper: conducive gradients shrink estimator variance
    under non-IID shards (Fig 1 / Theorem 2 vs Theorem 1)."""
    key = jax.random.PRNGKey(1)
    S, n, d = 5, 40, 3
    x, bank = _gaussian_problem(key, S, n, d)
    theta = jnp.zeros(d)
    scheme = ShardScheme(sizes=(n,) * S, probs=(1.0 / S,) * S)

    def estimator_variance(method, bank_=None):
        cfg = SamplerConfig(method=method, num_shards=S, prior_precision=1.0)
        drift_fn = make_drift_fn(log_lik, cfg, scheme, bank_)
        drifts = []
        k = key
        for t in range(400):
            k, k1, k2 = jax.random.split(k, 3)
            s = int(jax.random.randint(k1, (), 0, S))
            idx = jax.random.randint(k2, (5,), 0, n)
            drifts.append(drift_fn(theta, {"x": x[s][idx]}, s, 5))
        d_ = jnp.stack(drifts)
        return float(jnp.mean(jnp.var(d_, axis=0)))

    v_dsgld = estimator_variance("dsgld")
    v_fsgld = estimator_variance("fsgld", bank)
    assert v_fsgld < 0.25 * v_dsgld, (v_fsgld, v_dsgld)


# ---------------------------------------------------------------------------
# surrogates
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 9999))
def test_gaussian_product_matches_sum_of_grads(seed):
    """grad log q == sum_s grad log q_s for the product Gaussian (the
    closed-form the 'computed once' claim rests on)."""
    key = jax.random.PRNGKey(seed)
    S, d = 4, 3
    mus = jax.random.normal(key, (S, d))
    precs = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                      (S, d))) + 0.1
    bank = make_bank(mus, precs, "diag")
    theta = jax.random.normal(jax.random.fold_in(key, 2), (d,))
    direct = sum(bank.shard(s).grad_log(theta) for s in range(S))
    np.testing.assert_allclose(np.asarray(bank.global_.grad_log(theta)),
                               np.asarray(direct), rtol=1e-4, atol=1e-4)


def test_fit_gaussian_full_and_diag():
    key = jax.random.PRNGKey(0)
    true_mu = jnp.array([1.0, -2.0])
    true_cov = jnp.array([[2.0, 0.6], [0.6, 1.0]])
    chol = jnp.linalg.cholesky(true_cov)
    samples = true_mu + jax.random.normal(key, (20000, 2)) @ chol.T
    mu, prec = fit_gaussian(samples, "full")
    np.testing.assert_allclose(np.asarray(mu), np.asarray(true_mu),
                               atol=0.05)
    np.testing.assert_allclose(np.asarray(jnp.linalg.inv(prec)),
                               np.asarray(true_cov), atol=0.1)
    mu_d, prec_d = fit_gaussian(samples, "diag")
    np.testing.assert_allclose(np.asarray(1.0 / prec_d),
                               np.asarray(jnp.diag(true_cov)), atol=0.1)


# ---------------------------------------------------------------------------
# posterior recovery (conjugate Gaussian; paper Sec 5.1 setting)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gaussian_mean_runs():
    key = jax.random.PRNGKey(0)
    S, n, d = 10, 200, 2
    x, bank = _gaussian_problem(key, S, n, d)
    N = S * n
    post_mean = x.reshape(-1, d).sum(0) / (1 + N)
    out = {}
    for method, local in [("sgld", 1), ("dsgld", 100), ("fsgld", 100)]:
        rounds = 30000 // local
        samp = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), {"x": x},
            minibatch=10, step_size=1e-4, method=method,
            surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                       if method == "fsgld" else None),
            schedule=api.Schedule(rounds=rounds, local_steps=local,
                                  n_chains=1, thin=10))
        trace = samp.sample(jax.random.PRNGKey(2), jnp.zeros(d))[0]
        trace = trace[trace.shape[0] // 2:]
        out[method] = float(jnp.sum((trace.mean(0) - post_mean) ** 2))
    return out


@pytest.mark.slow  # the shared fixture runs 3 x 30k-step chains (~50s)
def test_fsgld_converges_where_dsgld_drifts(gaussian_mean_runs):
    """Paper Fig 2/3: with 100 local updates DSGLD drifts toward the local
    mixture; FSGLD stays on the true posterior."""
    assert gaussian_mean_runs["fsgld"] < 1e-3, gaussian_mean_runs
    assert gaussian_mean_runs["dsgld"] > 10 * gaussian_mean_runs["fsgld"], \
        gaussian_mean_runs


@pytest.mark.slow
def test_sgld_baseline_converges(gaussian_mean_runs):
    assert gaussian_mean_runs["sgld"] < 5e-3, gaussian_mean_runs
