"""Pallas kernel correctness: shape/dtype sweeps against the pure-jnp oracle
(bit-exact, including in-kernel noise), plus noise statistics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KW = dict(h=1e-3, scale=37.0, f_s=0.1, prior_prec=1.0, alpha=1.0,
          temperature=1.0)


def _operands(P, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    th = jax.random.normal(ks[0], (P,))
    g = jax.random.normal(ks[1], (P,))
    mg = jax.random.normal(ks[2], (P,))
    ms = jax.random.normal(ks[3], (P,))
    lg = jnp.abs(jax.random.normal(ks[4], (P,))) + 0.1
    ls = jnp.abs(jax.random.normal(ks[5], (P,))) + 0.1
    return th, g, mg, ms, lg, ls


@pytest.mark.parametrize("P", [1, 7, 128, 1000, 4096, 33333, 131072])
@pytest.mark.parametrize("variant", ["plain", "scalar", "diag"])
def test_kernel_matches_oracle(P, variant):
    th, g, mg, ms, lg, ls = _operands(P)
    seed = jnp.uint32(99)
    if variant == "plain":
        a = ops.fused_update_flat(th, g, seed, **KW)
        b = ref.fsgld_update_flat(th, g, seed, **KW)
    elif variant == "scalar":
        a = ops.fused_update_flat(th, g, seed, mu_g=mg, mu_s=ms,
                                  lam_g=jnp.float32(0.7),
                                  lam_s=jnp.float32(0.3), **KW)
        b = ref.fsgld_update_flat(th, g, seed, mu_g=mg, mu_s=ms, lam_g=0.7,
                                  lam_s=0.3, **KW)
    else:
        a = ops.fused_update_flat(th, g, seed, mu_g=mg, mu_s=ms, lam_g=lg,
                                  lam_s=ls, **KW)
        b = ref.fsgld_update_flat(th, g, seed, mu_g=mg, mu_s=ms, lam_g=lg,
                                  lam_s=ls, **KW)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_mixed_dtype_means(dtype):
    """Surrogate means arrive in bf16 at billion scale — kernel upcasts."""
    P = 4096
    th, g, mg, ms, lg, ls = _operands(P)
    seed = jnp.uint32(3)
    a = ops.fused_update_flat(th, g, seed, mu_g=mg.astype(dtype),
                              mu_s=ms.astype(dtype), lam_g=jnp.float32(0.7),
                              lam_s=jnp.float32(0.3), **KW)
    b = ref.fsgld_update_flat(th, g, seed, mu_g=mg.astype(dtype),
                              mu_s=ms.astype(dtype), lam_g=0.7, lam_s=0.3,
                              **KW)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(P=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1),
       h=st.floats(1e-6, 1e-2), scale=st.floats(0.1, 1e4),
       temp=st.floats(0.0, 2.0))
def test_kernel_property_sweep(P, seed, h, scale, temp):
    """Hypothesis: for arbitrary sizes/hyperparams the kernel equals the
    oracle (the system invariant behind make_step_fn(use_kernel=True))."""
    th, g, mg, ms, lg, ls = _operands(P, key=seed % 97)
    kw = dict(h=h, scale=scale, f_s=0.25, prior_prec=0.5, alpha=1.0,
              temperature=temp)
    s = jnp.uint32(seed)
    a = ops.fused_update_flat(th, g, s, mu_g=mg, mu_s=ms, lam_g=lg,
                              lam_s=ls, **kw)
    b = ref.fsgld_update_flat(th, g, s, mu_g=mg, mu_s=ms, lam_g=lg,
                              lam_s=ls, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


def test_noise_is_standard_normal():
    x = np.asarray(ref.gaussian_noise(jnp.uint32(7),
                                      jnp.arange(500_000, dtype=jnp.uint32)))
    assert abs(x.mean()) < 0.01
    assert abs(x.std() - 1.0) < 0.01
    kurt = ((x - x.mean()) ** 4).mean() / x.var() ** 2
    assert abs(kurt - 3.0) < 0.05
    # distinct seeds decorrelate
    y = np.asarray(ref.gaussian_noise(jnp.uint32(8),
                                      jnp.arange(500_000, dtype=jnp.uint32)))
    assert abs(np.corrcoef(x, y)[0, 1]) < 0.01


def test_fused_tree_update_matches_unfused_at_zero_temperature():
    """End-to-end: kernel-routed step == pure-jnp step when noise is off
    (noise streams differ by construction; drift must not)."""
    from repro.configs.base import SamplerConfig
    from repro.core import ShardScheme, make_step_fn, make_bank

    tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (130,)),
            "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (7, 11))}}

    def log_lik(theta, batch):
        return -0.5 * jnp.sum((batch["x"] - theta["a"][0]) ** 2) \
            - 0.5 * jnp.sum(theta["b"]["c"] ** 2)

    cfg = SamplerConfig(method="fsgld", step_size=1e-3, num_shards=4,
                        temperature=0.0, surrogate="scalar")
    scheme = ShardScheme(sizes=(50,) * 4, probs=(0.25,) * 4)
    means = jax.tree.map(
        lambda t: jnp.stack([t * 0.9, t * 1.1, t * 0.8, t * 1.2]), tree)
    precs = jax.tree.map(lambda t: jnp.array([0.5, 0.6, 0.7, 0.8]), tree)
    bank = make_bank(means, precs, "scalar")
    batch = {"x": jnp.ones((8,))}

    ref_step = make_step_fn(log_lik, cfg, scheme, bank, use_kernel=False)
    ker_step = make_step_fn(log_lik, cfg, scheme, bank, use_kernel=True)
    key = jax.random.PRNGKey(5)
    out_a = ref_step(tree, key, batch, 2, 8)
    out_b = ker_step(tree, key, batch, 2, 8)
    for la, lb in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5, rtol=1e-5)
