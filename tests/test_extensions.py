"""Beyond-paper extensions: SGHMC with conducive gradients, adaptive
surrogate refresh, linear control-variate surrogates, MCMC diagnostics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (FederatedSGHMC, Gaussian,
                        analytic_gaussian_likelihood_surrogate,
                        conducive_gradient, ess, fit_bank_linear, make_bank,
                        refresh_bank, rhat, summarize)


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    S, n, d = 10, 200, 2
    mus = jax.random.uniform(key, (S, d), minval=-6, maxval=6)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    post_mean = x.reshape(-1, d).sum(0) / (1 + S * n)
    return {"x": x}, bank, post_mean


def test_sghmc_with_conducive_gradients_converges(problem):
    data, bank, post_mean = problem
    cfg = SamplerConfig(method="fsgld", step_size=2e-5, num_shards=10,
                        local_updates=100, prior_precision=1.0)
    samp = FederatedSGHMC(log_lik, cfg, data, minibatch=10, bank=bank)
    tr = samp.run(jax.random.PRNGKey(1), jnp.zeros(2), 150,
                  collect_every=10)
    tr = tr[tr.shape[0] // 2:]
    mse = float(jnp.sum((tr.mean(0) - post_mean) ** 2))
    assert mse < 5e-3, mse


def test_sghmc_dsgld_mode_biased_vs_fsgld(problem):
    """The conducive correction composes with the SGHMC drift: dsgld-mode
    SGHMC drifts under delayed communication, fsgld-mode does not."""
    data, bank, post_mean = problem

    def run(method):
        cfg = SamplerConfig(method=method, step_size=2e-5, num_shards=10,
                            local_updates=100, prior_precision=1.0)
        samp = FederatedSGHMC(log_lik, cfg, data, minibatch=10, bank=bank)
        tr = samp.run(jax.random.PRNGKey(1), jnp.zeros(2), 150,
                      collect_every=10)
        tr = tr[tr.shape[0] // 2:]
        return float(jnp.sum((tr.mean(0) - post_mean) ** 2))

    assert run("fsgld") < 0.3 * run("dsgld")


def test_refresh_bank_gradient_matching(problem):
    """After refresh at theta, grad log q_s(theta) equals the exact local
    likelihood gradient at theta (per shard)."""
    data, _, _ = problem
    theta = jnp.array([0.7, -1.3])
    bank = refresh_bank(log_lik, data, theta)
    for s in range(3):
        got = bank.shard(s).grad_log(theta)
        want = jax.grad(
            lambda t: log_lik(t, jax.tree.map(lambda a: a[s], data)))(theta)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)


def test_adaptive_refresh_run(problem):
    data, bank, post_mean = problem
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=10,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank,
                                    refresh_every=25),
        schedule=api.Schedule(rounds=100, local_steps=100, n_chains=1,
                              thin=10))
    tr = samp.sample(jax.random.PRNGKey(2), jnp.zeros(2))[0]
    tr = tr[tr.shape[0] // 2:]
    mse = float(jnp.sum((tr.mean(0) - post_mean) ** 2))
    assert mse < 1e-3, mse


def test_linear_surrogates_zero_mean_and_stable(problem):
    data, _, post_mean = problem
    bank = fit_bank_linear(log_lik, data, jnp.zeros(2), batch=50)
    f = 1.0 / 10
    total = sum(f * conducive_gradient(jnp.ones(2), bank.global_,
                                       bank.shard(s), f)
                for s in range(10))
    np.testing.assert_allclose(np.asarray(total), 0.0, atol=1e-2)
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=10,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind=bank.kind, bank=bank),
        schedule=api.Schedule(rounds=100, local_steps=100, n_chains=1,
                              thin=10))
    tr = samp.sample(jax.random.PRNGKey(3), jnp.zeros(2))[0]
    assert bool(jnp.all(jnp.isfinite(tr)))
    mse = float(jnp.sum((tr[tr.shape[0] // 2:].mean(0) - post_mean) ** 2))
    assert mse < 5e-3, mse


# ---------------------------------------------------------------------------
# diagnostics
# ---------------------------------------------------------------------------

def test_rhat_iid_chains_near_one():
    key = jax.random.PRNGKey(0)
    chains = jax.random.normal(key, (4, 2000, 3))
    r = rhat(chains)
    assert float(jnp.max(jnp.abs(r - 1.0))) < 0.02


def test_rhat_detects_unmixed_chains():
    key = jax.random.PRNGKey(0)
    chains = jax.random.normal(key, (4, 1000, 2)) \
        + jnp.arange(4.0)[:, None, None]
    assert float(jnp.min(rhat(chains))) > 1.5


def test_ess_iid_near_n():
    key = jax.random.PRNGKey(1)
    chains = jax.random.normal(key, (2, 4000, 2))
    e = ess(chains)
    assert float(jnp.min(e)) > 0.5 * 8000


def test_ess_autocorrelated_much_smaller():
    key = jax.random.PRNGKey(2)
    eps = jax.random.normal(key, (2, 4000, 1))
    # AR(1) with rho=0.95 -> tau ~ 39
    def ar(carry, e):
        x = 0.95 * carry + e
        return x, x
    _, x = jax.lax.scan(ar, jnp.zeros((2, 1)), eps.transpose(1, 0, 2))
    chains = x.transpose(1, 0, 2)
    e = ess(chains)
    assert float(jnp.max(e)) < 1500, float(jnp.max(e))
    s = summarize(chains)
    assert s["min_ess"] == float(jnp.min(e))
