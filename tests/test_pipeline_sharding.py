"""Input pipeline + sharding-rule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import (ClientDataset, FederatedPipeline,
                                 categorical_schedule, round_robin)
from repro.launch.specs import params_shape
from repro.sharding import param_specs


def test_client_dataset_epochs_cover_all():
    data = {"x": np.arange(10)[:, None]}
    ds = ClientDataset(data, seed=0)
    seen = []
    for _ in range(5):
        seen.extend(ds.next_batch(2)["x"][:, 0].tolist())
    assert sorted(seen) == list(range(10))  # one full epoch, no repeats


def test_pipeline_prefetch_and_schedule():
    clients = [ClientDataset({"x": np.full((8, 2), i)}, seed=i)
               for i in range(3)]
    pipe = FederatedPipeline(clients, batch_size=4,
                             schedule=round_robin(3), prefetch=2)
    for expect in [0, 1, 2, 0, 1]:
        s, batch = next(pipe)
        assert s == expect
        assert bool(jnp.all(batch["x"] == expect))


def test_categorical_schedule_marginals():
    sched = categorical_schedule([0.7, 0.2, 0.1], seed=0)
    draws = np.array([next(sched) for _ in range(5000)])
    freq = np.bincount(draws, minlength=3) / 5000
    np.testing.assert_allclose(freq, [0.7, 0.2, 0.1], atol=0.03)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh512():
    # abstract-device mesh just for spec resolution (no computation)
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    from jax.sharding import Mesh
    return Mesh(devs, ("data", "model"))


def test_param_specs_2d_sharding(mesh512):
    pshape = params_shape(get_config("qwen3-1.7b"))
    specs = param_specs(pshape, mesh512)
    # stacked attention weight: (layers, D, H*hd) -> (None, data, model)
    wq = specs["blocks"]["l0"]["attn"]["wq"]
    assert wq == P(None, "data", "model"), wq
    assert specs["embed"] == P("model", "data")
    assert specs["head"] == P("data", "model")
    # norms replicate
    assert specs["final_norm"] == P()


def test_param_specs_uneven_dims_replicate(mesh512):
    """whisper: 20 heads / 51866 vocab don't divide 16 -> those dims fall
    back to replication instead of failing."""
    pshape = params_shape(get_config("whisper-large-v3"))
    specs = param_specs(pshape, mesh512)
    assert specs["embed"] == P(None, "data")  # vocab 51866 % 16 != 0
    wq = specs["blocks"]["l0"]["attn"]["wq"]  # q_dim 1280 % 16 == 0
    assert wq == P(None, "data", "model")


def test_param_specs_serving_layout_drops_fsdp(mesh512):
    pshape = params_shape(get_smoke_config("rwkv6-7b"))
    specs = param_specs(pshape, mesh512, serve=True)
    for leaf in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in jax.tree.leaves(leaf) and "data" not in leaf


def test_param_specs_serving_layout_keeps_2d_when_too_big(mesh512):
    pshape = params_shape(get_config("grok-1-314b"))
    specs = param_specs(pshape, mesh512, serve=True)
    wq = specs["blocks"]["l0"]["attn"]["wq"]
    assert wq == P(None, "data", "model")  # 314B can't replicate over data
