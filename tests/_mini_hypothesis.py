"""Deterministic fallback for the `hypothesis` API surface this suite uses.

The repo's property tests (`tests/test_kernels.py`, `test_layers.py`,
`test_samplers.py`) only need ``given``/``settings`` and the ``integers``,
``floats``, ``sampled_from`` strategies. When the real package is installed
(CI does, via ``pip install -e .[dev]``) it is used untouched;
``tests/conftest.py`` registers this module under the ``hypothesis`` name
only when the import fails, so the suite still collects and exercises every
property on the bare container image.

Semantics of the stand-in: each strategy draws ``max_examples`` values from
a seeded PRNG, always including the domain endpoints first (the cheap
analogue of hypothesis's shrink-toward-boundary behaviour). Failures
re-raise with the offending example in the message. No shrinking, no
database, no health checks — it is a gate for a missing dependency, not a
replacement.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random


class _Strategy:
    def __init__(self, endpoints, draw):
        self.endpoints = list(endpoints)
        self.draw = draw


class strategies:  # noqa: N801 - mimics the hypothesis.strategies module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            [min_value, max_value],
            lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            [min_value, max_value],
            lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(
            options[:2], lambda rng: rng.choice(options))

    @staticmethod
    def booleans():
        return _Strategy([False, True], lambda rng: rng.random() < 0.5)


def settings(max_examples=None, deadline=None, **_ignored):
    """Decorator recording ``max_examples``; order-independent wrt @given."""

    def deco(fn):
        if max_examples is not None:
            fn._mini_hyp_max_examples = max_examples
        return fn

    return deco


def given(**param_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_mini_hyp_max_examples", 10)
            names = list(param_strategies)
            strats = [param_strategies[k] for k in names]
            # endpoint combinations first, then seeded random draws
            combos = list(itertools.islice(
                itertools.product(*(s.endpoints for s in strats)), n))
            rng = random.Random(0xF5617D)
            while len(combos) < n:
                combos.append(tuple(s.draw(rng) for s in strats))
            for combo in combos:
                example = dict(zip(names, combo))
                try:
                    fn(*args, **kwargs, **example)
                except Exception as e:  # noqa: BLE001 - annotate and re-raise
                    raise AssertionError(
                        f"mini-hypothesis falsifying example "
                        f"{fn.__name__}({example})") from e

        # hide the strategy params from pytest's fixture resolution
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in param_strategies]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        return wrapper

    return deco


class HealthCheck:  # pragma: no cover - accepted, ignored
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None
