"""Preemption-safe resume (PR 7): snapshots of the full scan carry.

The contract: a run that snapshots every k rounds — and a run KILLED
after any snapshot and resumed — produces traces BITWISE identical to
the uninterrupted single-dispatch run. That holds across executors
(vmap / packed kernel), under an active federation scenario (delayed +
partial participation + stragglers + top-k error-feedback compression:
every piece of carried state — PRNG key, sids, server reference,
error-feedback accumulator, health words — must live in the snapshot),
with mesh padding (n_chains not a multiple of the data axis), and with
a recovery policy's health state.

Plus the snapshot substrate itself: atomic writes (a torn snapshot is
detected and the loader falls back to the previous one), pruning, and
the ``snap-NNNNNN`` listing discipline.
"""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.checkpoint.snapshot import (latest_snapshot, list_snapshots,
                                       save_snapshot)
from repro.configs.base import SamplerConfig
from repro.core import analytic_gaussian_likelihood_surrogate, make_bank
from repro.core.engine import MeshChainEngine
from repro.core.health import Recovery
from repro.fed import CommSchedule, Compression, Federation
from repro.testing import ChaosSpec, corrupt_draw

S, n, d = 5, 40, 3
KEY = jax.random.PRNGKey(7)


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


@pytest.fixture(scope="module")
def problem():
    key0 = jax.random.PRNGKey(0)
    mus = jax.random.uniform(key0, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key0, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _engine(problem, use_kernel=False):
    data, bank = problem
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=3, prior_precision=1.0)
    return MeshChainEngine(log_lik, cfg, data, minibatch=8, bank=bank,
                          use_kernel=use_kernel)


HARD_FED = Federation(
    schedule=CommSchedule(delay=2, participation=0.6, straggler_prob=0.2),
    compression=Compression(kind="topk", frac=0.5, error_feedback=True))


# ---------------------------------------------------------------------------
# resume parity matrix: executors x scenarios
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["vmap", "kernel"])
@pytest.mark.parametrize("fed", [None, HARD_FED],
                         ids=["identity", "hard-fed"])
def test_snapshot_and_resume_bitwise_parity(tmp_path, problem,
                                            use_kernel, fed):
    """Snapshotted run == oracle, and a run killed after round 3 (its
    newest snapshot deleted to simulate the torn tail) resumed == oracle
    — bitwise, every executor x scenario cell."""
    eng = _engine(problem, use_kernel=use_kernel)
    snaps = str(tmp_path / "snaps")
    ref = eng.run(KEY, jnp.zeros(d), 7, n_chains=4, federation=fed)
    a = eng.run(KEY, jnp.zeros(d), 7, n_chains=4, federation=fed,
                snapshot_every=3, snapshot_path=snaps)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(a))
    got = [r for r, _ in list_snapshots(snaps)]
    assert got == [3, 6, 7][-2:], got  # keep=2 pruning

    # kill: drop the final snapshot, resume from round 3's
    shutil.rmtree(list_snapshots(snaps)[-1][1])
    b = eng.run(KEY, jnp.zeros(d), 7, n_chains=4, federation=fed,
                snapshot_every=3, snapshot_path=snaps, resume=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(b))


def test_resume_with_padding_health_and_chaos(tmp_path, problem):
    """The full carry survives segmentation: mesh padding (n_chains=3),
    a quarantine policy with the divergence detector on, and a chaos
    fault in the SECOND segment (the resumed run must replay it at the
    same absolute round)."""
    eng = _engine(problem)
    rec = Recovery(policy="quarantine", divergence_threshold=100.0)
    chaos = ChaosSpec(nan_chains=(1,), nan_rounds=(4,))
    snaps = str(tmp_path / "snaps")
    ref, href = eng.run(KEY, jnp.zeros(d), 6, n_chains=3, recovery=rec,
                        chaos=chaos)
    a, ha = eng.run(KEY, jnp.zeros(d), 6, n_chains=3, recovery=rec,
                    chaos=chaos, snapshot_every=2, snapshot_path=snaps)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(href.word),
                                  np.asarray(ha.word))
    shutil.rmtree(list_snapshots(snaps)[-1][1])
    b, hb = eng.run(KEY, jnp.zeros(d), 6, n_chains=3, recovery=rec,
                    chaos=chaos, snapshot_every=2, snapshot_path=snaps,
                    resume=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(href.word),
                                  np.asarray(hb.word))
    assert np.asarray(href.word)[1] == 5  # chaos at round 4 -> word 5


def test_resume_at_end_returns_stored_trace(tmp_path, problem):
    """Resuming a COMPLETED run re-dispatches nothing: the stored trace
    comes back bitwise."""
    eng = _engine(problem)
    snaps = str(tmp_path / "snaps")
    ref = eng.run(KEY, jnp.zeros(d), 6, n_chains=4, snapshot_every=3,
                  snapshot_path=snaps)
    again = eng.run(KEY, jnp.zeros(d), 6, n_chains=4, snapshot_every=3,
                    snapshot_path=snaps, resume=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(again))


def test_resume_without_snapshots_is_fresh_run(tmp_path, problem):
    eng = _engine(problem)
    snaps = str(tmp_path / "empty")
    ref = eng.run(KEY, jnp.zeros(d), 4, n_chains=4)
    a = eng.run(KEY, jnp.zeros(d), 4, n_chains=4, snapshot_path=snaps,
                resume=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(a))


def test_resume_collect_false_final_states(tmp_path, problem):
    """Large-model mode (collect=False): the carry snapshot holds no
    trace, and resumed FINAL STATES match the uninterrupted run."""
    eng = _engine(problem)
    snaps = str(tmp_path / "snaps")
    ref = eng.run(KEY, jnp.zeros(d), 6, n_chains=4, collect=False)
    a = eng.run(KEY, jnp.zeros(d), 6, n_chains=4, collect=False,
                snapshot_every=2, snapshot_path=snaps)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(a))
    shutil.rmtree(list_snapshots(snaps)[-1][1])
    b = eng.run(KEY, jnp.zeros(d), 6, n_chains=4, collect=False,
                snapshot_every=2, snapshot_path=snaps, resume=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(b))


def test_run_validates_snapshot_args(problem):
    eng = _engine(problem)
    with pytest.raises(ValueError, match="snapshot_path"):
        eng.run(KEY, jnp.zeros(d), 2, snapshot_every=1)
    with pytest.raises(NotImplementedError, match="refresh"):
        eng.run(KEY, jnp.zeros(d), 2, snapshot_every=1,
                snapshot_path="/tmp/x", refresh_every=1)


# ---------------------------------------------------------------------------
# snapshot substrate: atomicity, fallback, pruning
# ---------------------------------------------------------------------------

def _payload(v=0.0):
    return {"chains": jnp.full((2, 3), v), "key": jnp.zeros(2, jnp.uint32)}


def test_torn_snapshot_falls_back_to_previous(tmp_path):
    snaps = str(tmp_path / "snaps")
    save_snapshot(snaps, _payload(1.0), rounds_done=2)
    save_snapshot(snaps, _payload(2.0), rounds_done=4)
    # tear the newest snapshot the way a preempted write would
    newest = list_snapshots(snaps)[-1][1]
    corrupt_draw(newest, mode="truncate")
    with pytest.warns(UserWarning, match="skipping"):
        payload, r = latest_snapshot(snaps, _payload())
    assert r == 2
    np.testing.assert_array_equal(np.asarray(payload["chains"]),
                                  np.full((2, 3), 1.0))


def test_all_snapshots_torn_means_fresh_start(tmp_path):
    snaps = str(tmp_path / "snaps")
    save_snapshot(snaps, _payload(1.0), rounds_done=2)
    corrupt_draw(list_snapshots(snaps)[0][1], mode="garbage")
    with pytest.warns(UserWarning, match="skipping"):
        payload, r = latest_snapshot(snaps, _payload())
    assert payload is None and r == 0


def test_snapshot_pruning_keeps_newest(tmp_path):
    snaps = str(tmp_path / "snaps")
    for r in (1, 2, 3, 4):
        save_snapshot(snaps, _payload(float(r)), rounds_done=r, keep=2)
    assert [r for r, _ in list_snapshots(snaps)] == [3, 4]
    # overwriting the same round replaces, not duplicates
    save_snapshot(snaps, _payload(9.0), rounds_done=4, keep=2)
    assert [r for r, _ in list_snapshots(snaps)] == [3, 4]
    payload, r = latest_snapshot(snaps, _payload())
    assert r == 4
    np.testing.assert_array_equal(np.asarray(payload["chains"]),
                                  np.full((2, 3), 9.0))


def test_atomic_save_never_leaves_half_checkpoint(tmp_path):
    """In-place overwrite staged through .tmp + replace: after a save
    over an existing checkpoint no temp dir remains and the envelope
    verifies; a manually-torn arrays file is detected by the content
    hash."""
    path = str(tmp_path / "ck")
    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    checkpoint.save(path, tree, step=1)
    checkpoint.save(path, jax.tree.map(lambda t: t + 1, tree), step=2)
    assert not [x for x in os.listdir(str(tmp_path))
                if x.startswith(".tmp")]
    got, step, _ = checkpoint.restore(path, tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"] + 1))
    from repro.testing import truncate_file
    truncate_file(os.path.join(path, "arrays.npz"))
    with pytest.raises(checkpoint.CorruptCheckpointError, match="torn|unreadable"):
        checkpoint.restore(path, tree)
