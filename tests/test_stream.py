"""CI ``client-scale`` lane: the streamed client axis.

Contracts (the streamed-axis acceptance criteria):

  * PEAK-RESIDENT PROPERTY — for ANY communication schedule
    (participation x delay x stragglers) the window planner
    (``repro.fed.schedule.plan_stream``) never asks the device to hold
    more than ``resident`` distinct clients, its windows tile the
    schedule exactly, and every sid the scan will visit is inside its
    window's resident set;
  * BITWISE PARITY — fault-free streamed runs equal the resident path
    bit-for-bit on every executor (vmap / per_leaf / packed), across
    dynamics (sghmc), aggregation (fald), compression (bidir top-k),
    and lazy ClientSource data;
  * IN-SCAN LOWERING — with streaming lowered in, the executor jaxpr is
    still ONE rounds-scan, one pallas_call on the packed path, and no
    pad primitive in any scan body (the resident remap is a
    compare-and-sum rank, not a searchsorted scan);
  * ERROR CONTRACTS — unknown ``shard_probs`` presets fail with
    did-you-mean hints; unstreamable configs (categorical reassign,
    refresh, snapshots, recovery, pooled sgld, resident > clients) are
    refused with actionable messages; an undersized resident budget
    names the minimum viable value;
  * the cross-silo host reductions (``repro.fed.hierarchy``) match the
    flat numpy reductions for any silo size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (MeshChainEngine, make_bank,
                        analytic_gaussian_likelihood_surrogate)
from repro.core.surrogate import SurrogateBank
from repro.fed import (CommSchedule, Compression, Federation, Stream,
                       SyntheticClientSource, hierarchical_mean,
                       hierarchical_sum, normalize_hierarchical,
                       plan_stream, replay_sids, resolve_shard_probs,
                       shard_prob_preset_names)


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem(key, S=12, n=24, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# property: peak resident <= K for ANY schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [1, 2, 3])
@pytest.mark.parametrize("participation,delay,straggler",
                         [(1.0, 1, 0.0), (0.6, 1, 0.0), (1.0, 3, 0.0),
                          (0.5, 2, 0.25), (0.8, 3, 0.1)])
def test_peak_resident_bounded_for_any_schedule(window, participation,
                                                delay, straggler):
    """The planner's windows tile the schedule, hold at most ``resident``
    distinct clients each (padded to exactly K, sorted), and cover every
    sid the scan will visit — the device working set is provably bounded
    by the resident budget for any participation/delay/straggler mix."""
    R, C, S = 10, 4, 16
    sched = CommSchedule(delay=delay, participation=participation,
                         straggler_prob=straggler)
    sids = replay_sids(jax.random.PRNGKey(3), num_rounds=R, n_chains=C,
                       num_shards=S, federated=True, sched=sched)
    K = min(C * window, S)
    wins = plan_stream(sids, resident=K, window=window)
    assert sum(w.length for w in wins) == R
    assert [w.r0 for w in wins] == list(range(0, R, window))
    for w in wins:
        ids = np.asarray(w.resident_ids)
        assert ids.shape == (K,) and ids.dtype == np.int32
        assert np.all(np.diff(ids) >= 0), "resident ids not sorted"
        assert np.unique(ids).size <= K
        blk = np.asarray(sids[w.r0:w.r0 + w.length])
        assert np.isin(blk, ids).all(), \
            "scheduled sid outside its window's resident set"


def test_plan_stream_names_minimum_viable_resident():
    sids = replay_sids(jax.random.PRNGKey(0), num_rounds=4, n_chains=6,
                      num_shards=8)
    with pytest.raises(ValueError, match=r"raise resident to at least"):
        plan_stream(sids, resident=1, window=2)


# ---------------------------------------------------------------------------
# bitwise parity: streamed == resident on every executor / variant
# ---------------------------------------------------------------------------

_FED = Federation(schedule=CommSchedule(delay=2, participation=0.6,
                                        straggler_prob=0.2))


def _facade(data, bank, executor, *, stream=None, method="fsgld",
            kernel="sgld", federation=_FED, collect=True):
    return api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4, method=method, kernel=kernel,
        surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                   if method == "fsgld"
                   else api.SurrogateSpec(kind="none")),
        schedule=api.Schedule(rounds=6, local_steps=3, n_chains=4,
                              reassign="permutation", thin=3),
        execution=api.Execution(executor=executor, collect=collect,
                                stream=stream),
        federation=federation)


@pytest.mark.parametrize("executor", ["vmap", "per_leaf", "packed"])
def test_streamed_bitwise_parity_every_executor(executor):
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(7), jnp.zeros(3)
    ref = _facade(data, bank, executor).sample(key, t0)
    got = _facade(data, bank, executor,
                  stream=Stream(resident=6, window=2)).sample(key, t0)
    _assert_bitwise(ref, got)


@pytest.mark.parametrize("variant", ["fald", "sghmc", "compressed",
                                     "no_prefetch"])
def test_streamed_bitwise_parity_variants(variant):
    data, bank = _problem(jax.random.PRNGKey(1))
    key, t0 = jax.random.PRNGKey(9), jnp.zeros(3)
    kw = {}
    if variant == "fald":
        kw = dict(method="fald")
    elif variant == "sghmc":
        kw = dict(kernel="sghmc")
    elif variant == "compressed":
        kw = dict(federation=Federation(
            schedule=CommSchedule(delay=2),
            compression=Compression(kind="topk", frac=0.5,
                                    direction="bidir")))
    stream = (Stream(resident=6, window=2, prefetch=False)
              if variant == "no_prefetch" else Stream(resident=6, window=2))
    ref = _facade(data, bank, "vmap", **kw).sample(key, t0)
    got = _facade(data, bank, "vmap", stream=stream, **kw).sample(key, t0)
    _assert_bitwise(ref, got)


def test_streamed_client_source_odd_chain_count():
    """Lazy ClientSource data + a chain count that does not divide the
    client count (block-cyclic tiling): streamed final states equal the
    materialize-all resident path bitwise."""
    src = SyntheticClientSource(jax.random.PRNGKey(5), num_clients=24,
                                shard_size=8, seq_len=8, vocab_size=32)

    def tok_ll(theta, batch):
        return jnp.sum(jax.nn.log_softmax(theta)[batch["labels"]])

    def build(stream):
        return api.FSGLD(
            api.Posterior(tok_ll), src, minibatch=4, step_size=1e-3,
            method="dsgld", surrogate=api.SurrogateSpec(kind="none"),
            schedule=api.Schedule(rounds=5, local_steps=2, n_chains=5,
                                  reassign="permutation"),
            execution=api.Execution(executor="vmap", collect=False,
                                    stream=stream))

    key, t0 = jax.random.PRNGKey(2), jnp.zeros(32)
    ref = build(None).sample(key, t0)
    got = build(Stream(resident=10, window=2)).sample(key, t0)
    _assert_bitwise(ref, got)


def test_uniform_preset_bitwise_matches_probs_none():
    """The 'uniform' preset and probs=None spell the SAME f32 values —
    which spelling built the scheme never perturbs the math."""
    sizes = np.full((12,), 24, np.int64)
    preset = resolve_shard_probs("uniform", sizes)
    from repro.core.sampler import ShardScheme
    none_path = ShardScheme(sizes=tuple(sizes), probs=None).probs_array()
    np.testing.assert_array_equal(np.asarray(preset, np.float32),
                                  none_path)
    data, bank = _problem(jax.random.PRNGKey(3))
    key, t0 = jax.random.PRNGKey(4), jnp.zeros(3)
    a = _facade(data, bank, "vmap").sample(key, t0)
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4, surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=6, local_steps=3, n_chains=4,
                              reassign="permutation", thin=3),
        execution=api.Execution(executor="vmap"),
        shard_probs="uniform", federation=_FED)
    _assert_bitwise(a, f.sample(key, t0))


# ---------------------------------------------------------------------------
# in-scan lowering with streaming: one scan, one pallas_call, no pad
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _all_eqns(sub)


def _subjaxprs(v):
    if hasattr(v, "jaxpr"):           # ClosedJaxpr
        return [v.jaxpr]
    if hasattr(v, "eqns"):            # raw Jaxpr
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def test_streamed_rounds_lower_into_one_scan():
    """Streaming + delayed/partial schedule + top-k compression, packed
    executor: the window program is still ONE rounds-scan, exactly one
    pallas_call, and no pad primitive in any scan body — the global->
    resident sid remap lowers as a compare-and-sum rank, never as an
    inner searchsorted scan."""
    # num_rounds != local_updates, so the length filter below uniquely
    # identifies the rounds scan (the local-steps scan has length 4)
    S, K, num_rounds = 8, 4, 6
    data, bank = _problem(jax.random.PRNGKey(2), S=S, n=16)
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=4, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=6, bank=bank,
                          use_kernel=True)
    fed = Federation(schedule=CommSchedule(delay=2, participation=0.5),
                     compression=Compression(kind="topk", frac=0.1))
    layout = eng._layout_for(jnp.zeros(3))
    execute = eng._executor(num_rounds=num_rounds, n_chains=4,
                            reassign="permutation", collect=True,
                            collect_every=2, layout=layout,
                            federation=fed, stream=K)
    chains = jnp.zeros((4, 3))
    sids0 = jnp.zeros((4,), jnp.int32)
    ref0 = jnp.zeros((4, 3), jnp.float32)
    ids = jnp.arange(K, dtype=jnp.int32)
    data_k = jax.tree.map(lambda l: l[:K], data)
    bank_k = SurrogateBank(jax.tree.map(lambda m: m[:K], bank.means),
                           jax.tree.map(lambda p: p[:K], bank.precs),
                           bank.global_, bank.kind)
    sp = (jnp.full((K,), 16, jnp.int32), jnp.full((K,), 16.0, jnp.float32),
          jnp.full((K,), 1.0 / S, jnp.float32))
    jaxpr = jax.make_jaxpr(execute)(
        jax.random.PRNGKey(0), chains, data_k, bank_k,
        jnp.asarray(0, jnp.int32), (sids0, (ref0, ref0)), None, ids, sp)

    eqns = list(_all_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if "pallas" in e.primitive.name]
    assert len(pallas) == 1, [e.primitive.name for e in pallas]
    round_scans = [e for e in eqns if e.primitive.name == "scan"
                   and e.params["length"] == num_rounds]
    assert len(round_scans) == 1, "rounds loop not a single scan"
    for s in (e for e in eqns if e.primitive.name == "scan"):
        body = [e.primitive.name
                for e in _all_eqns(s.params["jaxpr"].jaxpr)]
        assert "pad" not in body, "pad op inside a scan body"
        assert body.count("pallas_call") <= 1


# ---------------------------------------------------------------------------
# error contracts
# ---------------------------------------------------------------------------

def test_unknown_preset_has_did_you_mean_hint():
    sizes = np.full((4,), 10)
    with pytest.raises(KeyError,
                       match=r"did you mean 'size-proportional'\?"):
        resolve_shard_probs("size-proportionl", sizes)
    with pytest.raises(KeyError, match="available"):
        resolve_shard_probs("not-a-preset", sizes)
    assert set(shard_prob_preset_names()) >= {"uniform",
                                              "size-proportional",
                                              "sqrt-size"}


def _engine(S=8):
    data, bank = _problem(jax.random.PRNGKey(1), S=S, n=16)
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=3, prior_precision=1.0)
    return MeshChainEngine(log_lik, cfg, data, minibatch=6, bank=bank)


def test_streamed_refusals_are_actionable(tmp_path):
    eng = _engine()
    key, t0 = jax.random.PRNGKey(0), jnp.zeros(3)

    def run(**kw):
        base = dict(n_chains=2, stream=Stream(resident=4),
                    reassign="permutation")
        return eng.run(key, t0, 2, **{**base, **kw})

    with pytest.raises(NotImplementedError, match="permutation"):
        run(reassign="categorical")
    with pytest.raises(ValueError, match="lower resident"):
        run(stream=Stream(resident=64))
    with pytest.raises(NotImplementedError, match="refresh_every"):
        run(refresh_every=1)
    with pytest.raises(NotImplementedError, match="snapshot"):
        run(snapshot_every=1, snapshot_path=str(tmp_path))
    from repro.core.health import Recovery
    with pytest.raises(NotImplementedError, match="recovery"):
        run(recovery=Recovery())


def test_facade_refuses_client_source_misuse():
    src = SyntheticClientSource(jax.random.PRNGKey(5), num_clients=6,
                                shard_size=8, seq_len=8, vocab_size=32)
    post = api.Posterior(lambda t, b: jnp.sum(t))
    with pytest.raises(ValueError, match="PartitionedSource"):
        api.FSGLD(post, src, minibatch=4, method="dsgld",
                  surrogate=api.SurrogateSpec(kind="none"),
                  federation=Federation(
                      partition=__import__("repro.fed", fromlist=["x"])
                      .PartitionSpec(num_shards=3)))
    with pytest.raises(ValueError, match="carries its own sizes"):
        api.FSGLD(post, src, minibatch=4, method="dsgld",
                  surrogate=api.SurrogateSpec(kind="none"),
                  sizes=(8,) * 6)
    with pytest.raises(ValueError, match="prefit bank"):
        api.FSGLD(post, src, minibatch=4,
                  surrogate=api.SurrogateSpec(kind="diag")).fit(
            jax.random.PRNGKey(0), jnp.zeros(32))


# ---------------------------------------------------------------------------
# cross-silo host reductions == flat reductions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("silo", [1, 7, 64, 10_000])
def test_hierarchy_matches_flat_reductions(silo):
    rng = np.random.default_rng(0)
    x = rng.uniform(0.1, 2.0, size=1000)
    w = rng.uniform(0.1, 1.0, size=1000)
    assert np.isclose(hierarchical_sum(x, silo), float(np.sum(x)),
                      rtol=1e-12)
    assert np.isclose(hierarchical_mean(x, w, silo),
                      float(np.average(x, weights=w)), rtol=1e-10)
    p = normalize_hierarchical(x, silo)
    assert p.dtype == np.float32
    np.testing.assert_allclose(
        p, (x / np.sum(x)).astype(np.float32), rtol=1e-6)
    assert abs(hierarchical_sum(p, silo) - 1.0) < 1e-6


def test_hierarchy_rejects_degenerate_inputs():
    with pytest.raises(ValueError, match="zero"):
        hierarchical_mean([1.0, 2.0], [0.0, 0.0])
    with pytest.raises(ValueError, match="total"):
        normalize_hierarchical(np.zeros(4))
    with pytest.raises(ValueError, match="silo"):
        list(__import__("repro.fed.hierarchy",
                        fromlist=["silo_slices"]).silo_slices(10, 0))
