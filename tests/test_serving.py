"""Bayesian ensemble serving (repro/serve) + draw banks (repro/checkpoint).

Contracts under test:

  * K=1 ensemble serving is BITWISE identical to the plain
    prefill+decode loop — same tokens, same per-step logits (the
    monotone-shift argument in repro/serve/ensemble.py, pinned);
  * predictive_stats analytic facts: identical draws -> MI == 0 and
    var == 0; K-fold aggregation in log space matches a direct fp32
    computation;
  * draw banks: versioned DrawMeta round-trip, freshest-K selection,
    atomic completeness (a half-written draw is invisible), arch /
    fingerprint mismatch REFUSED with a ValueError (never a shape
    error), legacy single-checkpoint fallback;
  * hot-swap: a server polling a bank picks up new draws between
    requests, and refresh() is a no-op when nothing changed;
  * the facade: api.Serving validation + FSGLD.serve / load_bank.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, checkpoint
from repro.configs import get_smoke_config
from repro.models import (broadcast_cache, decode_step, ensemble_decode_step,
                          init_params, prefill_with_cache)
from repro.serve import EnsembleServer, ensemble_prefill, predictive_stats

ARCH = "h2o-danube-1.8b"  # smallest smoke config


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0))


def _prompt(cfg, B=2, S=4):
    return jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)


# ---------------------------------------------------------------------------
# K=1 bitwise parity with the single-draw path
# ---------------------------------------------------------------------------

def test_k1_serving_bitwise_matches_legacy_loop(cfg, params):
    B, S, G = 2, 4, 4
    prompt = _prompt(cfg, B, S)
    total = S + G

    # legacy path: plain prefill + decode_step greedy loop
    logits, cache = prefill_with_cache(params, cfg, prompt, total)
    legacy_tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
    legacy_logits = []
    tok = legacy_tokens[0][:, None]
    for t in range(S, total - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = decode_step(params, cfg, cache, tok, pos)
        legacy_logits.append(logits)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        legacy_tokens.append(tok[:, 0])

    # ensemble path with K=1
    draws = jax.tree.map(lambda l: l[None], params)
    logits0, caches = ensemble_prefill(draws, cfg, prompt, total)
    stats = [predictive_stats(logits0[None])]
    tok = stats[0].token[:, None]
    for t in range(S, total - 1):
        pos = jnp.full((B,), t, jnp.int32)
        lk, caches = ensemble_decode_step(draws, cfg, caches, tok, pos)
        np.testing.assert_array_equal(  # per-step logits, bitwise
            np.asarray(lk[0]), np.asarray(legacy_logits[t - S]))
        stats.append(predictive_stats(lk))
        tok = stats[-1].token[:, None]

    for s, ref in zip(stats, legacy_tokens):
        np.testing.assert_array_equal(np.asarray(s.token),
                                      np.asarray(ref))


def test_k1_server_matches_legacy_loop_end_to_end(cfg, params):
    B, S, G = 2, 4, 4
    prompt = _prompt(cfg, B, S)
    total = S + G
    logits, cache = prefill_with_cache(params, cfg, prompt, total)
    want = [jnp.argmax(logits, -1).astype(jnp.int32)]
    tok = want[0][:, None]
    for t in range(S, total - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = decode_step(params, cfg, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        want.append(tok[:, 0])
    srv = EnsembleServer(cfg, draws=jax.tree.map(lambda l: l[None], params))
    res = srv.generate(prompt, gen=G)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.stack([np.asarray(w) for w in want], 1))
    # single draw: zero epistemic uncertainty, exactly
    assert np.all(np.asarray(res.mutual_info) == 0.0)
    assert np.all(np.asarray(res.token_var) == 0.0)


# ---------------------------------------------------------------------------
# predictive_stats analytic facts
# ---------------------------------------------------------------------------

def test_identical_draws_have_zero_epistemic_uncertainty():
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, 3, 17))
    stacked = jnp.concatenate([logits] * 4, 0)  # 4 identical draws
    s = predictive_stats(stacked)
    np.testing.assert_allclose(np.asarray(s.mutual_info), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s.token_var), 0.0, atol=1e-12)
    # and the aggregate equals the single draw's
    s1 = predictive_stats(logits)
    np.testing.assert_array_equal(np.asarray(s.token), np.asarray(s1.token))
    np.testing.assert_allclose(np.asarray(s.entropy),
                               np.asarray(s1.entropy), rtol=1e-6)


def test_predictive_stats_matches_direct_fp32_mean():
    K, B, V = 5, 2, 11
    logits = jax.random.normal(jax.random.PRNGKey(1), (K, B, V)) * 2
    s = predictive_stats(logits)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    mean_probs = probs.mean(0)
    np.testing.assert_array_equal(
        np.asarray(s.token), np.asarray(jnp.argmax(mean_probs, -1)))
    want_h = -jnp.sum(mean_probs * jnp.log(mean_probs), -1)
    np.testing.assert_allclose(np.asarray(s.entropy), np.asarray(want_h),
                               rtol=1e-5)
    assert np.all(np.asarray(s.mutual_info) > -1e-6)  # BALD is >= 0


def test_disagreeing_draws_have_positive_mutual_info():
    # draw 0 is certain of class 0, draw 1 certain of class 1
    logits = jnp.stack([jnp.array([[10.0, -10.0, 0.0]]),
                        jnp.array([[-10.0, 10.0, 0.0]])])
    s = predictive_stats(logits)
    assert float(s.mutual_info[0]) > 0.5  # ~log 2 of pure disagreement
    assert float(s.token_var[0]) > 0.2


# ---------------------------------------------------------------------------
# draw banks
# ---------------------------------------------------------------------------

def _meta(cfg, r=0):
    return checkpoint.DrawMeta(method="fsgld", round=r,
                               scenario="identity", seed=0,
                               dtype="float32", arch=cfg.name)


def test_draw_bank_roundtrip_with_meta(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    for r in range(3):
        tree = jax.tree.map(lambda l, rr=r: l + rr, params)
        checkpoint.save_draw(bank, tree, _meta(cfg, r), step=r)
    assert len(checkpoint.list_draws(bank)) == 3
    stacked, metas = checkpoint.load_bank(bank, params, k=2)
    assert [m.round for m in metas] == [1, 2]  # freshest k, oldest first
    leaf0 = jax.tree.leaves(params)[0]
    got = jax.tree.leaves(stacked)[0]
    np.testing.assert_array_equal(np.asarray(got[0]),
                                  np.asarray(leaf0 + 1))
    np.testing.assert_array_equal(np.asarray(got[1]),
                                  np.asarray(leaf0 + 2))


def test_draw_bank_refuses_arch_mismatch(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg))
    with pytest.raises(ValueError, match="refused"):
        checkpoint.load_bank(bank, params, expect_arch="other-arch")


def test_draw_bank_refuses_fingerprint_mismatch(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg))
    other = init_params(get_smoke_config("gemma-7b"), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="refused|names"):
        checkpoint.load_bank(bank, other)


def test_draw_bank_asks_too_many_draws(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg))
    with pytest.raises(ValueError, match="requested"):
        checkpoint.load_bank(bank, params, k=5)


def test_half_written_draw_is_invisible(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg))
    # simulate a crashed writer: a draw dir without a manifest
    os.makedirs(os.path.join(bank, "draw-000001"))
    assert len(checkpoint.list_draws(bank)) == 1
    stacked, metas = checkpoint.load_bank(bank, params)
    assert jax.tree.leaves(stacked)[0].shape[0] == 1


def test_legacy_checkpoint_reads_as_one_draw_bank(tmp_path, cfg, params):
    path = str(tmp_path / "ck")
    checkpoint.save(path, params, step=7, extra={"method": "fsgld"})
    stacked, metas = checkpoint.load_bank(path, params)
    assert jax.tree.leaves(stacked)[0].shape[0] == 1
    assert metas == [None] or metas[0] is not None  # meta optional
    srv = EnsembleServer(cfg, bank=path)
    assert srv.n_draws == 1
    assert srv.refresh() is False  # nothing new to pick up


def test_checkpoint_v2_meta_roundtrip(tmp_path, cfg, params):
    path = str(tmp_path / "ck")
    checkpoint.save(path, params, step=3, meta=_meta(cfg, r=3))
    meta = checkpoint.read_meta(path)
    assert meta.arch == cfg.name and meta.round == 3
    assert meta.config_hash == checkpoint.tree_fingerprint(params)
    tree, step, extra = checkpoint.restore(path, params)
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_name_mismatch_is_value_error(tmp_path, cfg, params):
    path = str(tmp_path / "ck")
    checkpoint.save(path, params, step=0)
    other = init_params(get_smoke_config("rwkv6-7b"), jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        checkpoint.restore(path, other)


# ---------------------------------------------------------------------------
# hot-swap
# ---------------------------------------------------------------------------

def test_server_hot_swaps_fresh_draws(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg, 0))
    srv = EnsembleServer(cfg, bank=bank)
    assert srv.n_draws == 1
    assert srv.refresh() is False  # nothing new
    checkpoint.save_draw(bank, jax.tree.map(lambda l: l + 1, params),
                         _meta(cfg, 1))
    assert srv.refresh() is True  # picked up without restart
    assert srv.n_draws == 2
    assert [m.round for m in srv.metas] == [0, 1]
    assert srv.refresh() is False


def test_server_bank_want_k_serves_freshest(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg, 0))
    # n_draws=2 wanted but only 1 available: serve what exists
    srv = EnsembleServer(cfg, bank=bank, n_draws=2)
    assert srv.n_draws == 1
    for r in (1, 2):
        checkpoint.save_draw(bank, jax.tree.map(lambda l, rr=r: l + rr,
                                                params), _meta(cfg, r))
    assert srv.refresh() is True
    assert srv.n_draws == 2
    assert [m.round for m in srv.metas] == [1, 2]  # freshest two


def test_server_refuses_mismatched_bank(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    checkpoint.save_draw(bank, params, _meta(cfg))
    other_cfg = get_smoke_config("gemma-7b")
    with pytest.raises(ValueError, match="refused|names"):
        EnsembleServer(other_cfg, bank=bank)


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_serving_spec_validation():
    with pytest.raises(ValueError, match="draws"):
        api.Serving(draws=0)
    with pytest.raises(ValueError, match="collect"):
        api.Serving(collect=("mean", "nope"))
    s = api.Serving(draws=2, collect=("entropy",))
    assert s.draws == 2


def test_fsgld_serve_and_load_bank(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    for r in range(2):
        checkpoint.save_draw(bank, jax.tree.map(lambda l, rr=r: l + rr,
                                                params), _meta(cfg, r))
    spec = api.Serving(draws=2, arch=ARCH, batch=2, prompt_len=4, gen=3)
    srv = api.FSGLD.serve(spec, bank=bank)
    assert srv.n_draws == 2
    res = srv.generate(gen=3, batch=2, prompt_len=4)
    assert res.tokens.shape == (2, 3)
    assert np.all(np.isfinite(np.asarray(res.entropy)))
    assert np.all(np.isfinite(np.asarray(res.mean_logprob)))

    stacked, metas = api.FSGLD.load_bank(bank, params, k=1,
                                         expect_arch=cfg.name)
    assert jax.tree.leaves(stacked)[0].shape[0] == 1
    assert metas[0].round == 1

    srv2 = api.FSGLD.serve(spec, draws=stacked)
    assert srv2.n_draws == 1
