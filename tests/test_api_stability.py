"""API-stability lane: the public ``repro.api`` surface is snapshot-tested.

Two gates:

  * the exported symbols and their call signatures must match the
    snapshot below — a mismatch means the public API changed, which is
    fine ONLY as a deliberate act: update the snapshot AND the README
    migration table in the same commit;
  * the README "## API" quickstart block must actually run (doctest-style
    extraction — the documented first contact with the repo can never go
    stale).
"""
import inspect
import os
import re

import pytest

from repro import api

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _params(obj):
    """Ordered (name, has_default) tuples of a callable's signature,
    self excluded."""
    sig = inspect.signature(obj)
    return tuple((n, p.default is not inspect.Parameter.empty)
                 for n, p in sig.parameters.items() if n != "self")


# The snapshot. Field ORDER is part of the contract (positional calls);
# (name, has_default) pairs catch silently-added required arguments.
EXPECTED_ALL = ("Posterior", "SurrogateSpec", "Schedule", "Execution",
                "Federation", "Stream", "SyntheticClientSource",
                "Recovery", "RunHealth", "Serving", "Telemetry",
                "MetricsFrame", "FSGLD", "fit_bank_local_sgld",
                "get_scenario")

EXPECTED_SIGNATURES = {
    "Posterior": (("log_lik", False), ("prior_precision", True),
                  ("temperature", True)),
    "SurrogateSpec": (("kind", True), ("bank", True), ("fit", True),
                      ("refresh_every", True), ("fit_steps", True),
                      ("fit_minibatch", True), ("fit_step_size", True)),
    "Schedule": (("rounds", False), ("local_steps", True),
                 ("n_chains", True), ("reassign", True), ("thin", True)),
    "Execution": (("mesh", True), ("executor", True), ("dtype", True),
                  ("collect", True), ("recovery", True),
                  ("snapshot_every", True), ("snapshot_path", True),
                  ("resume", True), ("stream", True),
                  ("telemetry", True)),
    "Telemetry": (("probe", True), ("log_every", True)),
    "MetricsFrame": (("metrics", False),),
    "Federation": (("partition", True), ("schedule", True),
                   ("compression", True)),
    "Stream": (("resident", False), ("window", True), ("prefetch", True)),
    "SyntheticClientSource": (("key", False), ("num_clients", False),
                              ("shard_size", False), ("seq_len", False),
                              ("vocab_size", False), ("alpha", True)),
    "Recovery": (("policy", True), ("divergence_threshold", True),
                 ("check_momentum", True), ("window", True),
                 ("quantile", True)),
    "FSGLD": (("posterior", False), ("data", False), ("minibatch", False),
              ("step_size", True), ("method", True), ("kernel", True),
              ("alpha", True), ("friction", True), ("surrogate", True),
              ("schedule", True), ("execution", True),
              ("shard_probs", True), ("sizes", True),
              ("federation", True)),
    "Serving": (("draws", True), ("arch", True), ("smoke", True),
                ("batch", True), ("prompt_len", True), ("gen", True),
                ("mesh", True), ("collect", True)),
    "FSGLD.sample": (("key", False), ("theta0", False), ("rounds", True),
                     ("n_chains", True), ("federation", True),
                     ("stream", True), ("telemetry", True)),
    "FSGLD.fit": (("key", False), ("theta0", False)),
    "FSGLD.serve": (("spec", False), ("bank", True), ("draws", True),
                    ("seed", True)),
    "FSGLD.load_bank": (("path", False), ("like", False), ("k", True),
                        ("expect_arch", True)),
    "get_scenario": (("name_or_spec", False),),
    "fit_bank_local_sgld": (("log_lik_fn", False), ("shard_data", False),
                            ("theta0", False), ("key", False),
                            ("fit_steps", False), ("minibatch", False),
                            ("step_size", False), ("kind", True),
                            ("lam_floor", True)),
}


def test_public_symbols_snapshot():
    assert tuple(api.__all__) == EXPECTED_ALL, (
        "repro.api.__all__ changed — update the snapshot and the README "
        f"migration table deliberately: {api.__all__}")
    for name in EXPECTED_ALL:
        assert hasattr(api, name), name


@pytest.mark.parametrize("name", sorted(EXPECTED_SIGNATURES))
def test_signature_snapshot(name):
    obj = api
    for part in name.split("."):
        obj = getattr(obj, part)
    got = _params(obj)
    assert got == EXPECTED_SIGNATURES[name], (
        f"signature of repro.api.{name} changed — update the snapshot "
        f"and the README migration table deliberately:\n got {got}\n "
        f"want {EXPECTED_SIGNATURES[name]}")


# ---------------------------------------------------------------------------
# README quickstart doctest
# ---------------------------------------------------------------------------

def _readme_block(section: str) -> str:
    text = open(os.path.join(REPO, "README.md")).read()
    m = re.search(rf"^## {section}$(.*?)^## ", text, re.M | re.S)
    assert m, f"README has no '## {section}' section"
    code = re.search(r"```python\n(.*?)```", m.group(1), re.S)
    assert code, f"README '## {section}' has no python quickstart block"
    return code.group(1)


def test_readme_quickstart_runs():
    """Exec the README quickstart verbatim: its asserts are the test."""
    src = _readme_block("API")
    assert "api.FSGLD(" in src and "sample(" in src
    exec(compile(src, "README.md:<api-quickstart>", "exec"), {})


def test_readme_fault_tolerance_quickstart_runs(tmp_path, monkeypatch):
    """Exec the README '## Fault tolerance' quickstart verbatim:
    recovery policy -> (trace, RunHealth), snapshots land, diagnostics
    take the health mask."""
    src = _readme_block("Fault tolerance")
    assert "Recovery(" in src and "snapshot_every" in src
    src = src.replace("/tmp/snaps", str(tmp_path / "snaps"))
    exec(compile(src, "README.md:<fault-tolerance-quickstart>", "exec"), {})


def test_readme_serving_quickstart_runs():
    """Exec the README '## Serving' quickstart verbatim: draw bank with
    provenance envelopes -> K-draw ensemble server -> uncertainty-bearing
    generate -> hot-swap no-op. Its asserts are the test."""
    src = _readme_block("Serving")
    assert "FSGLD.serve(" in src and "save_draw(" in src
    exec(compile(src, "README.md:<serving-quickstart>", "exec"), {})


def test_readme_rival_samplers_runs():
    """Exec the README '## Rival samplers' quickstart verbatim: the
    method axis runs FA-LD through the same facade and matches the
    pure-JAX oracle bitwise. Its asserts are the test."""
    src = _readme_block("Rival samplers")
    assert "method=" in src and "fald" in src
    exec(compile(src, "README.md:<rival-samplers-quickstart>", "exec"), {})


def test_readme_client_scale_quickstart_runs():
    """Exec the README '## Client scale-out' quickstart verbatim: lazy
    synthetic clients + Stream(resident=K) sample bitwise-identically to
    the resident path. Its asserts are the test."""
    src = _readme_block("Client scale-out")
    assert "Stream(" in src and "SyntheticClientSource(" in src
    exec(compile(src, "README.md:<client-scale-quickstart>", "exec"), {})


def test_readme_observability_quickstart_runs(tmp_path):
    """Exec the README '## Observability' quickstart verbatim: in-scan
    telemetry -> MetricsFrame + exporters, telemetry-off bitwise
    identity. Its asserts are the test."""
    src = _readme_block("Observability")
    assert "Telemetry(" in src and "write_metrics_jsonl(" in src
    src = src.replace("/tmp/obs-demo", str(tmp_path / "obs-demo"))
    exec(compile(src, "README.md:<observability-quickstart>", "exec"), {})
