"""SPMD behaviour tests that need >1 device: run in subprocesses with
forced host-device counts (the main test process must keep the single real
CPU device — see dryrun.py's XLA_FLAGS note)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_large_model_round_runs_on_chain_engine_multidevice():
    """The large-model federated round runs ON THE CHAIN ENGINE (the
    private ppermute ring in launch/steps.py is retired): 4 transformer
    chains on a 4-way data axis go through repro.api.FSGLD, reassignment
    is the engine's collision-free SPMD permutation, the sampler keeps
    sampling (finite chains) and the chains diverge (each visited its own
    client sequence)."""
    script = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import api
from repro.configs import get_smoke_config
from repro.data import token_shards
from repro.models import init_params, log_lik_fn
mesh = jax.make_mesh((4, 1), ("data", "model"))
cfg = get_smoke_config("qwen3-1.7b")
params = init_params(cfg, jax.random.PRNGKey(0))
shards = token_shards(jax.random.PRNGKey(1), num_shards=4, shard_size=16,
                      seq_len=16, vocab_size=cfg.vocab_size)
f = api.FSGLD(
    api.Posterior(lambda p, b: log_lik_fn(p, cfg, b), prior_precision=1.0),
    shards, minibatch=4, step_size=1e-4, method="dsgld",
    schedule=api.Schedule(rounds=2, local_steps=2, n_chains=4,
                          reassign="permutation"),
    execution=api.Execution(mesh=mesh, collect=False))
finals = f.sample(jax.random.PRNGKey(7), params)
leaves = jax.tree.leaves(finals)
assert all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32))))
           for l in leaves)
assert leaves[0].shape[0] == 4
# chains visited different client sequences: their states diverged
emb = finals["embed"].reshape(4, -1)
d01 = float(jnp.abs(emb[0] - emb[1]).max())
assert d01 > 0.0, "chains did not diverge"
print("ENGINE_ROUND_OK")
"""
    r = _run(script, devices=4)
    assert "ENGINE_ROUND_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


@pytest.mark.slow
def test_dryrun_single_combo_subprocess():
    """End-to-end dry-run smoke: one fast combo compiles on the full
    512-device production mesh in a subprocess."""
    script = r"""
import repro.launch.dryrun as d
rc = d.main(["--arch", "h2o-danube-1.8b", "--shape", "long_500k"])
assert rc == 0
print("DRYRUN_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "DRYRUN_OK" in r.stdout, (r.stdout, r.stderr[-2000:])
