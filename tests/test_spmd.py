"""SPMD behaviour tests that need >1 device: run in subprocesses with
forced host-device counts (the main test process must keep the single real
CPU device — see dryrun.py's XLA_FLAGS note)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_federated_round_ppermute_rotates_chains():
    """4 chains on a 4-way data axis: after one round every chain state has
    moved to the next device (the paper's Reassign_chain as one collective
    permute) and the sampler keeps sampling (finite lls)."""
    script = r"""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config, SamplerConfig
from repro.launch.steps import init_surrogate_state, make_federated_round
from repro.models import init_params
mesh = jax.make_mesh((4, 1), ("data", "model"))
cfg = get_smoke_config("qwen3-1.7b")
sampler = SamplerConfig(method="fsgld", step_size=1e-6)
C, T = 4, 2
params = init_params(cfg, jax.random.PRNGKey(0))
chains = jax.tree.map(
    lambda t: jnp.stack([t + i for i in range(C)]), params)
surr = jax.vmap(lambda i: init_surrogate_state(params, lam=1e-4))(
    jnp.arange(C))
B, S = 2, 16
batches = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (C, T, B, S), 0,
                                 cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (C, T, B, S), 0,
                                 cfg.vocab_size)}
seeds = jnp.arange(C, dtype=jnp.uint32)[:, None]
rnd = make_federated_round(cfg, sampler, mesh, scale=10.0, n_chains=C)
with mesh:
    new_chains, lls = jax.jit(rnd)(chains, surr, batches, seeds)
assert jnp.all(jnp.isfinite(lls)), lls
# marker params (embed offsets) rotated by one position around the ring
emb_old = chains["embed"][:, 0, 0]
emb_new = new_chains["embed"][:, 0, 0]
# chain i moved to position (i+1) % C; step perturbation is ~1e-6-scale
err = jnp.abs(emb_new - jnp.roll(emb_old, 1)).max()
assert err < 1e-2, (emb_old, emb_new)
print("PPERMUTE_OK")
"""
    r = _run(script, devices=4)
    assert "PPERMUTE_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


@pytest.mark.slow
def test_dryrun_single_combo_subprocess():
    """End-to-end dry-run smoke: one fast combo compiles on the full
    512-device production mesh in a subprocess."""
    script = r"""
import repro.launch.dryrun as d
rc = d.main(["--arch", "h2o-danube-1.8b", "--shape", "long_500k"])
assert rc == 0
print("DRYRUN_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "DRYRUN_OK" in r.stdout, (r.stdout, r.stderr[-2000:])
