"""Cache-populating prefill: one forward pass fills the decode cache;
subsequent decode steps must match the full-sequence forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import (decode_step, encoder_forward, forward,
                          init_params, prefill_with_cache)
from repro.models.model import ACT_DTYPE

ARCHS = ["qwen3-1.7b", "h2o-danube-1.8b", "whisper-large-v3",
         "recurrentgemma-2b", "rwkv6-7b", "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S_p, S_tot = 2, 20, 32
    tokens = jax.random.randint(key, (B, S_tot), 0, cfg.vocab_size)
    enc = None
    kw = {}
    if cfg.family == "vlm":
        enc = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model),
                                ACT_DTYPE)
        kw = {"enc_embeds": enc}
    elif cfg.family == "audio":
        enc_in = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
        enc = encoder_forward(params, cfg, enc_in)
        kw = {"enc_embeds": enc_in}
    hidden, _ = forward(params, cfg, tokens, **kw)
    full = jnp.einsum("bsd,dv->bsv", hidden,
                      params["head"].astype(ACT_DTYPE),
                      preferred_element_type=jnp.float32)

    logits_p, cache = prefill_with_cache(params, cfg, tokens[:, :S_p],
                                         S_tot, **kw)
    errs = [float(jnp.max(jnp.abs(logits_p - full[:, S_p - 1])))]
    if enc is not None:
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p,
                                                   enc_out=enc))
    else:
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    for t in range(S_p, S_tot):
        lg, cache = step(cache, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    rel = max(errs) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 0.05, (arch, rel)
