"""Fault-tolerant sampling runtime (PR 7): chaos-injection suite.

Deterministic fault injectors (``repro.testing.chaos``) drive the
contracts under test:

  * health tracking OFF-path neutrality: enabling a ``Recovery`` policy
    on a fault-free run is BITWISE identical to the plain run (the
    health probe key is salted off the sampling stream);
  * quarantine isolation: a NaN injected into ONE chain leaves every
    other chain's trace bitwise identical to the fault-free run, and
    the faulty chain's health word records the first bad round;
  * respawn determinism: re-seeding from a healthy donor is a pure
    function of the run key — two runs agree bitwise;
  * the jaxpr acceptance gate HOLDS with health + chaos lowered into
    the scan: still one pallas_call, no `pad` primitive in any scan
    body (fault tolerance costs zero extra launches);
  * corrupted wire payloads under a compressed federation scenario are
    contained to the chain whose payload was corrupted;
  * storage chaos: ``corrupt_draw``/``truncate_file``/``flaky_io``
    against the draw bank — ``load_bank`` degrades (serve K-j healthy
    draws, warn) or refuses loudly (all corrupt), and a live
    ``EnsembleServer`` keeps its previous ensemble when a refresh
    fails.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_smoke_config
from repro.configs.base import SamplerConfig
from repro.core import analytic_gaussian_likelihood_surrogate, make_bank
from repro.core.diagnostics import ess, rhat, summarize
from repro.core.engine import MeshChainEngine
from repro.core.health import Recovery, RunHealth
from repro.fed import CommSchedule, Compression, Federation
from repro.models import init_params
from repro.serve import EnsembleServer
from repro.testing import ChaosSpec, corrupt_draw, flaky_io

S, N, D = 4, 12, 3


def log_lik(theta, b):
    return -0.5 * jnp.sum((b["y"] - b["x"] @ theta["w"]) ** 2)


@pytest.fixture(scope="module")
def problem():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (S, N, D))
    w = jax.random.normal(ks[1], (D,))
    y = x @ w + 0.1 * jax.random.normal(ks[2], (S, N))
    return {"x": x, "y": y}


def _engine(problem):
    cfg = SamplerConfig(method="dsgld", step_size=1e-3, num_shards=S,
                        local_updates=2, prior_precision=1.0)
    return MeshChainEngine(log_lik, cfg, problem, minibatch=4)


THETA0 = {"w": jnp.zeros(D)}
KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# health tracking is free: fault-free runs are bitwise unchanged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["quarantine", "respawn"])
def test_health_no_fault_is_bitwise_identical(problem, policy):
    eng = _engine(problem)
    base = eng.run(KEY, THETA0, 5, n_chains=4, reassign="permutation")
    out, h = eng.run(KEY, THETA0, 5, n_chains=4, reassign="permutation",
                     recovery=Recovery(policy=policy,
                                       divergence_threshold=50.0))
    assert isinstance(h, RunHealth)
    assert h.n_healthy == h.n_chains == 4
    assert np.all(np.asarray(h.healthy))
    np.testing.assert_array_equal(np.asarray(base["w"]),
                                  np.asarray(out["w"]))


# ---------------------------------------------------------------------------
# quarantine: the faulty chain is contained, neighbours bitwise intact
# ---------------------------------------------------------------------------

def test_quarantine_isolates_nan_chain_bitwise(problem):
    eng = _engine(problem)
    base = eng.run(KEY, THETA0, 5, n_chains=4, reassign="permutation")
    chaos = ChaosSpec(nan_chains=(2,), nan_rounds=(1,))
    out, h = eng.run(KEY, THETA0, 5, n_chains=4, reassign="permutation",
                     recovery=Recovery(policy="quarantine"), chaos=chaos)
    # health word records 1 + first bad round; everyone else clean
    np.testing.assert_array_equal(np.asarray(h.word), [0, 0, 2, 0])
    assert h.n_healthy == 3
    others = [0, 1, 3]
    np.testing.assert_array_equal(np.asarray(base["w"])[others],
                                  np.asarray(out["w"])[others])
    # the quarantined chain is frozen at its last healthy state — its
    # trace stays finite, the NaN never reaches storage
    assert np.isfinite(np.asarray(out["w"])[2]).all()


def test_respawn_is_deterministic_and_finite(problem):
    eng = _engine(problem)
    chaos = ChaosSpec(nan_chains=(2,), nan_rounds=(1,))
    runs = [eng.run(KEY, THETA0, 5, n_chains=4, reassign="permutation",
                    recovery=Recovery(policy="respawn"), chaos=chaos)
            for _ in range(2)]
    (a, ha), (b, hb) = runs
    np.testing.assert_array_equal(np.asarray(ha.word), [0, 0, 1, 0])
    np.testing.assert_array_equal(np.asarray(ha.word), np.asarray(hb.word))
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    assert np.isfinite(np.asarray(a["w"])).all()


def test_quarantine_with_mesh_padding(problem):
    """n_chains=3 on the padded block: the pad row's health word must
    never flag (pad chains are not real) and real-chain containment
    still holds."""
    eng = _engine(problem)
    base = eng.run(KEY, THETA0, 4, n_chains=3, reassign="permutation")
    chaos = ChaosSpec(nan_chains=(1,), nan_rounds=(0,))
    out, h = eng.run(KEY, THETA0, 4, n_chains=3, reassign="permutation",
                     recovery=Recovery(policy="quarantine"), chaos=chaos)
    assert h.n_chains == 3  # real rows only in the result
    np.testing.assert_array_equal(np.asarray(h.word), [0, 1, 0])
    others = [0, 2]
    np.testing.assert_array_equal(np.asarray(base["w"])[others],
                                  np.asarray(out["w"])[others])


# ---------------------------------------------------------------------------
# windowed-quantile divergence detector (PR 8): the log-posterior
# reference is a quantile over the last `window` probes, not a running
# max — tight thresholds become usable, warm-up never false-trips
# ---------------------------------------------------------------------------

def test_detector_warmup_never_false_trips(problem):
    """Until the window holds enough probes for the quantile to be
    finite (ceil((1-q)*W) rounds at the default W=8, q=0.5), the
    reference is -inf and NOTHING can trip — even a threshold far
    inside the probe noise. The same absurd threshold past warm-up
    does trip: the reference went finite and tight."""
    eng = _engine(problem)
    rec = Recovery(policy="quarantine", divergence_threshold=1e-6)
    # 4 rounds: 8-slot window still majority -inf -> median -inf
    _, h4 = eng.run(KEY, THETA0, 4, n_chains=4, reassign="permutation",
                    recovery=rec)
    assert h4.n_healthy == 4, np.asarray(h4.word)
    assert np.all(np.isneginf(np.asarray(h4.lp_ref))), h4.lp_ref
    # 12 rounds: the median is finite and a 1e-6 threshold is far
    # inside the minibatch probe noise -> chains trip
    _, h12 = eng.run(KEY, THETA0, 12, n_chains=4, reassign="permutation",
                     recovery=rec)
    assert h12.n_healthy < 4, np.asarray(h12.word)


def test_detector_window_and_quantile_are_plumbed(problem):
    """window/quantile reach the in-scan detector: quantile=1.0 over a
    short window warms up after ONE probe (the max of a single finite
    probe is finite), so the same tight threshold that was inert during
    the default config's warm-up trips within the first rounds here."""
    eng = _engine(problem)
    _, h = eng.run(KEY, THETA0, 3, n_chains=4, reassign="permutation",
                   recovery=Recovery(policy="quarantine",
                                     divergence_threshold=1e-6,
                                     window=2, quantile=1.0))
    assert h.n_healthy < 4, np.asarray(h.word)
    # sane threshold, same custom window: nothing trips, bitwise clean
    base = eng.run(KEY, THETA0, 3, n_chains=4, reassign="permutation")
    out, h2 = eng.run(KEY, THETA0, 3, n_chains=4, reassign="permutation",
                      recovery=Recovery(policy="quarantine",
                                        divergence_threshold=200.0,
                                        window=2, quantile=1.0))
    assert h2.n_healthy == 4
    np.testing.assert_array_equal(np.asarray(base["w"]),
                                  np.asarray(out["w"]))
    assert np.all(np.isfinite(np.asarray(h2.lp_ref)))


def test_recovery_validates_window_and_quantile():
    with pytest.raises(AssertionError):
        Recovery(window=0)
    with pytest.raises(AssertionError):
        Recovery(quantile=1.5)


# ---------------------------------------------------------------------------
# the jaxpr acceptance gate holds with fault tolerance lowered in
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for e in jaxpr.eqns:
        yield e
        for v in e.params.values():
            for sub in _subjaxprs(v):
                yield from _all_eqns(sub)


def _subjaxprs(v):
    if hasattr(v, "jaxpr"):
        return [v.jaxpr]
    if hasattr(v, "eqns"):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def test_jaxpr_gate_holds_with_health_and_chaos():
    """One pallas_call, no `pad` primitive in any scan body — with a
    recovery policy (detector on), chaos injection, and the packed
    executor all active. Fault tolerance is where()s inside the scanned
    round body, not extra launches."""
    key0 = jax.random.PRNGKey(2)
    mus = jax.random.uniform(key0, (S, D), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key0, 1),
                                            (S, 24, D))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    data, bank = {"x": x}, make_bank(mu_s, prec_s, "diag")
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=3, prior_precision=1.0)
    eng = MeshChainEngine(lambda t, b: -0.5 * jnp.sum((b["x"] - t) ** 2),
                          cfg, data, minibatch=6, bank=bank,
                          use_kernel=True)
    theta0 = jnp.zeros(D)
    layout = eng._layout_for(theta0)
    assert layout is not None
    execute = eng._executor(
        num_rounds=3, n_chains=4, reassign="categorical", collect=True,
        collect_every=1, layout=layout,
        recovery=Recovery(policy="quarantine", divergence_threshold=50.0),
        chaos=ChaosSpec(nan_chains=(1,), nan_rounds=(1,)))
    chains = jnp.zeros((4, D))
    hw0 = (jnp.zeros((4,), jnp.int32), jnp.full((4, 8), -jnp.inf,
                                                jnp.float32))
    jaxpr = jax.make_jaxpr(execute)(
        jax.random.PRNGKey(0), chains, data, bank,
        jnp.asarray(0, jnp.int32), None, hw0)

    eqns = list(_all_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if "pallas" in e.primitive.name]
    assert len(pallas) == 1, [e.primitive.name for e in pallas]
    scans = [e for e in eqns if e.primitive.name == "scan"]
    assert scans, "rounds loop not scanned"
    for s in scans:
        body = [e.primitive.name
                for e in _all_eqns(s.params["jaxpr"].jaxpr)]
        assert "pad" not in body, "pad op inside a scan body"
        assert body.count("pallas_call") <= 1


# ---------------------------------------------------------------------------
# corrupted wire payloads under compression are contained
# ---------------------------------------------------------------------------

def test_payload_corruption_contained_under_compression():
    """A NaN'd compressed payload poisons only the chain whose wire
    delta was corrupted: its server reference (and onward state) goes
    bad, the health check quarantines it, and every other chain's trace
    is bitwise identical to the fault-free scenario run."""
    key0 = jax.random.PRNGKey(0)
    mus = jax.random.uniform(key0, (S, D), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key0, 1),
                                            (S, 40, D))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    data, bank = {"x": x}, make_bank(mu_s, prec_s, "diag")
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=3, prior_precision=1.0)
    eng = MeshChainEngine(lambda t, b: -0.5 * jnp.sum((b["x"] - t) ** 2),
                          cfg, data, minibatch=8, bank=bank)
    fed = Federation(schedule=CommSchedule(delay=2),
                     compression=Compression(kind="topk", frac=0.5,
                                             error_feedback=True))
    base = eng.run(KEY, jnp.zeros(D), 6, n_chains=4, federation=fed)
    chaos = ChaosSpec(payload_nan_chains=(1,), payload_nan_rounds=(2,))
    out, h = eng.run(KEY, jnp.zeros(D), 6, n_chains=4, federation=fed,
                     recovery=Recovery(policy="quarantine"), chaos=chaos)
    word = np.asarray(h.word)
    assert word[1] != 0 and np.all(word[[0, 2, 3]] == 0), word
    others = [0, 2, 3]
    np.testing.assert_array_equal(np.asarray(base)[others],
                                  np.asarray(out)[others])


# ---------------------------------------------------------------------------
# diagnostics refuse poisoned traces, accept the health mask
# ---------------------------------------------------------------------------

def test_diagnostics_refuse_nonfinite_and_accept_mask(problem):
    eng = _engine(problem)
    chaos = ChaosSpec(payload_nan_chains=(), nan_chains=(2,),
                      nan_rounds=(1,))
    out, h = eng.run(KEY, THETA0, 8, n_chains=4, reassign="permutation",
                     recovery=Recovery(policy="quarantine"), chaos=chaos)
    trace = jnp.concatenate([out["w"], out["w"]], axis=1)  # N >= 4
    poisoned = trace.at[2, 0].set(jnp.nan)  # what no-recovery looks like
    for fn in (rhat, ess):
        with pytest.raises(ValueError, match="non-finite"):
            fn(poisoned)
        assert np.all(np.isfinite(np.asarray(
            fn(poisoned, mask=h.healthy))))
    with pytest.raises(ValueError, match="excludes every chain"):
        rhat(trace, mask=np.zeros(4, bool))
    with pytest.raises(ValueError, match="mask shape"):
        ess(trace, mask=np.ones(3, bool))
    s = summarize(trace, mask=h.healthy)
    assert s["n_healthy"] == 3 and s["n_excluded"] == 1


# ---------------------------------------------------------------------------
# storage chaos: draw banks + the ensemble server
# ---------------------------------------------------------------------------

ARCH = "h2o-danube-1.8b"


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.PRNGKey(0))


def _meta(cfg, r=0):
    return checkpoint.DrawMeta(method="fsgld", round=r,
                               scenario="identity", seed=0,
                               dtype="float32", arch=cfg.name)


def _fill_bank(bank, cfg, params, n=3):
    paths = []
    for r in range(n):
        paths.append(checkpoint.save_draw(
            bank, jax.tree.map(lambda l, rr=r: l + rr, params),
            _meta(cfg, r), step=r))
    return paths


@pytest.mark.parametrize("mode", ["truncate", "garbage", "missing"])
def test_load_bank_degrades_around_corrupt_draw(tmp_path, cfg, params,
                                                mode):
    bank = str(tmp_path / "bank")
    paths = _fill_bank(bank, cfg, params, n=3)
    corrupt_draw(paths[1], mode=mode)
    with pytest.warns(UserWarning, match="corrupt"):
        stacked, metas = checkpoint.load_bank(bank, params)
    assert jax.tree.leaves(stacked)[0].shape[0] == 2
    assert [m.round for m in metas] == [0, 2]  # the bad middle draw gone


def test_load_bank_degraded_still_serves_want_k(tmp_path, cfg, params):
    """want_k=2 with the freshest draw corrupt: the bank walks further
    back and still serves 2 healthy draws."""
    bank = str(tmp_path / "bank")
    paths = _fill_bank(bank, cfg, params, n=3)
    corrupt_draw(paths[2], mode="truncate")
    stacked, metas = checkpoint.load_bank(bank, params, k=2)
    assert jax.tree.leaves(stacked)[0].shape[0] == 2
    assert [m.round for m in metas] == [0, 1]


def test_load_bank_all_corrupt_refuses_loudly(tmp_path, cfg, params):
    bank = str(tmp_path / "bank")
    paths = _fill_bank(bank, cfg, params, n=2)
    for p in paths:
        corrupt_draw(p, mode="garbage")
    with pytest.raises(ValueError, match="no servable draws"):
        checkpoint.load_bank(bank, params)


def test_load_bank_missing_dir_and_empty_bank_errors(tmp_path, params):
    with pytest.raises(ValueError, match="does not exist"):
        checkpoint.load_bank(str(tmp_path / "nope"), params)
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(ValueError, match="no complete draw"):
        checkpoint.load_bank(str(empty), params)


def test_server_survives_corrupted_refresh(tmp_path, cfg, params):
    """A live server whose bank refresh hits a wholly-corrupt new draw
    set keeps serving the previous ensemble (warn, not crash)."""
    bank = str(tmp_path / "bank")
    _fill_bank(bank, cfg, params, n=1)
    srv = EnsembleServer(cfg, bank=bank)
    assert srv.n_draws == 1
    before = jax.tree.leaves(srv.draws)[0]
    # every draw (old + new) goes corrupt on disk: refresh can load
    # nothing, but the in-memory ensemble keeps serving
    for p in checkpoint.list_draws(bank):
        corrupt_draw(p, mode="garbage")
    checkpoint.save_draw(bank, params, _meta(cfg, 9), step=9)
    corrupt_draw(checkpoint.list_draws(bank)[-1], mode="truncate")
    with pytest.warns(UserWarning, match="keeping the previous"):
        assert srv.refresh(retries=1, backoff_s=0.0) is False
    assert srv.n_draws == 1
    np.testing.assert_array_equal(np.asarray(before),
                                  np.asarray(jax.tree.leaves(srv.draws)[0]))


def test_server_retries_flaky_reads_with_backoff(tmp_path, cfg, params):
    """Transient read failures (flaky_io raises OSError on the first n
    manifest reads) are retried with backoff and the refresh then
    succeeds. (A flaky ARRAY read instead degrades through the bank's
    corrupt-draw skipping — also survivable, tested above.)"""
    bank = str(tmp_path / "bank")
    _fill_bank(bank, cfg, params, n=1)
    srv = EnsembleServer(cfg, bank=bank)
    checkpoint.save_draw(bank, jax.tree.map(lambda l: l + 5, params),
                         _meta(cfg, 5), step=5)
    with flaky_io(1, match="manifest.json") as calls:
        assert srv.refresh(retries=2, backoff_s=0.0) is True
    assert calls[0] >= 1  # the injector actually fired
    assert srv.n_draws == 2


def test_initial_load_still_fails_hard(tmp_path, cfg, params):
    """Degradation is for live servers only: constructing a server on a
    wholly-corrupt bank must raise (serving garbage is worse than not
    starting)."""
    bank = str(tmp_path / "bank")
    paths = _fill_bank(bank, cfg, params, n=1)
    corrupt_draw(paths[0], mode="garbage")
    with pytest.raises(ValueError):
        EnsembleServer(cfg, bank=bank)


def test_chaos_spec_validation_and_hashability():
    spec = ChaosSpec(nan_chains=[2], nan_rounds=[1])
    assert spec.nan_chains == (2,) and spec.active
    assert hash(spec) == hash(ChaosSpec(nan_chains=(2,), nan_rounds=(1,)))
    assert not ChaosSpec().active
    assert ChaosSpec(payload_nan_chains=(0,),
                     payload_nan_rounds=(0,)).poisons_payload
