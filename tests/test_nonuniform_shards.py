"""Non-uniform shard-selection probabilities f_s and unequal shard sizes:
the paper's estimators are stated for general f_s (Eq. 4) — verify
unbiasedness and FSGLD convergence beyond the uniform case the experiments
use."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import (ShardScheme,
                        analytic_gaussian_likelihood_surrogate,
                        make_bank, make_drift_fn)


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def test_estimator_unbiased_nonuniform_fs():
    key = jax.random.PRNGKey(0)
    S, n, d = 4, 12, 2
    probs = (0.4, 0.3, 0.2, 0.1)
    x = jax.random.normal(key, (S, n, d)) + jnp.arange(S)[:, None, None]
    theta = jnp.array([0.5, -0.5])
    exact = -theta + jnp.sum(x.reshape(-1, d) - theta, axis=0)
    scheme = ShardScheme(sizes=(n,) * S, probs=probs)
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    for method, b in (("dsgld", None), ("fsgld", bank)):
        cfg = SamplerConfig(method=method, num_shards=S,
                            shard_probs=probs, prior_precision=1.0)
        drift = make_drift_fn(log_lik, cfg, scheme, b)
        acc = jnp.zeros(d)
        for s in range(S):
            for i in range(n):
                acc = acc + probs[s] * (1.0 / n) * drift(
                    theta, {"x": x[s, i:i + 1]}, s, 1)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(exact),
                                   rtol=1e-3, atol=1e-3)


def test_fsgld_converges_nonuniform_fs():
    """Chain correctness under skewed availability: rarely-selected shards
    get proportionally larger updates (1/f_s); FSGLD must still hit the
    true posterior."""
    key = jax.random.PRNGKey(1)
    S, n, d = 4, 100, 2
    probs = (0.4, 0.3, 0.2, 0.1)
    mus = jnp.array([[3.0, 0.0], [-3.0, 1.0], [0.0, -3.0], [2.0, 2.0]])
    x = mus[:, None, :] + jax.random.normal(key, (S, n, d))
    post_mean = x.reshape(-1, d).sum(0) / (1 + S * n)
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), {"x": x},
        minibatch=10, step_size=1e-4, shard_probs=probs,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=400, local_steps=50, n_chains=1,
                              thin=10))
    tr = samp.sample(jax.random.PRNGKey(2), jnp.zeros(d))[0]
    tr = tr[tr.shape[0] // 2:]
    mse = float(jnp.sum((tr.mean(0) - post_mean) ** 2))
    assert mse < 1e-3, mse
