"""Unit tests for the federation subsystem (repro.fed, PR 5).

Partitioners: split correctness + skew direction + pad-row deadness.
Schedules: mask lowering semantics + the delayed/participation
equivalences on the real engine. Compression: operator contracts
(top-k support, rand-k/qsgd unbiasedness, error-feedback identity,
flattener round-trip) + the frac=1 == exact-exchange engine identity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import analytic_gaussian_likelihood_surrogate, make_bank
from repro.fed import (CommSchedule, Compression, Federation,
                       PartitionSpec, get_scenario, make_compressor,
                       make_flattener, partition, scenario_names)
from repro.fed import schedule as fsched


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _facade(data, bank, **kw):
    kw.setdefault("schedule", api.Schedule(rounds=4, local_steps=3,
                                           n_chains=4))
    return api.FSGLD(api.Posterior(log_lik, prior_precision=1.0), data,
                     minibatch=8, step_size=1e-4,
                     surrogate=api.SurrogateSpec(kind="diag", bank=bank),
                     **kw)


def _pooled(key, N=400, d=4, classes=4):
    """Pooled labeled data: Gaussian class clusters."""
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (N,), 0, classes)
    x = jax.random.normal(k2, (N, d)) + 2.0 * y[:, None]
    return {"x": x, "y": y}


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

def _sets(shard_data, sizes, field="x"):
    got = []
    for s, n_s in enumerate(sizes):
        got.append(np.asarray(shard_data[field][s, :n_s]))
    return got


@pytest.mark.parametrize("kind", ["iid", "dirichlet", "quantity",
                                  "covariate"])
def test_partition_covers_without_duplicates(kind):
    data = _pooled(jax.random.PRNGKey(0))
    spec = PartitionSpec(kind=kind, num_shards=4, alpha=0.3)
    shards, sizes = partition(jax.random.PRNGKey(1), data, spec)
    assert len(sizes) == 4 and min(sizes) >= spec.min_size
    live = np.concatenate([c[:, 0] for c in _sets(shards, sizes)])
    pool = np.asarray(data["x"][:, 0])
    # every live row is a real pooled row, each used at most once
    assert len(np.unique(live)) == len(live)
    assert np.isin(live, pool).all()
    assert len(live) <= len(pool)
    # pad rows are NaN (provably dead under the engine's masked sampling)
    for s, n_s in enumerate(sizes):
        pad = np.asarray(shards["x"][s, n_s:])
        assert np.isnan(pad).all() if pad.size else True


def test_dirichlet_low_alpha_skews_labels():
    data = _pooled(jax.random.PRNGKey(2), N=800)
    sk01, sizes01 = partition(jax.random.PRNGKey(3), data,
                              PartitionSpec(kind="dirichlet", num_shards=4,
                                            alpha=0.05, min_size=2))
    sk100, sizes100 = partition(jax.random.PRNGKey(3), data,
                                PartitionSpec(kind="dirichlet",
                                              num_shards=4, alpha=100.0))

    def max_frac(shards, sizes):
        fr = []
        for s, n_s in enumerate(sizes):
            lab = np.asarray(shards["y"][s, :n_s])
            _, cnt = np.unique(lab, return_counts=True)
            fr.append(cnt.max() / n_s)
        return np.mean(fr)

    # low alpha: each client dominated by few classes; high alpha ~ IID
    assert max_frac(sk01, sizes01) > max_frac(sk100, sizes100) + 0.15


def test_quantity_skew_is_ragged_and_iid_is_uniform():
    data = _pooled(jax.random.PRNGKey(4))
    _, sizes_q = partition(jax.random.PRNGKey(5), data,
                           PartitionSpec(kind="quantity", num_shards=4,
                                         alpha=0.3))
    assert max(sizes_q) > 2 * min(sizes_q), sizes_q
    _, sizes_i = partition(jax.random.PRNGKey(5), data,
                           PartitionSpec(kind="iid", num_shards=4))
    assert len(set(sizes_i)) == 1


def test_covariate_shift_separates_feature_space():
    data = _pooled(jax.random.PRNGKey(6), N=400)
    shards, sizes = partition(jax.random.PRNGKey(7), data,
                              PartitionSpec(kind="covariate",
                                            num_shards=4))
    # client means along the principal direction are strictly ordered
    means = [float(np.asarray(shards["x"][s, :n_s]).mean())
             for s, n_s in enumerate(sizes)]
    assert sorted(means) == means or sorted(means, reverse=True) == means


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_schedule_mask_lowering():
    sched = CommSchedule(delay=3, participation=0.5, straggler_prob=0.2)
    comms = [bool(fsched.comm_mask(sched, jnp.int32(r)))
             for r in range(7)]
    assert comms == [True, False, False, True, False, False, True]
    # round 0 forces full participation; later rounds are Bernoulli(p)
    m0 = fsched.participation_mask(sched, jax.random.PRNGKey(0),
                                   jnp.int32(0), 64)
    assert bool(m0.all())
    m5 = fsched.participation_mask(sched, jax.random.PRNGKey(0),
                                   jnp.int32(5), 2048)
    assert 0.4 < float(m5.mean()) < 0.6
    assert CommSchedule().identity and not sched.identity


def test_participation_zero_equals_infinite_delay():
    """participation -> only the forced round-0 exchange happens, which
    is exactly what delay > num_rounds does — both runs share the fed
    RNG stream, so the traces are bitwise equal."""
    data, bank = _problem(jax.random.PRNGKey(0))
    f = _facade(data, bank)
    a = f.sample(jax.random.PRNGKey(9), jnp.zeros(3),
                 federation=Federation(
                     schedule=CommSchedule(participation=1e-9)))
    b = f.sample(jax.random.PRNGKey(9), jnp.zeros(3),
                 federation=Federation(schedule=CommSchedule(delay=100)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_freezes_state_and_trace():
    """straggler_prob ~ 1-eps: no update ever lands — every chain stays
    at theta0 (state AND trace)."""
    data, bank = _problem(jax.random.PRNGKey(1))
    f = _facade(data, bank)
    tr = f.sample(jax.random.PRNGKey(3), jnp.ones(3),
                  federation=Federation(
                      schedule=CommSchedule(straggler_prob=0.999999)))
    np.testing.assert_array_equal(np.asarray(tr),
                                  np.ones_like(np.asarray(tr)))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_and_frac1_is_identity():
    spec = Compression(kind="topk", frac=0.25)
    fn = make_compressor(spec, 8)
    d = jnp.asarray([[1.0, -9.0, 2.0, 0.5, -3.0, 0.1, 0.2, 0.3]])
    out = np.asarray(fn(d, jax.random.PRNGKey(0)))[0]
    assert set(np.flatnonzero(out)) == {1, 4}
    np.testing.assert_array_equal(out[[1, 4]], [-9.0, -3.0])
    ident = make_compressor(Compression(kind="topk", frac=1.0), 8)
    np.testing.assert_array_equal(np.asarray(ident(d, None)),
                                  np.asarray(d))


@pytest.mark.parametrize("kind", ["randk", "qsgd"])
def test_stochastic_compressors_are_unbiased(kind):
    spec = Compression(kind=kind, frac=0.25, bits=4)
    fn = make_compressor(spec, 16)
    d = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    outs = jax.vmap(lambda k: fn(d, k))(
        jax.random.split(jax.random.PRNGKey(2), 4000))
    err = float(jnp.abs(outs.mean(0) - d).max())
    assert err < 0.1, err


def test_qsgd_quantizes_to_levels():
    spec = Compression(kind="qsgd", bits=2)   # 3 levels of |max|
    fn = make_compressor(spec, 8)
    d = jax.random.normal(jax.random.PRNGKey(3), (1, 8))
    out = np.asarray(fn(d, jax.random.PRNGKey(4)))
    scale = float(np.abs(np.asarray(d)).max())
    lvls = np.abs(out) / scale * 3
    np.testing.assert_allclose(lvls, np.round(lvls), atol=1e-5)


def test_flattener_roundtrip_mixed_dtypes():
    tree = {"a": jnp.ones((3, 2, 2), jnp.bfloat16),
            "b": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    flatten, unflatten, dim = make_flattener(tree)
    assert dim == 8
    flat = flatten(tree)
    assert flat.shape == (3, 8) and flat.dtype == jnp.float32
    back = unflatten(flat)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k], jnp.float32),
                                      np.asarray(tree[k], jnp.float32))


def test_bytes_per_round_orders_compressors():
    P = 10_000
    exact = Compression().bytes_per_round(P)
    topk = Compression(kind="topk", frac=0.01).bytes_per_round(P)
    qsgd = Compression(kind="qsgd", bits=8).bytes_per_round(P)
    assert topk < qsgd < exact


def test_topk_frac1_matches_uncompressed_exchange_on_engine():
    """With frac=1 the payload is exact, so a delayed schedule with and
    without the compressor must produce bitwise-identical traces (the
    error-feedback state stays zero, the server view tracks theta)."""
    data, bank = _problem(jax.random.PRNGKey(0))
    f = _facade(data, bank)
    sched = CommSchedule(delay=2)
    a = f.sample(jax.random.PRNGKey(9), jnp.zeros(3),
                 federation=Federation(schedule=sched))
    b = f.sample(jax.random.PRNGKey(9), jnp.zeros(3),
                 federation=Federation(
                     schedule=sched,
                     compression=Compression(kind="topk", frac=1.0)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_leaves_non_exchanging_chains_untouched_x64():
    """Non-exchanging chains must never round-trip through the fp32
    compression space: with float64 state, an active compressor, and a
    schedule under which no chain ever exchanges a non-zero payload
    (participation ~ 0 past the forced round-0 exchange of a zero
    delta), the trace is BITWISE the no-compression run — state writes
    happen only for chains that actually exchanged."""
    import os
    import subprocess
    import sys
    script = r"""
import warnings
warnings.simplefilter("ignore")
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
from repro import api
from repro.fed import CommSchedule, Compression, Federation
from repro.core import make_bank, analytic_gaussian_likelihood_surrogate

def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (4, 24, 3), jnp.float64) \
    + jnp.arange(4.0)[:, None, None]
mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
bank = make_bank(mu_s, prec_s, "diag")
f = api.FSGLD(api.Posterior(log_lik, prior_precision=1.0), {"x": x},
              minibatch=6, step_size=1e-4,
              surrogate=api.SurrogateSpec(kind="diag", bank=bank),
              schedule=api.Schedule(rounds=4, local_steps=3, n_chains=2))
sched = CommSchedule(delay=3, participation=1e-12)
a = f.sample(jax.random.PRNGKey(5), jnp.zeros(3, jnp.float64),
             federation=Federation(schedule=sched))
b = f.sample(jax.random.PRNGKey(5), jnp.zeros(3, jnp.float64),
             federation=Federation(
                 schedule=sched,
                 compression=Compression(kind="qsgd", bits=8)))
assert a.dtype == jnp.float64, a.dtype
np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("X64_UNTOUCHED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "X64_UNTOUCHED_OK" in r.stdout, (r.stdout, r.stderr[-2000:])


# ---------------------------------------------------------------------------
# registry + facade plumbing
# ---------------------------------------------------------------------------

def test_registry_names_resolve_and_unknown_raises():
    for name in scenario_names():
        assert isinstance(get_scenario(name), Federation)
    spec = Federation()
    assert get_scenario(spec) is spec
    with pytest.raises(KeyError, match="unknown federation scenario"):
        get_scenario("no-such-scenario")
    # the ISSUE's named configurations all exist
    for name in ("iid", "dirichlet-0.1", "delayed-5x", "partial-50%",
                 "topk-1%", "elf-dual-topk-1%", "elf-bidir-topk-1%",
                 "elf-bidir-randk-10%", "elf-bidir-qsgd-8bit"):
        assert name in scenario_names(), name


def test_registry_unknown_name_error_is_actionable():
    """A typo'd scenario name lists every available name AND suggests
    the nearest match; non-string keys get the same actionable error
    instead of a bare TypeError."""
    with pytest.raises(KeyError, match=r"did you mean 'delayed-5x'"):
        get_scenario("delayed-5")
    with pytest.raises(KeyError, match=r"elf-bidir-topk-1%"):
        get_scenario("elf-bidir-topk")
    with pytest.raises(KeyError, match="available: identity"):
        get_scenario("no-such-scenario")
    with pytest.raises(KeyError, match="unknown federation scenario"):
        get_scenario(("not", "hashable", ["x"]))


def test_sample_time_repartition_refused():
    data, bank = _problem(jax.random.PRNGKey(0))
    f = _facade(data, bank)
    with pytest.raises(ValueError, match="cannot re-partition"):
        f.sample(jax.random.PRNGKey(0), jnp.zeros(3), federation="iid")


def test_partition_spec_num_shards_drives_cfg():
    data = _pooled(jax.random.PRNGKey(8))
    sc = dataclasses.replace(
        get_scenario("dirichlet-0.1"),
        partition=PartitionSpec(kind="dirichlet", alpha=0.1,
                                num_shards=4))
    f = api.FSGLD(api.Posterior(log_lik), data, minibatch=6,
                  step_size=1e-4, method="dsgld",
                  schedule=api.Schedule(rounds=2, local_steps=2,
                                        n_chains=2),
                  federation=sc)
    assert f.cfg.num_shards == 4
    tr = f.sample(jax.random.PRNGKey(1), jnp.zeros(4))
    assert bool(jnp.all(jnp.isfinite(tr)))
