"""Executor x dynamics x dtype PARITY MATRIX (the PR 4 CI gate).

Every cell of (vmap | per_leaf | packed) x (sgld | sghmc transition
kernel) x (fp32 | bf16 parameter leaves) must be BIT-IDENTICAL to the
``FederatedSampler.run_vmap`` oracle configured for the same cell
(``use_kernel`` mirrors the executor, ``dynamics`` the transition
kernel). This is the contract that lets the facade route every dynamics
and dtype through the one fast path: the packed executor's momentum
segment and per-leaf quantize-back may never drift from the reference
semantics.

Runs as its own CI lane (``parity-matrix`` in .github/workflows/ci.yml);
locally: ``PYTHONPATH=src python -m pytest -q tests/test_parity_matrix.py``.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import SamplerConfig
from repro.core import FederatedSampler, make_bank

S, N, DIN, DOUT = 4, 24, 2, 300
ROUNDS, LOCAL, CHAINS, M = 3, 3, 4, 6


def log_lik(theta, batch):
    pred = batch["x"] @ theta["w"] + theta["b"]
    return -0.5 * jnp.sum((batch["y"] - pred) ** 2)


def _problem(key, dtype):
    """Multi-leaf linear-model posterior + 'scalar' bank; the w leaf spans
    multiple packed blocks so in-leaf segment offsets are exercised."""
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (S, N, DIN))
    w_true = jax.random.normal(ks[1], (DIN, DOUT))
    y = x @ w_true + 0.1 * jax.random.normal(ks[2], (S, N, DOUT))
    theta0 = {"b": jnp.zeros(DOUT, dtype), "w": jnp.zeros((DIN, DOUT), dtype)}
    means = {"b": jax.random.normal(ks[3], (S, DOUT)) * 0.1,
             "w": jnp.broadcast_to(w_true[None], (S, DIN, DOUT))
             + 0.1 * jax.random.normal(ks[3], (S, DIN, DOUT))}
    precs = {"b": jnp.linspace(1.0, 2.0, S),
             "w": jnp.linspace(3.0, 5.0, S)}
    return {"x": x, "y": y}, make_bank(means, precs, "scalar"), theta0


_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16}


@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
@pytest.mark.parametrize("kernel", ["sgld", "sghmc"])
@pytest.mark.parametrize("executor", ["vmap", "per_leaf", "packed"])
def test_parity_cell_bitmatches_oracle(executor, kernel, dtype):
    data, bank, theta0 = _problem(jax.random.PRNGKey(2), _DTYPES[dtype])
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=M,
        step_size=1e-4, kernel=kernel, friction=0.1,
        surrogate=api.SurrogateSpec(kind="scalar", bank=bank),
        schedule=api.Schedule(rounds=ROUNDS, local_steps=LOCAL,
                              n_chains=CHAINS),
        execution=api.Execution(executor=executor))
    if executor == "packed":
        assert f.engine._layout_for(theta0) is not None, \
            "packed cell silently fell back off the packed path"
    got = f.sample(jax.random.PRNGKey(7), theta0)

    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=LOCAL, prior_precision=1.0,
                        surrogate="scalar")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.sghmc import SGHMCConfig
        oracle = FederatedSampler(
            log_lik, cfg, data, minibatch=M, bank=bank,
            use_kernel=(executor != "vmap"),
            dynamics=("sghmc" if kernel == "sghmc" else "langevin"),
            sghmc=(SGHMCConfig(friction=0.1) if kernel == "sghmc"
                   else None))
    ref = oracle.run_vmap(jax.random.PRNGKey(7), theta0, ROUNDS,
                          n_chains=CHAINS)
    for name in theta0:
        assert got[name].shape == (CHAINS, ROUNDS * LOCAL) \
            + theta0[name].shape
        assert got[name].dtype == theta0[name].dtype
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(ref[name]), err_msg=name)


@pytest.mark.parametrize("executor", ["vmap", "packed"])
def test_sghmc_nan_pad_rows_never_reach_real_chains(executor):
    """Mesh-pad chain rows are provably DEAD under SGHMC dynamics: build
    the executor with one pad row (n_total=4 over n_chains=3), poison
    that row's theta AND momentum with NaN, and the real chains' traces
    must still bit-match the run_vmap oracle — any leak through
    reassignment, the packed momentum segment, or a cross-chain
    collective would surface as NaN (0 * NaN == NaN), not as drift."""
    data, bank, theta0 = _problem(jax.random.PRNGKey(2), jnp.float32)
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=M,
        step_size=1e-4, kernel="sghmc", friction=0.1,
        surrogate=api.SurrogateSpec(kind="scalar", bank=bank),
        schedule=api.Schedule(rounds=ROUNDS, local_steps=LOCAL,
                              n_chains=3),
        execution=api.Execution(executor=executor))
    eng = f.engine
    layout = eng._layout_for(theta0) if executor == "packed" else None
    if executor == "packed":
        assert layout is not None
    execute = eng._executor(num_rounds=ROUNDS, n_chains=3, n_total=4,
                            reassign="categorical", collect=True,
                            collect_every=1, layout=layout)
    from repro.core.sghmc import init_momentum
    th = jax.tree.map(lambda t: jnp.zeros((4,) + t.shape, t.dtype),
                      theta0)
    chains = jax.tree.map(lambda t: t.at[3].set(jnp.nan),
                          (th, init_momentum(th)))
    chains_out, trace, _, _, _ = execute(
        jax.random.PRNGKey(7), chains, data, bank,
        jnp.asarray(0, jnp.int32), None, None)

    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=LOCAL, prior_precision=1.0,
                        surrogate="scalar")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core.sghmc import SGHMCConfig
        oracle = FederatedSampler(log_lik, cfg, data, minibatch=M,
                                  bank=bank,
                                  use_kernel=(executor != "vmap"),
                                  dynamics="sghmc",
                                  sghmc=SGHMCConfig(friction=0.1))
    ref = oracle.run_vmap(jax.random.PRNGKey(7), theta0, ROUNDS,
                          n_chains=3)
    for name in theta0:
        np.testing.assert_array_equal(
            np.asarray(trace[name][:3]), np.asarray(ref[name]),
            err_msg=f"{name}: NaN pad row leaked into real chains")
    # the pad row itself stays poisoned — proof the executor never
    # sanitised it into something that could silently participate
    for leaf in jax.tree.leaves(jax.tree.map(lambda t: t[3],
                                             chains_out[0])):
        assert np.isnan(np.asarray(leaf)).all()


def test_mixed_dtype_tree_stays_packed_and_bitmatches():
    """One bf16 leaf + one fp32 leaf in the SAME tree rides the packed
    buffer (the old fp32-only guard is gone) and still bit-matches the
    per-leaf kernel oracle leaf-for-leaf."""
    data, bank, theta0 = _problem(jax.random.PRNGKey(5), jnp.float32)
    theta0 = {"b": theta0["b"].astype(jnp.bfloat16), "w": theta0["w"]}
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=LOCAL, prior_precision=1.0,
                        surrogate="scalar")
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=M,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="scalar", bank=bank),
        schedule=api.Schedule(rounds=ROUNDS, local_steps=LOCAL,
                              n_chains=CHAINS),
        execution=api.Execution(executor="packed"))
    assert f.engine._layout_for(theta0) is not None
    got = f.sample(jax.random.PRNGKey(3), theta0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        oracle = FederatedSampler(log_lik, cfg, data, minibatch=M,
                                  bank=bank, use_kernel=True)
    ref = oracle.run_vmap(jax.random.PRNGKey(3), theta0, ROUNDS,
                          n_chains=CHAINS)
    assert got["b"].dtype == jnp.bfloat16 and got["w"].dtype == jnp.float32
    for name in theta0:
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(ref[name]), err_msg=name)
