"""Rival samplers (PR 8): FA-LD aggregation + ELF dual compression.

Contracts (the PR 8 acceptance criteria):

  * the engine's ``aggregation='fald'`` mode is BIT-IDENTICAL to the
    pure-JAX ``repro.rivals.fald_run_vmap`` oracle on every executor
    (vmap / per_leaf / packed) x scenario (exact, delayed, compressed-
    bidir) cell;
  * the jaxpr acceptance gate HOLDS with FA-LD averaging AND
    bidirectional compression lowered into the scanned round body: one
    rounds-scan, one pallas_call, no ``pad`` primitive;
  * ELF dual/bidir compression contracts: randk/qsgd payload operators
    are unbiased; topk ``frac=1`` bidir with error feedback is the
    exact exchange, bitwise; the dual error-feedback state survives
    ``snapshot_every``/``resume`` bitwise;
  * the ``method=`` facade axis resolves through ``repro.rivals``:
    'fald' runs (and refuses the SGHMC kernel), unknown names get an
    actionable error with a nearest-match hint.
"""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint import list_snapshots
from repro.configs.base import SamplerConfig
from repro.core.engine import MeshChainEngine
from repro.fed import CommSchedule, Compression, Federation, get_scenario
from repro.fed.compress import make_compressor
from repro.rivals import METHODS, fald_run_vmap, get_method, Method

S, N, D = 5, 24, 3
KEY = jax.random.PRNGKey(7)


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


@pytest.fixture(scope="module")
def problem():
    key0 = jax.random.PRNGKey(0)
    mus = jax.random.uniform(key0, (S, D), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(
        jax.random.fold_in(key0, 1), (S, N, D))
    return {"x": x}


# ---------------------------------------------------------------------------
# FA-LD == oracle, bitwise, on every executor x scenario cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["vmap", "per_leaf", "packed"])
@pytest.mark.parametrize("scenario", [None, "delayed-5x",
                                      "elf-bidir-topk-1%"])
def test_fald_bitwise_vs_oracle(problem, executor, scenario):
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), problem,
        minibatch=6, step_size=1e-4, method="fald",
        schedule=api.Schedule(rounds=4, local_steps=3, n_chains=4),
        execution=api.Execution(executor=executor), federation=scenario)
    got = f.sample(KEY, jnp.zeros(D))
    ref = fald_run_vmap(log_lik, f.cfg, f.data, 6, KEY, jnp.zeros(D), 4,
                        n_chains=4, federation=scenario,
                        use_kernel=(executor != "vmap"))
    assert got.shape == ref.shape == (4, 12, D)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_fald_averaging_actually_averages(problem):
    """At an exact every-round exchange all chains leave the exchange on
    the SAME server state: the collected states one local step later
    differ only by per-chain noise/minibatch — and with local_steps=1
    on round boundaries the post-exchange pre-step states coincide, so
    chains must NOT equal a no-aggregation DSGLD run."""
    kw = dict(minibatch=6, step_size=1e-4,
              schedule=api.Schedule(rounds=3, local_steps=2, n_chains=4))
    post = api.Posterior(log_lik, prior_precision=1.0)
    fald = api.FSGLD(post, problem, method="fald", **kw)
    dsgld = api.FSGLD(post, problem, method="dsgld", **kw)
    a = np.asarray(fald.sample(KEY, jnp.zeros(D)))
    b = np.asarray(dsgld.sample(KEY, jnp.zeros(D)))
    assert not np.array_equal(a, b)
    # averaging contracts the chain spread at every exchange round
    assert np.isfinite(a).all()


# ---------------------------------------------------------------------------
# jaxpr gate: FA-LD + bidirectional compression, in-scan
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _all_eqns(sub)


def _subjaxprs(v):
    if hasattr(v, "jaxpr"):
        return [v.jaxpr]
    if hasattr(v, "eqns"):
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def test_fald_bidir_lowering_one_scan_one_pallas_no_pad(problem):
    """Server averaging (masked psum) + primal AND dual compression with
    two error-feedback states all ride the ONE rounds-scan; the packed
    executor still issues exactly one pallas_call and no pad primitive
    appears in any scan body."""
    cfg = SamplerConfig(method="dsgld", step_size=1e-4, num_shards=S,
                        local_updates=3, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, problem, minibatch=6,
                          use_kernel=True, aggregation="fald")
    fed = Federation(
        schedule=CommSchedule(delay=2, participation=0.5),
        compression=Compression(kind="topk", frac=0.1,
                                direction="bidir"))
    num_rounds = 6
    layout = eng._layout_for(jnp.zeros(D))
    execute = eng._executor(num_rounds=num_rounds, n_chains=4,
                            reassign="categorical", collect=True,
                            collect_every=1, layout=layout,
                            federation=fed)
    chains = jnp.zeros((4, D))
    sids0 = jnp.zeros((4,), jnp.int32)
    ref0 = jnp.zeros((4, D), jnp.float32)
    jaxpr = jax.make_jaxpr(execute)(
        jax.random.PRNGKey(0), chains, problem, None,
        jnp.asarray(0, jnp.int32), (sids0, (ref0, ref0, ref0)), None)

    eqns = list(_all_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if "pallas" in e.primitive.name]
    assert len(pallas) == 1, [e.primitive.name for e in pallas]
    round_scans = [e for e in eqns if e.primitive.name == "scan"
                   and e.params["length"] == num_rounds]
    assert len(round_scans) == 1, "rounds loop not a single scan"
    for s in (e for e in eqns if e.primitive.name == "scan"):
        body = [e.primitive.name
                for e in _all_eqns(s.params["jaxpr"].jaxpr)]
        assert "pad" not in body, "pad op inside a scan body"
        assert body.count("pallas_call") <= 1


# ---------------------------------------------------------------------------
# ELF dual-compression contracts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    Compression(kind="randk", frac=0.25, direction="dual"),
    Compression(kind="qsgd", bits=4, direction="bidir"),
], ids=["randk", "qsgd"])
def test_randk_qsgd_operators_unbiased(spec):
    """E[C(upd)] == upd for the stochastic operators — the property that
    keeps the dual (broadcast) leg unbiased without error feedback."""
    dim = 48
    upd = jax.random.normal(jax.random.PRNGKey(0), (2, dim))
    compress = make_compressor(spec, dim)
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    mean = jnp.mean(jax.vmap(lambda k: compress(upd, k))(keys), axis=0)
    scale = float(jnp.max(jnp.abs(upd)))
    np.testing.assert_allclose(np.asarray(mean), np.asarray(upd),
                               atol=0.05 * scale)


@pytest.mark.parametrize("direction", ["dual", "bidir"])
def test_topk_full_frac_bidir_is_exact_exchange_bitwise(direction):
    """topk frac=1 keeps every coordinate, so dual/bidir specs with the
    error-feedback states active must reproduce the uncompressed
    exchange bit for bit on the PR 5 reference config — the dual leg's
    add/sub round-trip never touches the values (small per-round deltas
    make ``ref + (flat - ref)`` exact). Both runs share the same
    non-identity schedule so they lower the same fed round body (same
    RNG stream); the only difference is the payload math under test."""
    from repro.core import analytic_gaussian_likelihood_surrogate, \
        make_bank
    key0 = jax.random.PRNGKey(0)
    mus = jax.random.uniform(key0, (S, D), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(
        jax.random.fold_in(key0, 1), (S, 40, D))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), {"x": x},
        minibatch=8, step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag",
                                    bank=make_bank(mu_s, prec_s, "diag")),
        schedule=api.Schedule(rounds=4, local_steps=3, n_chains=4))
    sched = CommSchedule(delay=2)
    exact = f.sample(jax.random.PRNGKey(9), jnp.zeros(D),
                     federation=Federation(schedule=sched))
    comp = f.sample(jax.random.PRNGKey(9), jnp.zeros(D),
                    federation=Federation(
                        schedule=sched,
                        compression=Compression(kind="topk", frac=1.0,
                                                direction=direction)))
    np.testing.assert_array_equal(np.asarray(exact), np.asarray(comp))


@pytest.mark.parametrize("direction", ["dual", "bidir"])
def test_dual_error_feedback_survives_resume_bitwise(tmp_path, problem,
                                                     direction):
    """The dual EF residual rides the fed carry: a run killed mid-way
    and resumed from its snapshot equals the uninterrupted run bitwise
    — dropping ``derr`` on resume would silently re-bias the broadcast."""
    cfg = SamplerConfig(method="dsgld", step_size=1e-4, num_shards=S,
                        local_updates=2, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, problem, minibatch=6)
    fed = Federation(
        schedule=CommSchedule(delay=2),
        compression=Compression(kind="topk", frac=0.5,
                                direction=direction))
    snaps = str(tmp_path / "snaps")
    ref = eng.run(KEY, jnp.zeros(D), 7, n_chains=4, federation=fed)
    a = eng.run(KEY, jnp.zeros(D), 7, n_chains=4, federation=fed,
                snapshot_every=3, snapshot_path=snaps)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(a))
    # kill: drop the newest snapshot, resume from the older one
    shutil.rmtree(list_snapshots(snaps)[-1][1])
    b = eng.run(KEY, jnp.zeros(D), 7, n_chains=4, federation=fed,
                snapshot_every=3, snapshot_path=snaps, resume=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(b))


def test_dual_only_direction_differs_from_primal(problem):
    """direction='dual' compresses the broadcast, not the upload: each
    leg draws its own operator key, so a STOCHASTIC operator (randk)
    must produce a different trajectory than both the exact exchange
    and the primal-only spec. (A deterministic operator like topk is
    key-blind, and with no aggregation between the legs primal-only and
    dual-only are then the same transformation — the distinction is
    real exactly when the operator or the server step is.)"""
    cfg = SamplerConfig(method="dsgld", step_size=1e-4, num_shards=S,
                        local_updates=2, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, problem, minibatch=6)
    sched = CommSchedule(delay=2)
    runs = {}
    for tag, comp in [
            ("exact", Compression()),
            ("primal", Compression(kind="randk", frac=0.5,
                                   direction="primal")),
            ("dual", Compression(kind="randk", frac=0.5,
                                 direction="dual"))]:
        fed = Federation(schedule=sched, compression=comp)
        runs[tag] = np.asarray(eng.run(KEY, jnp.zeros(D), 4, n_chains=4,
                                       federation=fed))
        assert np.isfinite(runs[tag]).all(), tag
    assert not np.array_equal(runs["exact"], runs["dual"])
    assert not np.array_equal(runs["primal"], runs["dual"])


# ---------------------------------------------------------------------------
# the method facade axis
# ---------------------------------------------------------------------------

def test_method_table_resolves_and_hints():
    assert set(METHODS) == {"sgld", "dsgld", "fsgld", "fald"}
    assert isinstance(get_method("fald"), Method)
    assert get_method("fald").aggregation == "fald"
    assert get_method("fsgld").needs_surrogate
    with pytest.raises(ValueError, match=r"did you mean 'fald'"):
        get_method("falld")
    with pytest.raises(ValueError, match="available"):
        get_method(None)


def test_facade_fald_refuses_sghmc(problem):
    with pytest.raises(ValueError, match="sghmc"):
        api.FSGLD(api.Posterior(log_lik), problem, minibatch=6,
                  method="fald", kernel="sghmc")


def test_engine_validates_aggregation(problem):
    cfg = SamplerConfig(method="dsgld", step_size=1e-4, num_shards=S,
                        local_updates=2, prior_precision=1.0)
    with pytest.raises(ValueError, match="aggregation"):
        MeshChainEngine(log_lik, cfg, problem, minibatch=6,
                        aggregation="bogus")
    with pytest.raises(NotImplementedError, match="Langevin"):
        from repro.core.sghmc import SGHMCConfig
        MeshChainEngine(log_lik, cfg, problem, minibatch=6,
                        aggregation="fald", dynamics="sghmc",
                        sghmc=SGHMCConfig())
