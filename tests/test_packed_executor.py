"""Packed single-launch executor (PR 2; multi-segment PR 4) correctness.

Contracts under test:

  * packed single-launch steps are BIT-IDENTICAL to the per-leaf
    chain-batched kernel — and therefore to the ``run_vmap`` oracle — for
    plain / scalar / diag variants, BOTH dynamics (langevin momentum-free
    and SGHMC with the second momentum buffer), multi-leaf pytrees, and
    ragged shards (the full executor x dynamics x dtype grid lives in
    tests/test_parity_matrix.py);
  * one ``pallas_call`` per step for the whole chain block and ZERO
    ``pad`` primitives inside the scan bodies (asserted on the jaxpr);
  * ``MeshChainEngine.run`` traces ONCE for R rounds (scan-over-rounds,
    no per-round retrace or dispatch);
  * ``PackedChains`` pack/unpack round-trips exactly for any floating
    dtype mix and ``quantize`` replays the per-leaf storage-dtype
    round-trip (identity object for all-fp32 layouts);
  * odd-chain pad devices SKIP pad-chain gradient work
    (``make_masked_grad_vmap``, asserted on the switch branch jaxprs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, MeshChainEngine, make_bank,
                        pad_shards, analytic_gaussian_likelihood_surrogate)
from repro.core.engine import pack_bank
from repro.kernels import ops


# ---------------------------------------------------------------------------
# problems
# ---------------------------------------------------------------------------

def log_lik_flat(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def log_lik_tree(theta, batch):
    pred = batch["x"] @ theta["w"] + theta["b"]
    return -0.5 * jnp.sum((batch["y"] - pred) ** 2)


def _flat_problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _tree_problem(key, S=4, n=24, din=2, dout=600):
    """Multi-leaf linear-model posterior + 'scalar' surrogate bank.
    dout=600 makes the w leaf (2, 600) span TWO packed blocks, so the
    engine-level oracle comparison also covers in-leaf base offsets."""
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (S, n, din))
    w_true = jax.random.normal(ks[1], (din, dout))
    y = x @ w_true + 0.1 * jax.random.normal(ks[2], (S, n, dout))
    theta0 = {"b": jnp.zeros(dout), "w": jnp.zeros((din, dout))}
    means = {"b": jax.random.normal(ks[3], (S, dout)) * 0.1,
             "w": jnp.broadcast_to(w_true[None], (S, din, dout))
             + 0.1 * jax.random.normal(ks[3], (S, din, dout))}
    precs = {"b": jnp.linspace(1.0, 2.0, S),
             "w": jnp.linspace(3.0, 5.0, S)}
    return {"x": x, "y": y}, make_bank(means, precs, "scalar"), theta0


def _ragged_problem(key, S=5, d=3):
    base = jax.random.normal(key, (S, 64, d)) + jnp.arange(S)[:, None, None]
    per_shard = [{"x": base[s, : 12 + 9 * s]} for s in range(S)]
    stacked, sizes = pad_shards(per_shard)  # NaN pad: touching it poisons
    xs = [p["x"] for p in per_shard]
    mu = jnp.stack([x.mean(0) for x in xs])
    prec = jnp.stack([jnp.full((d,), float(x.shape[0])) for x in xs])
    return stacked, sizes, make_bank(mu, prec, "diag")


# ---------------------------------------------------------------------------
# unit level: packed_step == per-leaf chain-batched kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dynamics", ["langevin", "sghmc"])
@pytest.mark.parametrize("variant", ["plain", "scalar"])
def test_packed_step_bitmatches_per_leaf_kernel_multileaf(variant,
                                                          dynamics):
    key = jax.random.PRNGKey(0)
    C, S = 4, 5
    # "b" spans MULTIPLE packed blocks (2*1300 > 2 * block_rows*LANE =
    # 2048): seg_base > 0 and repeated seg_leaf entries — the segment
    # paths a single-block leaf never touches — are exercised here
    shapes = {"a": (37,), "b": (2, 1300), "c": (3,)}
    ks = jax.random.split(key, 10)
    theta = {n: jax.random.normal(jax.random.fold_in(ks[0], i), (C,) + s)
             for i, (n, s) in enumerate(shapes.items())}
    g = {n: jax.random.normal(jax.random.fold_in(ks[1], i), (C,) + s)
         for i, (n, s) in enumerate(shapes.items())}
    keys = jax.random.split(ks[2], C)
    sids = jnp.array([0, 2, 2, 4], jnp.int32)
    scale = jnp.linspace(10.0, 40.0, C)
    f_s = jnp.linspace(0.1, 0.4, C)
    kw = dict(h=1e-4, prior_prec=1.0, alpha=1.0, temperature=1.0)
    hmc = dynamics == "sghmc"
    dyn_kw = dict(dynamics=dynamics, friction=0.25) if hmc else {}
    mom = {n: 0.01 * jax.random.normal(jax.random.fold_in(ks[4], i),
                                       (C,) + s)
           for i, (n, s) in enumerate(shapes.items())} if hmc else None

    if variant == "plain":
        bank, kind = None, None
    else:
        means = {n: jax.random.normal(jax.random.fold_in(ks[3], i),
                                      (S,) + s)
                 for i, (n, s) in enumerate(shapes.items())}
        precs = {n: jnp.linspace(0.5, 1.5, S) + i
                 for i, n in enumerate(shapes)}
        bank, kind = make_bank(means, precs, "scalar"), "scalar"

    ref = ops.fused_update_chains_tree(
        theta, g, keys, scale=scale, f_s=f_s, bank=bank, sids=sids,
        surrogate_kind=kind, momentum=mom, **dyn_kw, **kw)
    ref_r = None
    if hmc:
        ref, ref_r = ref

    layout = ops.make_packed_layout(jax.tree.map(lambda t: t[0], theta))
    th_p = layout.pack(theta)
    g_p = layout.pack(g)
    seeds = ops.chain_leaf_seeds(keys, layout.num_leaves)
    if variant == "plain":
        mu_g = mu_s = None
        lam_g_leaf = lam_s_leaf = None
    else:
        pb = pack_bank(layout, bank)
        mu_g = pb["mu_g"]
        mu_s = pb["means"][sids].reshape(-1, ops.LANE)
        lam_g_leaf = pb["lam_g_leaf"]
        lam_s_leaf = pb["lam_s_leaf"][sids]
    scalars = ops.packed_scalar_rows(
        layout, scale=scale, f_s=f_s, lam_g_leaf=lam_g_leaf,
        lam_s_leaf=lam_s_leaf, friction=(0.25 if hmc else 0.0), **kw)
    out_p = ops.packed_step(layout, th_p, g_p, seeds, scalars,
                            variant=variant if bank else "plain",
                            mu_g=mu_g, mu_s=mu_s,
                            r_p=(layout.pack(mom) if hmc else None),
                            dynamics=dynamics)
    if hmc:
        got, got_r = layout.unpack(out_p[0]), layout.unpack(out_p[1])
    else:
        got, got_r = layout.unpack(out_p), None
    for n in shapes:
        np.testing.assert_array_equal(np.asarray(got[n]),
                                      np.asarray(ref[n]), err_msg=n)
        if hmc:
            np.testing.assert_array_equal(np.asarray(got_r[n]),
                                          np.asarray(ref_r[n]),
                                          err_msg=f"momentum:{n}")


def test_packed_step_bitmatches_per_leaf_kernel_diag():
    key = jax.random.PRNGKey(1)
    C, S, P = 4, 5, 3001  # > 2 packed blocks: in-leaf base offsets live
    ks = jax.random.split(key, 8)
    theta = jax.random.normal(ks[0], (C, P))
    g = jax.random.normal(ks[1], (C, P))
    keys = jax.random.split(ks[2], C)
    sids = jnp.array([1, 0, 3, 3], jnp.int32)
    scale = jnp.linspace(5.0, 20.0, C)
    f_s = jnp.linspace(0.2, 0.5, C)
    bank = make_bank(jax.random.normal(ks[3], (S, P)),
                     jnp.abs(jax.random.normal(ks[4], (S, P))) + 0.1,
                     "diag")
    kw = dict(h=1e-4, prior_prec=1.0, alpha=1.0, temperature=1.0)

    ref = ops.fused_update_chains_tree(
        theta, g, keys, scale=scale, f_s=f_s, bank=bank, sids=sids,
        surrogate_kind="diag", **kw)

    layout = ops.make_packed_layout(theta[0])
    pb = pack_bank(layout, bank)
    seeds = ops.chain_leaf_seeds(keys, layout.num_leaves)
    scalars = ops.packed_scalar_rows(layout, scale=scale, f_s=f_s, **kw)
    out_p = ops.packed_step(
        layout, layout.pack(theta), layout.pack(g), seeds, scalars,
        variant="diag", mu_g=pb["mu_g"], lam_g=pb["lam_g"],
        mu_s=pb["means"][sids].reshape(-1, ops.LANE),
        lam_s=pb["precs"][sids].reshape(-1, ops.LANE))
    np.testing.assert_array_equal(np.asarray(layout.unpack(out_p)),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# engine level: packed executor vs the run_vmap oracle
# ---------------------------------------------------------------------------

def test_packed_engine_bitmatches_oracle_multileaf_scalar_bank():
    """Multi-leaf pytree + 'scalar' bank through the full engine: packed
    single-launch rounds equal the legacy per-chain kernel vmap bitwise."""
    data, bank, theta0 = _tree_problem(jax.random.PRNGKey(2))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=4,
                        local_updates=4, prior_precision=1.0,
                        surrogate="scalar")
    eng = MeshChainEngine(log_lik_tree, cfg, data, minibatch=6, bank=bank,
                          use_kernel=True)
    assert eng._layout_for(theta0) is not None, "packed path not taken"
    tr = eng.run(jax.random.PRNGKey(7), theta0, 3, n_chains=4)
    legacy = FederatedSampler(log_lik_tree, cfg, data, minibatch=6,
                              bank=bank, use_kernel=True)
    ref = legacy.run_vmap(jax.random.PRNGKey(7), theta0, 3, n_chains=4)
    for name in theta0:
        assert tr[name].shape == (4, 12) + theta0[name].shape
        np.testing.assert_array_equal(np.asarray(tr[name]),
                                      np.asarray(ref[name]), err_msg=name)


@pytest.mark.parametrize("method", ["sgld", "dsgld", "fsgld"])
def test_packed_engine_bitmatches_oracle_flat_diag(method):
    data, bank = _flat_problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method=method, step_size=1e-4, num_shards=5,
                        local_updates=5, prior_precision=1.0)
    eng = MeshChainEngine(log_lik_flat, cfg, data, minibatch=8,
                          bank=bank if method == "fsgld" else None,
                          use_kernel=True)
    tr = eng.run(jax.random.PRNGKey(3), jnp.zeros(3), 4, n_chains=4)
    legacy = FederatedSampler(log_lik_flat, cfg, data, minibatch=8,
                              bank=bank if method == "fsgld" else None,
                              use_kernel=True)
    ref = legacy.run_vmap(jax.random.PRNGKey(3), jnp.zeros(3), 4,
                          n_chains=4)
    np.testing.assert_array_equal(np.asarray(tr), np.asarray(ref))


def test_packed_engine_matches_per_leaf_engine_ragged():
    """Ragged NaN-padded shards: the packed executor equals the per-leaf
    chain-batched engine bitwise and never touches a pad row."""
    stacked, sizes, bank = _ragged_problem(jax.random.PRNGKey(4))
    S = len(sizes)
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=3, prior_precision=1.0)
    kw = dict(minibatch=6, bank=bank, sizes=sizes, use_kernel=True)
    packed = MeshChainEngine(log_lik_flat, cfg, stacked, **kw)
    per_leaf = MeshChainEngine(log_lik_flat, cfg, stacked, packed=False,
                               **kw)
    a = packed.run(jax.random.PRNGKey(5), jnp.zeros(3), 3, n_chains=4,
                   reassign="permutation")
    b = per_leaf.run(jax.random.PRNGKey(5), jnp.zeros(3), 3, n_chains=4,
                     reassign="permutation")
    assert bool(jnp.all(jnp.isfinite(a)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# dispatch economics: one trace for R rounds, one pallas_call per step
# ---------------------------------------------------------------------------

def _trace_count(num_rounds):
    calls = []

    def counting_ll(theta, batch):
        calls.append(1)
        return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

    data, bank = _flat_problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=3, prior_precision=1.0)
    eng = MeshChainEngine(counting_ll, cfg, data, minibatch=8, bank=bank,
                          use_kernel=True)
    eng.run(jax.random.PRNGKey(7), jnp.zeros(3), num_rounds, n_chains=4)
    first = len(calls)
    # same executor again: cached jit, zero retraces
    eng.run(jax.random.PRNGKey(8), jnp.zeros(3), num_rounds, n_chains=4)
    return first, len(calls)


def test_run_traces_once_for_r_rounds():
    """scan-over-rounds: trace work is CONSTANT in the round count (the
    old host loop retraced nothing but re-dispatched per round; a naive
    unrolled jit would retrace per round), and a second run() with the
    same shape is a pure cache hit."""
    first2, second2 = _trace_count(2)
    first6, second6 = _trace_count(6)
    assert first2 == first6, (first2, first6)
    assert second2 == first2, "second run() retraced"
    assert second6 == first6, "second run() retraced"


def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _all_eqns(sub)


def _subjaxprs(v):
    if hasattr(v, "jaxpr"):           # ClosedJaxpr
        return [v.jaxpr]
    if hasattr(v, "eqns"):            # raw Jaxpr
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def test_packed_run_jaxpr_single_pallas_call_no_pad_in_scan():
    """Acceptance gate: the WHOLE R-round executor jaxpr contains exactly
    one pallas_call (the single-launch step inside the nested scans — not
    one per leaf, not one per round) and no `pad` primitive inside any
    scan body (pack/unpack are hoisted update-slices/slices)."""
    data, bank, theta0 = _tree_problem(jax.random.PRNGKey(2))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=4,
                        local_updates=4, prior_precision=1.0,
                        surrogate="scalar")
    eng = MeshChainEngine(log_lik_tree, cfg, data, minibatch=6, bank=bank,
                          use_kernel=True)
    layout = eng._layout_for(theta0)
    assert layout is not None and layout.num_leaves == 2
    execute = eng._executor(num_rounds=3, n_chains=4,
                            reassign="categorical", collect=True,
                            collect_every=2, layout=layout)
    chains = jax.tree.map(
        lambda t: jnp.zeros((4,) + t.shape, t.dtype), theta0)
    jaxpr = jax.make_jaxpr(execute)(
        jax.random.PRNGKey(0), chains, data, bank,
        jnp.asarray(0, jnp.int32), None, None)

    eqns = list(_all_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if "pallas" in e.primitive.name]
    assert len(pallas) == 1, [e.primitive.name for e in pallas]

    scans = [e for e in eqns if e.primitive.name == "scan"]
    assert scans, "no scan in the executor: rounds loop not scanned"
    for s in scans:
        body = [e.primitive.name
                for e in _all_eqns(s.params["jaxpr"].jaxpr)]
        assert "pad" not in body, "pad op inside a scan body"
        assert body.count("pallas_call") <= 1


def test_packed_float_only_guard():
    """bf16 (any floating dtype) now PACKS — the PR 2 fp32-only guard is
    gone; only non-float leaves fall off the packed path (auto) or refuse
    (explicit packed=True)."""
    data, bank = _flat_problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method="dsgld", step_size=1e-4, num_shards=5,
                        local_updates=2, prior_precision=1.0)
    eng = MeshChainEngine(log_lik_flat, cfg, data, minibatch=8,
                          use_kernel=True)
    assert eng._layout_for(jnp.zeros(3, jnp.bfloat16)) is not None
    # auto mode: non-FLOAT params silently fall back to the per-leaf path
    assert eng._layout_for({"w": jnp.zeros(3),
                            "steps": jnp.zeros(3, jnp.int32)}) is None
    # explicit packed=True refuses instead of changing dtype semantics
    eng2 = MeshChainEngine(log_lik_flat, cfg, data, minibatch=8,
                           use_kernel=True, packed=True)
    with pytest.raises(ValueError):
        eng2._layout_for({"w": jnp.zeros(3),
                          "steps": jnp.zeros(3, jnp.int32)})


# ---------------------------------------------------------------------------
# PackedChains pack/unpack round-trips: mixed dtypes, ragged/odd leaf shapes
# ---------------------------------------------------------------------------

_RT_DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


@settings(max_examples=20, deadline=None)
@given(n_a=st.integers(1, 2200), n_b=st.integers(1, 3000),
       chains=st.integers(1, 5), dt_combo=st.integers(0, 26))
def test_pack_unpack_roundtrip_mixed_dtypes(n_a, n_b, chains, dt_combo):
    """Property: pack -> unpack is the identity for ANY mix of floating
    leaf dtypes and ragged leaf sizes (leaves spanning one block, many
    blocks, or a fraction of one). Narrow-dtype leaves widen to fp32
    losslessly, so the round trip is exact, and quantize() on a
    fresh-packed buffer is a fixed point. ``dt_combo`` decodes base-3 into
    the three leaf dtypes (0 = all fp32 ... 26 = all fp16)."""
    dt_a, dt_b, dt_c = dt_combo % 3, (dt_combo // 3) % 3, dt_combo // 9
    shapes = {"a": ((n_a,), _RT_DTYPES[dt_a]),
              "b": ((2, n_b), _RT_DTYPES[dt_b]),
              "c": ((37,), _RT_DTYPES[dt_c])}
    key = jax.random.PRNGKey(n_a * 7 + n_b * 3 + dt_combo)
    tree = {n: jax.random.normal(jax.random.fold_in(key, i),
                                 (chains,) + s).astype(dt)
            for i, (n, (s, dt)) in enumerate(shapes.items())}
    layout = ops.make_packed_layout(jax.tree.map(lambda t: t[0], tree))
    buf = layout.pack(tree)
    assert buf.shape == (chains * layout.rows_total, ops.LANE)
    assert buf.dtype == jnp.float32
    back = layout.unpack(buf)
    for n in tree:
        assert back[n].dtype == tree[n].dtype, n
        np.testing.assert_array_equal(np.asarray(back[n]),
                                      np.asarray(tree[n]), err_msg=n)
    # storage-dtype values are a fixed point of the per-step quantize
    np.testing.assert_array_equal(np.asarray(layout.quantize(buf)),
                                  np.asarray(buf))


def test_quantize_matches_per_leaf_dtype_roundtrip():
    """quantize() == unpack -> cast-to-storage-dtype -> repack, i.e. the
    exact round trip the per-leaf kernel applies each step, on values NOT
    already representable in the storage dtype."""
    tree = {"a": jnp.zeros((3, 513), jnp.bfloat16),
            "w": jnp.zeros((3, 2, 300), jnp.float32)}
    layout = ops.make_packed_layout(jax.tree.map(lambda t: t[0], tree))
    # fresh fp32 values with mantissas bf16 cannot hold
    buf = layout.pack({"a": jax.random.normal(jax.random.PRNGKey(0),
                                              (3, 513)) * 1.2345,
                       "w": jax.random.normal(jax.random.PRNGKey(1),
                                              (3, 2, 300)) * 1.2345})
    q = layout.quantize(buf)
    ref = layout.pack(layout.unpack(buf))  # unpack casts to leaf dtypes
    np.testing.assert_array_equal(np.asarray(q), np.asarray(ref))
    got = layout.unpack(q)
    # fp32 leaf untouched bitwise; bf16 leaf actually rounded
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(layout.unpack(buf)["w"]))
    raw_a = np.asarray(buf.reshape(3, -1)[:, :513], np.float32)
    assert not np.array_equal(np.asarray(got["a"], np.float32), raw_a)


def test_quantize_identity_for_fp32_layout():
    """All-fp32 layouts return the SAME buffer object: zero added ops in
    the scanned round body (the no-pad/single-pallas jaxpr gate relies on
    this)."""
    tree = {"a": jnp.zeros((2, 40)), "b": jnp.zeros((2, 7))}
    layout = ops.make_packed_layout(jax.tree.map(lambda t: t[0], tree))
    buf = layout.pack(tree)
    assert layout.quantize(buf) is buf


# ---------------------------------------------------------------------------
# pad-chain masking: odd-chain blocks skip pad gradients, not discard them
# ---------------------------------------------------------------------------

def test_masked_grad_vmap_skips_pad_chain_gradients():
    """ROADMAP open item: with n_chains=3 on a 2-way data axis (per=2,
    one pad chain), the pad device's switch branch must compute the
    gradient over ONE chain and concatenate a zero row — not vmap the
    full block and discard. Asserted structurally on the branch jaxprs."""
    from repro.core.engine import make_masked_grad_vmap
    from repro.launch.mesh import make_host_mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = 3
    grad_fn = jax.grad(lambda th, b: -0.5 * jnp.sum((b["x"] - th) ** 2))
    masked = make_masked_grad_vmap(grad_fn, per=2, n_chains=3, d_size=2)
    # no padding -> the plain vmap shortcut, no switch at all
    plain = make_masked_grad_vmap(grad_fn, per=2, n_chains=4, d_size=2)
    thetas = jnp.zeros((2, d))
    batches = {"x": jnp.zeros((2, 6, d))}
    pj = jax.make_jaxpr(plain)(thetas, batches)
    assert all(e.primitive.name != "cond" for e in _all_eqns(pj.jaxpr))

    # axis_index needs an axis context: trace inside shard_map on the
    # host mesh (the switch itself only cares about the traced index)
    mesh = make_host_mesh()
    fn = shard_map(masked, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                   check_rep=False)
    jaxpr = jax.make_jaxpr(fn)(thetas, batches)
    conds = [e for e in _all_eqns(jaxpr.jaxpr)
             if e.primitive.name == "cond"]
    assert conds, "pad masking switch missing from the round gradient pass"
    branches = conds[0].params["branches"]
    assert len(branches) == 2

    def has_padding_concat(bj):
        return any(
            e.primitive.name == "concatenate"
            and tuple(e.outvars[0].aval.shape) == (2, d)
            and tuple(e.invars[-1].aval.shape) == (1, d)
            for e in _all_eqns(bj.jaxpr))

    def grad_widths(bj):
        # leading dims of sliced per-branch gradient operands: the pad
        # branch must slice the block down to its single real chain
        return {tuple(e.outvars[0].aval.shape)[0]
                for e in _all_eqns(bj.jaxpr)
                if e.primitive.name in ("slice", "dynamic_slice")
                and len(e.outvars[0].aval.shape) >= 2}

    pad_branches = [b for b in branches if has_padding_concat(b)]
    full_branches = [b for b in branches if not has_padding_concat(b)]
    assert len(pad_branches) == 1 and len(full_branches) == 1
    assert 1 in grad_widths(pad_branches[0]), \
        "pad branch never sliced the block to its real chains"
