"""Layer-level oracles: chunked attention vs naive softmax, RWKV6 chunked
scan vs stepwise recurrence, RG-LRU associative scan vs stepwise, MoE
dispatch vs dense per-token routing, chunked CE vs full logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


def naive_attention(q, k, v, *, causal=True, window=None):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    kh = jnp.repeat(k, G, axis=2)
    vh = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))


@pytest.mark.parametrize("Sq,H,K,window,block", [
    (64, 4, 2, None, 16), (64, 4, 4, None, 64), (33, 8, 2, None, 8),
    (64, 4, 1, 16, 16), (128, 2, 2, 32, 48),
])
def test_chunked_attention_vs_naive(Sq, H, K, window, block):
    key = jax.random.PRNGKey(0)
    B, hd = 2, 16
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, K, hd))
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    out = L.chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                              causal=True, window=window, block_k=block)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([4, 16, 64]),
       S=st.integers(3, 70))
def test_rwkv_chunked_matches_stepwise(seed, chunk, S):
    """Property: the chunked linear-attention form equals the stepwise
    recurrence for any sequence length / chunk size."""
    key = jax.random.PRNGKey(seed)
    B, D, H, hd = 2, 32, 2, 16
    p = {
        "mu_r": jnp.full((D,), 0.4), "mu_k": jnp.full((D,), 0.5),
        "mu_v": jnp.full((D,), 0.6), "mu_w": jnp.full((D,), 0.3),
        "w_r": jax.random.normal(key, (D, D)) * D ** -0.5,
        "w_k": jax.random.normal(jax.random.fold_in(key, 1), (D, D))
        * D ** -0.5,
        "w_v": jax.random.normal(jax.random.fold_in(key, 2), (D, D))
        * D ** -0.5,
        "w_o": jax.random.normal(jax.random.fold_in(key, 3), (D, D))
        * D ** -0.5,
        "w0": jnp.full((D,), -0.5),
        "w_lora_a": jax.random.normal(jax.random.fold_in(key, 4), (D, 8))
        * 0.1,
        "w_lora_b": jax.random.normal(jax.random.fold_in(key, 5), (8, D))
        * 0.1,
        "u": jax.random.normal(jax.random.fold_in(key, 6), (H, hd)) * 0.1,
    }
    x = jax.random.normal(jax.random.fold_in(key, 7), (B, S, D))
    y_chunk, st_chunk = L.rwkv_forward(x, p, chunk=chunk)
    st_step = L.rwkv_init_state(B, H, hd, D, x.dtype)
    ys = []
    for t in range(S):
        y, st_step = L.rwkv_decode(x[:, t:t + 1], p, st_step)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_chunk["S"]),
                               np.asarray(st_step["S"]), atol=1e-4,
                               rtol=1e-3)


def test_rglru_scan_matches_stepwise():
    key = jax.random.PRNGKey(0)
    B, S, D = 2, 37, 16
    p = {
        "w_x": jax.random.normal(key, (D, D)) * D ** -0.5,
        "w_gate": jax.random.normal(jax.random.fold_in(key, 1), (D, D))
        * D ** -0.5,
        "w_out": jax.random.normal(jax.random.fold_in(key, 2), (D, D))
        * D ** -0.5,
        "conv_w": jax.random.normal(jax.random.fold_in(key, 3), (4, D))
        * 0.5,
        "w_rec": jax.random.normal(jax.random.fold_in(key, 4), (D, D))
        * D ** -0.5,
        "w_inp": jax.random.normal(jax.random.fold_in(key, 5), (D, D))
        * D ** -0.5,
        "lam": jnp.full((D,), 0.5),
    }
    x = jax.random.normal(jax.random.fold_in(key, 6), (B, S, D))
    y_scan, h_last = L.rglru_forward(x, p)
    state = L.rglru_init_state(B, D, 4, x.dtype)
    ys = []
    for t in range(S):
        y, state = L.rglru_decode(x[:, t:t + 1], p, state)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(state["h"]),
                               atol=1e-4, rtol=1e-3)


def test_moe_dispatch_matches_dense_routing():
    key = jax.random.PRNGKey(0)
    T, D, F, E, K = 96, 16, 32, 4, 2
    x = jax.random.normal(key, (T, D))
    ks = jax.random.split(key, 4)
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.1,
         "experts_wi_gate": jax.random.normal(ks[1], (E, D, F)) * D ** -0.5,
         "experts_wi_up": jax.random.normal(ks[2], (E, D, F)) * D ** -0.5,
         "experts_wo": jax.random.normal(ks[3], (E, F, D)) * F ** -0.5}
    y, aux = L._moe_group(x, p, top_k=K, ffn_type="silu",
                          capacity_factor=100.0)
    gates = jax.nn.softmax(x @ p["router"], -1)
    tw, ti = jax.lax.top_k(gates, K)
    tw = tw / tw.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for j in range(K):
        for e in range(E):
            sel = ti[:, j] == e
            h = jax.nn.silu(x @ p["experts_wi_gate"][e]) \
                * (x @ p["experts_wi_up"][e])
            want += jnp.where(sel[:, None], tw[:, j:j + 1]
                              * (h @ p["experts_wo"][e]), 0.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5,
                               rtol=1e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens_not_correctness():
    """At capacity_factor -> 0 everything drops: output must be exactly 0
    (overflow slots must not corrupt other tokens)."""
    key = jax.random.PRNGKey(0)
    T, D, F, E = 32, 8, 16, 4
    x = jax.random.normal(key, (T, D))
    ks = jax.random.split(key, 4)
    p = {"router": jax.random.normal(ks[0], (D, E)) * 0.1,
         "experts_wi_gate": jax.random.normal(ks[1], (E, D, F)),
         "experts_wi_up": jax.random.normal(ks[2], (E, D, F)),
         "experts_wo": jax.random.normal(ks[3], (E, F, D))}
    y, _ = L._moe_group(x, p, top_k=2, ffn_type="silu",
                        capacity_factor=1e-9)
    # capacity >= top_k by construction, so *some* tokens flow; no NaNs
    assert jnp.all(jnp.isfinite(y))


def test_chunked_log_lik_matches_full():
    from repro.models.model import chunked_log_lik
    key = jax.random.PRNGKey(0)
    B, S, D, V = 2, 50, 16, 37
    h = jax.random.normal(key, (B, S, D))
    head = jax.random.normal(jax.random.fold_in(key, 1), (D, V))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    got = chunked_log_lik(h, head, labels, chunk=16)
    logits = h @ head
    want = jnp.sum(jax.nn.log_softmax(logits)[
        jnp.arange(B)[:, None], jnp.arange(S)[None], labels])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
