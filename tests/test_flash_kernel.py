"""Pallas flash-attention kernel vs pure-jnp oracle (interpret mode),
shape/dtype/mask sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def naive(q, k, v, causal=True, window=None):
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("S,H,hd,bq,bk,causal,window", [
    (128, 2, 64, 64, 64, True, None),
    (128, 2, 64, 128, 32, True, None),
    (256, 1, 128, 64, 128, True, None),
    (128, 2, 64, 64, 64, False, None),
    (256, 2, 64, 64, 64, True, 96),
])
def test_flash_kernel_vs_naive(S, H, hd, bq, bk, causal, window):
    key = jax.random.PRNGKey(0)
    B = 2
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = naive(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(3)
    B, S, H, hd = 1, 128, 2, 64
    q = jax.random.normal(key, (B, S, H, hd)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, S, H, hd)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, S, H, hd)).astype(dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = naive(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32))
    assert out.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=tol, rtol=tol)
