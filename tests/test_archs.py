"""Per-architecture smoke tests (assignment requirement): reduced variant of
each family, one forward + one FSGLD train step on CPU, asserting output
shapes and no NaNs; plus prefill/decode parity for one arch per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SamplerConfig, get_smoke_config
from repro.launch.steps import (init_surrogate_state, make_serve_step,
                                make_train_step)
from repro.models import (decode_step, encoder_forward, forward, init_cache,
                          init_params, log_lik_fn)
from repro.models.model import ACT_DTYPE


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)

    hidden, aux = forward(params, cfg, batch["tokens"],
                          enc_embeds=batch.get("enc_embeds"))
    assert hidden.shape == (B, S, cfg.d_model)
    assert jnp.all(jnp.isfinite(hidden.astype(jnp.float32)))

    sampler = SamplerConfig(method="fsgld", step_size=1e-6)
    step = make_train_step(cfg, sampler, scale=100.0, f_s=0.25)
    surr = init_surrogate_state(params, lam=1e-4)
    new_params, metrics = jax.jit(step)(params, surr, batch,
                                        jax.random.PRNGKey(1))
    assert jnp.isfinite(metrics["log_lik"])
    for old, new in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)):
        assert old.shape == new.shape and old.dtype == new.dtype
        assert jnp.all(jnp.isfinite(new.astype(jnp.float32)))
    # the chain moved
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 16
    cache = init_cache(cfg, B, S)
    serve = make_serve_step(cfg)
    token = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    if cfg.family in ("vlm", "audio"):
        T = cfg.num_patches if cfg.family == "vlm" else cfg.encoder_seq
        enc = jax.random.normal(key, (B, T, cfg.d_model), ACT_DTYPE)
        nxt, cache2 = jax.jit(serve)(params, cache, token, pos, enc)
    else:
        nxt, cache2 = jax.jit(serve)(params, cache, token, pos)
    assert nxt.shape == (B,) and nxt.dtype == jnp.int32
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


PARITY_ARCHS = ["qwen3-1.7b", "h2o-danube-1.8b", "llama-3.2-vision-90b",
                "whisper-large-v3", "recurrentgemma-2b", "rwkv6-7b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_decode_parity(arch):
    """Full-sequence forward and token-by-token decode agree (validates KV
    caches, ring buffers, recurrent states) at bf16 tolerance."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc = enc_in = None
    if cfg.family == "vlm":
        enc = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model),
                                ACT_DTYPE)
        hidden, _ = forward(params, cfg, tokens, enc_embeds=enc)
    elif cfg.family == "audio":
        enc_in = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))
        enc = encoder_forward(params, cfg, enc_in)
        hidden, _ = forward(params, cfg, tokens, enc_embeds=enc_in)
    else:
        hidden, _ = forward(params, cfg, tokens)
    full = jnp.einsum("bsd,dv->bsv", hidden,
                      params["head"].astype(ACT_DTYPE),
                      preferred_element_type=jnp.float32)

    cache = init_cache(cfg, B, S)
    if enc is not None:
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p,
                                                   enc_out=enc))
    else:
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    outs = []
    for t in range(S):
        lg, cache = step(cache, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(full - dec))) \
        / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 0.05, rel


@pytest.mark.parametrize("arch", ["grok-1-314b", "phi3.5-moe-42b-a6.6b"])
def test_moe_parity_majority(arch):
    """MoE parity holds for most positions; router tie-flips at bf16
    boundaries and capacity drops affect isolated tokens (documented)."""
    from repro.configs.base import MoEConfig
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    hidden, _ = forward(params, cfg, tokens)
    full = jnp.einsum("bsd,dv->bsv", hidden,
                      params["head"].astype(ACT_DTYPE),
                      preferred_element_type=jnp.float32)
    cache = init_cache(cfg, B, S)
    step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
    outs = []
    for t in range(S):
        lg, cache = step(cache, tokens[:, t:t + 1],
                         jnp.full((B,), t, jnp.int32))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = jnp.max(jnp.abs(full - dec), axis=-1)
    frac_ok = float(jnp.mean(err < 0.1 * float(jnp.max(jnp.abs(full)))))
    assert frac_ok > 0.9, frac_ok
