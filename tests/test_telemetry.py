"""CI ``obs`` lane: in-scan sampler telemetry (PR 10).

Contracts (the observability acceptance criteria):

  * BITWISE NON-INTERFERENCE — a telemetry-ON run returns the SAME
    samples, bit for bit, as a telemetry-OFF run on every executor
    (vmap / per_leaf / packed): metric rows are extra scan outputs and
    the probe draws from ``fold_in(k_run, TELEMETRY_PROBE_SALT)``,
    never from the sampling stream;
  * IN-SCAN LOWERING — with telemetry on, the executor jaxpr is still
    ONE rounds-scan, one pallas_call on the packed path, and no pad
    primitive in any scan body;
  * METRIC GOLDENS — on a tiny Gaussian the exported rows equal
    hand-computed values: grad_norm/log_post from a replayed probe-key
    stream, drift/theta norms from the trace, wire bytes from
    ``Compression.bytes_per_round``, participation from the comm
    schedule, noise_scale from the dynamics' closed form;
  * SEGMENTATION — ``Telemetry(log_every=k)`` splits the run into
    segments bitwise-identically to a one-shot run, with equal frames,
    and emits ``engine.progress`` events through the tracer;
  * composition: collect=False, sghmc, fald, recovery all return their
    usual results with the frame appended; stream x telemetry and
    double segmentation are refused loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import (MeshChainEngine, make_bank,
                        analytic_gaussian_likelihood_surrogate)
from repro.core.conducive import conducive_gradient_from_bank
from repro.core.engine import _perm_sids_slice
from repro.fed import SCENARIOS
from repro.fed.schedule import comm_mask
from repro.obs import TELEMETRY_PROBE_SALT, MetricsFrame, Telemetry
from repro.obs import trace as obs_trace

EXECUTORS = ("vmap", "per_leaf", "packed")


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _facade(data, bank, *, executor="vmap", method="fsgld", kernel="sgld",
            telemetry=None, recovery=None, collect=True, rounds=4,
            local=5, n_chains=4, minibatch=8, step=1e-4,
            reassign="permutation", thin=1, federation=None):
    return api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data,
        minibatch=minibatch, step_size=step, method=method, kernel=kernel,
        surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                   if method == "fsgld"
                   else api.SurrogateSpec(kind="none")),
        schedule=api.Schedule(rounds=rounds, local_steps=local,
                              n_chains=n_chains, reassign=reassign,
                              thin=thin),
        execution=api.Execution(executor=executor, collect=collect,
                                recovery=recovery, telemetry=telemetry),
        federation=federation)


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# bitwise non-interference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("executor", EXECUTORS)
def test_telemetry_off_is_bitwise_identical(executor):
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(7), jnp.zeros(3)
    ref = _facade(data, bank, executor=executor).sample(key, t0)
    got, frame = _facade(data, bank, executor=executor,
                         telemetry=Telemetry()).sample(key, t0)
    _assert_bitwise(ref, got)
    assert isinstance(frame, MetricsFrame)
    assert frame.rounds == 4 and frame.n_chains == 4
    assert frame.names == Telemetry().names
    assert all(np.isfinite(a).all() for a in frame.metrics.values())


def test_probe_off_is_bitwise_identical_too():
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(7), jnp.zeros(3)
    ref = _facade(data, bank).sample(key, t0)
    got, frame = _facade(data, bank).sample(
        key, t0, telemetry=Telemetry(probe=False))
    _assert_bitwise(ref, got)
    assert "grad_norm" not in frame.names


def test_federated_telemetry_is_bitwise_identical():
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(3), jnp.zeros(3)
    ref = _facade(data, bank, federation="topk-1%").sample(key, t0)
    got, _ = _facade(data, bank, federation="topk-1%",
                     telemetry=Telemetry()).sample(key, t0)
    _assert_bitwise(ref, got)


# ---------------------------------------------------------------------------
# in-scan lowering (the jaxpr gate, telemetry enabled)
# ---------------------------------------------------------------------------

def _all_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _all_eqns(sub)


def _subjaxprs(v):
    if hasattr(v, "jaxpr"):           # ClosedJaxpr
        return [v.jaxpr]
    if hasattr(v, "eqns"):            # raw Jaxpr
        return [v]
    if isinstance(v, (list, tuple)):
        return [j for x in v for j in _subjaxprs(x)]
    return []


def test_telemetry_keeps_one_scan_one_pallas_no_pad():
    """Packed executor + scheduled compressed federation + telemetry:
    the metric rows ride the existing rounds-scan as extra outputs — no
    second scan, no extra pallas dispatch, no pad."""
    from repro.configs.base import SamplerConfig
    from repro.fed import CommSchedule, Compression, Federation

    data, bank = _problem(jax.random.PRNGKey(2))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=4, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=6, bank=bank,
                          use_kernel=True)
    fed = Federation(
        schedule=CommSchedule(delay=3, participation=0.5,
                              straggler_prob=0.1),
        compression=Compression(kind="topk", frac=0.1))
    num_rounds = 6
    layout = eng._layout_for(jnp.zeros(3))
    execute = eng._executor(num_rounds=num_rounds, n_chains=4,
                            reassign="categorical", collect=True,
                            collect_every=2, layout=layout, federation=fed,
                            telemetry=Telemetry())
    chains = jnp.zeros((4, 3))
    sids0 = jnp.zeros((4,), jnp.int32)
    ref0 = jnp.zeros((4, 3), jnp.float32)
    jaxpr = jax.make_jaxpr(execute)(
        jax.random.PRNGKey(0), chains, data, bank,
        jnp.asarray(0, jnp.int32), (sids0, (ref0, ref0)), None)

    eqns = list(_all_eqns(jaxpr.jaxpr))
    pallas = [e for e in eqns if "pallas" in e.primitive.name]
    assert len(pallas) == 1, [e.primitive.name for e in pallas]
    round_scans = [e for e in eqns if e.primitive.name == "scan"
                   and e.params["length"] == num_rounds]
    assert len(round_scans) == 1, "rounds loop not a single scan"
    for s in (e for e in eqns if e.primitive.name == "scan"):
        body = [e.primitive.name
                for e in _all_eqns(s.params["jaxpr"].jaxpr)]
        assert "pad" not in body, "pad op inside a scan body"
        assert body.count("pallas_call") <= 1


# ---------------------------------------------------------------------------
# metric goldens (tiny Gaussian, hand-computed)
# ---------------------------------------------------------------------------

def test_probe_metrics_match_replayed_key_stream():
    """grad_norm / log_post equal a host replay of the salted probe-key
    stream at the traced round-end states: the probe consumes
    ``fold_in(k_run, TELEMETRY_PROBE_SALT)``, draws its minibatch with
    the engine's randint sampler, and evaluates the likelihood grad."""
    d, n, m, C, R, T = 3, 16, 4, 2, 3, 2
    x = jax.random.normal(jax.random.PRNGKey(1), (1, n, d))
    data = {"x": x}
    key, t0 = jax.random.PRNGKey(9), jnp.zeros(d)
    f = _facade(data, None, method="dsgld", rounds=R, local=T,
                n_chains=C, minibatch=m)
    trace_ref = f.sample(key, t0)
    trace, frame = f.sample(key, t0, telemetry=Telemetry())
    _assert_bitwise(trace_ref, trace)
    trace = np.asarray(trace)                       # (C, R*T, d)

    k = key
    for r in range(R):
        k, k_assign, k_run = jax.random.split(k, 3)
        kp = jax.random.split(
            jax.random.fold_in(k_run, TELEMETRY_PROBE_SALT), C)
        start = trace[:, r * T - 1] if r else np.zeros((C, d))
        end = trace[:, r * T + T - 1]
        for c in range(C):
            idx = jax.random.randint(kp[c], (m,), 0, n)
            batch = np.asarray(x[0])[np.asarray(idx)]
            th = end[c]
            grad = (batch - th).sum(0)
            ll = -0.5 * ((batch - th) ** 2).sum()
            np.testing.assert_allclose(
                frame.metrics["grad_norm"][r, c],
                np.linalg.norm(grad), rtol=1e-5)
            np.testing.assert_allclose(
                frame.metrics["log_post"][r, c],
                ll - 0.5 * (th ** 2).sum(), rtol=1e-5)
            np.testing.assert_allclose(
                frame.metrics["theta_norm"][r, c],
                np.linalg.norm(th), rtol=1e-5)
            np.testing.assert_allclose(
                frame.metrics["drift_norm"][r, c],
                np.linalg.norm(end[c] - start[c]), rtol=1e-4)
    # identity path: every round exchanges the exact payload
    np.testing.assert_array_equal(frame.metrics["participation"], 1.0)
    np.testing.assert_array_equal(frame.metrics["bytes_per_round"],
                                  8.0 * d)
    np.testing.assert_array_equal(frame.metrics["health_word"], 0.0)
    np.testing.assert_array_equal(frame.metrics["conducive_norm"], 0.0)


def test_conducive_norm_matches_bank_evaluation():
    """conducive_norm is ||g_s(theta)|| (paper Eq. 5) at the round-end
    state against the live bank — replayed with the engine's own
    permutation slice for the chain->client assignment."""
    d, C, R, T = 3, 2, 3, 2
    data, bank = _problem(jax.random.PRNGKey(4), S=2, n=12, d=d)
    key, t0 = jax.random.PRNGKey(11), jnp.zeros(d)
    f = _facade(data, bank, rounds=R, local=T, n_chains=C, minibatch=4)
    trace, frame = f.sample(key, t0, telemetry=Telemetry())
    trace = np.asarray(trace)
    alpha = f.engine.cfg.alpha

    k = key
    for r in range(R):
        k, k_assign, k_run = jax.random.split(k, 3)
        sids = np.asarray(_perm_sids_slice(k_assign, 2, 0, C, C))
        end = trace[:, r * T + T - 1]
        for c in range(C):
            g = conducive_gradient_from_bank(
                jnp.asarray(end[c]), bank, int(sids[c]), 0.5, alpha)
            np.testing.assert_allclose(
                frame.metrics["conducive_norm"][r, c],
                np.linalg.norm(np.asarray(g)), rtol=1e-4)


def test_bytes_and_participation_follow_the_scenario():
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(5), jnp.zeros(3)
    # topk-1% on d=3: one kept coordinate up (8B), dense broadcast down
    comp = SCENARIOS["topk-1%"].compression
    _, frame = _facade(data, bank, federation="topk-1%").sample(
        key, t0, telemetry=Telemetry(probe=False))
    np.testing.assert_array_equal(frame.metrics["participation"], 1.0)
    np.testing.assert_array_equal(frame.metrics["bytes_per_round"],
                                  float(comp.bytes_per_round(3)))
    assert comp.bytes_per_round(3) == 20  # 8*1 up + 4*3 down

    # delayed-5x over 10 rounds: rounds 0 and 5 exchange, others idle
    sched = SCENARIOS["delayed-5x"].schedule
    _, fr = _facade(data, bank, federation="delayed-5x", rounds=10).sample(
        key, t0, telemetry=Telemetry(probe=False))
    mask = np.array([bool(comm_mask(sched, r)) for r in range(10)],
                    np.float32)
    np.testing.assert_array_equal(
        fr.metrics["participation"], np.broadcast_to(mask[:, None], (10, 4)))
    np.testing.assert_array_equal(
        fr.metrics["bytes_per_round"],
        np.broadcast_to((mask * 24.0)[:, None], (10, 4)))


def test_noise_scale_closed_forms():
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(2), jnp.zeros(3)
    h = 1e-4
    cases = [
        # (facade kwargs, expected std of one local step's injected noise)
        (dict(), np.sqrt(h)),                          # sgld: sqrt(h*tau)
        (dict(method="fald", n_chains=4),
         np.sqrt(h * 4)),                              # fald: tau x C
        (dict(kernel="sghmc"),
         np.sqrt(2 * 0.1 * h)),                        # sqrt(2*a*tau*h)
    ]
    for kw, want in cases:
        out = _facade(data, bank, step=h, **kw).sample(
            key, t0, telemetry=Telemetry(probe=False))
        frame = out[-1]
        np.testing.assert_allclose(frame.metrics["noise_scale"], want,
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# segmentation, composition, refusals
# ---------------------------------------------------------------------------

def test_log_every_segmentation_is_bitwise_lossless():
    data, bank = _problem(jax.random.PRNGKey(0))
    key, t0 = jax.random.PRNGKey(7), jnp.zeros(3)
    one, f_one = _facade(data, bank, rounds=5).sample(
        key, t0, telemetry=Telemetry())
    seg, f_seg = _facade(data, bank, rounds=5).sample(
        key, t0, telemetry=Telemetry(log_every=2))
    _assert_bitwise(one, seg)
    assert f_one.names == f_seg.names
    for n in f_one.names:
        np.testing.assert_array_equal(f_one.metrics[n], f_seg.metrics[n])


def test_engine_progress_events_are_emitted(tmp_path):
    data, bank = _problem(jax.random.PRNGKey(0))
    path = str(tmp_path / "trace.jsonl")
    obs_trace.configure(path)
    try:
        _facade(data, bank, rounds=4).sample(
            jax.random.PRNGKey(7), jnp.zeros(3),
            telemetry=Telemetry(log_every=2))
    finally:
        obs_trace.configure()
    recs = obs_trace.read_jsonl(path)
    prog = [r for r in recs if r["name"] == "engine.progress"]
    assert [p["round"] for p in prog] == [2, 4]
    assert all(p["rounds"] == 4 and p["steps_per_s"] > 0 for p in prog)
    assert all("grad_norm" in p and "bytes_per_round" in p for p in prog)
    segs = [r for r in recs if r["name"] == "engine.segment"]
    assert len(segs) == 2 and all(s["dur_s"] > 0 for s in segs)


def test_collect_false_returns_finals_and_frame():
    data, bank = _problem(jax.random.PRNGKey(0))
    finals, frame = _facade(data, bank, collect=False).sample(
        jax.random.PRNGKey(7), jnp.zeros(3), telemetry=Telemetry())
    assert finals.shape == (4, 3)
    assert frame.rounds == 4


def test_recovery_returns_result_health_frame():
    data, bank = _problem(jax.random.PRNGKey(0))
    trace, health, frame = _facade(
        data, bank, kernel="sghmc", recovery=api.Recovery()).sample(
        jax.random.PRNGKey(7), jnp.zeros(3), telemetry=Telemetry())
    assert isinstance(health, api.RunHealth)
    np.testing.assert_array_equal(frame.metrics["health_word"], 0.0)
    np.testing.assert_allclose(frame.metrics["noise_scale"],
                               np.sqrt(2 * 0.1 * 1e-4), rtol=1e-6)


def test_stream_and_double_segmentation_are_refused():
    data, bank = _problem(jax.random.PRNGKey(0), S=12, n=24)
    f = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=8,
        step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=4, local_steps=3, n_chains=4,
                              reassign="permutation"),
        execution=api.Execution(stream=api.Stream(resident=8, window=2),
                                telemetry=Telemetry()))
    with pytest.raises(NotImplementedError, match="telemetry"):
        f.sample(jax.random.PRNGKey(0), jnp.zeros(3))

    g = _facade(data, bank)
    g.execution = api.Execution(snapshot_every=2, snapshot_path="/tmp/x",
                                telemetry=Telemetry(log_every=2))
    with pytest.raises(NotImplementedError, match="segmentation"):
        g.sample(jax.random.PRNGKey(0), jnp.zeros(3))
