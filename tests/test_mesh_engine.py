"""Mesh-parallel chain runtime (core/engine.py) correctness.

The contract under test: the shard_map executor on the 1x1 host mesh is
BIT-IDENTICAL (exact fp32 equality, noise included) to the legacy vmap
loop for all three methods; permutation reassignment is collision-free
every round even with ragged clients; padded rows are provably dead (NaN
poison); and the chain-batched Pallas entry point equals per-chain kernel
calls elementwise.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, MeshChainEngine, make_bank,
                        pad_shards, refresh_bank, refresh_bank_mesh,
                        analytic_gaussian_likelihood_surrogate)
from repro.kernels import ops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem(key, S=5, n=40, d=3):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def _ragged_problem(key, S=5, d=3):
    base = jax.random.normal(key, (S, 64, d)) + jnp.arange(S)[:, None, None]
    per_shard = [{"x": base[s, : 12 + 9 * s]} for s in range(S)]
    stacked, sizes = pad_shards(per_shard)  # NaN pad: touching it poisons
    xs = [p["x"] for p in per_shard]
    mu = jnp.stack([x.mean(0) for x in xs])
    prec = jnp.stack([jnp.full((d,), float(x.shape[0])) for x in xs])
    return stacked, sizes, make_bank(mu, prec, "diag")


# ---------------------------------------------------------------------------
# exact equality with the legacy vmap executor (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["sgld", "dsgld", "fsgld"])
def test_mesh_engine_bitmatches_legacy_vmap(method):
    data, bank = _problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method=method, step_size=1e-4, num_shards=5,
                        local_updates=5, prior_precision=1.0)
    use_bank = bank if method == "fsgld" else None
    samp = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=use_bank)
    a = samp.run_vmap(jax.random.PRNGKey(7), jnp.zeros(3), 4, n_chains=4)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=8, bank=use_bank)
    b = eng.run(jax.random.PRNGKey(7), jnp.zeros(3), 4, n_chains=4)
    assert a.shape == b.shape == (4, 20, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mesh_engine_bitmatches_legacy_permutation_mode():
    data, bank = _problem(jax.random.PRNGKey(1))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=3, prior_precision=1.0)
    samp = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=bank)
    a = samp.run_vmap(jax.random.PRNGKey(3), jnp.zeros(3), 3, n_chains=4,
                      reassign="permutation")
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=8, bank=bank)
    b = eng.run(jax.random.PRNGKey(3), jnp.zeros(3), 3, n_chains=4,
                reassign="permutation")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_block_cyclic_permutation_nchains_gt_shards(use_kernel):
    """ROADMAP open item (closed in PR 5): permutation mode supports
    n_chains > S via block-cyclic client visiting — chain c sits at
    client perm[c % S], bit-identical to the run_vmap oracle's tiled
    permutation."""
    data, bank = _problem(jax.random.PRNGKey(1))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=3, prior_precision=1.0)
    samp = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=bank,
                            use_kernel=use_kernel)
    a = samp.run_vmap(jax.random.PRNGKey(3), jnp.zeros(3), 3, n_chains=7,
                      reassign="permutation")
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=8, bank=bank,
                          use_kernel=use_kernel)
    b = eng.run(jax.random.PRNGKey(3), jnp.zeros(3), 3, n_chains=7,
                reassign="permutation")
    assert a.shape == b.shape == (7, 9, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_block_cyclic_visiting_is_balanced():
    """With C = 2S every client hosts exactly 2 chains each round."""
    data, bank = _problem(jax.random.PRNGKey(2))
    S, C = 5, 10
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=2, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=6, bank=bank)
    sids = np.asarray(eng._permute_sids(jax.random.PRNGKey(4), C))
    assert sids.shape == (C,)
    np.testing.assert_array_equal(sids[:S], sids[S:])  # cyclic tiling
    _, counts = np.unique(sids, return_counts=True)
    np.testing.assert_array_equal(counts, np.full(S, 2))


# ---------------------------------------------------------------------------
# permutation reassignment: collision-free every round, ragged clients
# ---------------------------------------------------------------------------

def test_permutation_reassignment_valid_every_round_ragged():
    stacked, sizes, bank = _ragged_problem(jax.random.PRNGKey(2))
    S = len(sizes)
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                        local_updates=2, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, stacked, minibatch=6, bank=bank,
                          sizes=sizes)
    C = 4
    key = jax.random.PRNGKey(9)
    seen = []
    for _ in range(20):  # replicate run()'s per-round key stream
        key, k_assign, _ = jax.random.split(key, 3)
        sids = np.asarray(eng._permute_sids(k_assign, C))
        # a valid injective assignment into [0, S)
        assert sids.shape == (C,)
        assert len(set(sids.tolist())) == C, sids
        assert sids.min() >= 0 and sids.max() < S, sids
        # and identical to the legacy host-side slice
        legacy = np.asarray(jax.random.permutation(k_assign, S)[:C])
        np.testing.assert_array_equal(sids, legacy)
        seen.append(tuple(sids.tolist()))
    assert len(set(seen)) > 1, "reassignment never changed"


def test_ragged_shards_pad_rows_never_sampled():
    """Pad rows hold NaN; any estimator touching one would poison the
    chain. All three methods must stay finite."""
    stacked, sizes, bank = _ragged_problem(jax.random.PRNGKey(4))
    S = len(sizes)
    for method in ["sgld", "dsgld", "fsgld"]:
        cfg = SamplerConfig(method=method, step_size=1e-4, num_shards=S,
                            local_updates=3, prior_precision=1.0)
        eng = MeshChainEngine(log_lik, cfg, stacked, minibatch=6,
                              bank=bank if method == "fsgld" else None,
                              sizes=sizes)
        tr = eng.run(jax.random.PRNGKey(5), jnp.zeros(3), 3, n_chains=4,
                     reassign="permutation" if method != "sgld"
                     else "categorical")
        assert bool(jnp.all(jnp.isfinite(tr))), method


# ---------------------------------------------------------------------------
# chain-batched fused kernel path
# ---------------------------------------------------------------------------

def test_kernel_engine_runs_four_chains_through_shard_map():
    """Acceptance: a >=4-chain run through the shard_map path with the
    Pallas kernel enabled, bit-equal to the legacy per-chain kernel vmap."""
    data, bank = _problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=5,
                        local_updates=4, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=8, bank=bank,
                          use_kernel=True)
    tr = eng.run(jax.random.PRNGKey(7), jnp.zeros(3), 3, n_chains=4)
    assert tr.shape == (4, 12, 3)
    assert bool(jnp.all(jnp.isfinite(tr)))
    legacy = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=bank,
                              use_kernel=True)
    ref = legacy.run_vmap(jax.random.PRNGKey(7), jnp.zeros(3), 3,
                          n_chains=4)
    np.testing.assert_array_equal(np.asarray(tr), np.asarray(ref))


def test_kernel_engine_ignores_bank_for_non_fsgld():
    """A resident bank must NOT leak a conducive term into DSGLD updates
    (regression: the chain-batched round once passed it unconditionally)."""
    data, bank = _problem(jax.random.PRNGKey(0))
    cfg = SamplerConfig(method="dsgld", step_size=1e-4, num_shards=5,
                        local_updates=3, prior_precision=1.0)
    eng = MeshChainEngine(log_lik, cfg, data, minibatch=8, bank=bank,
                          use_kernel=True)
    tr = eng.run(jax.random.PRNGKey(7), jnp.zeros(3), 2, n_chains=4)
    legacy = FederatedSampler(log_lik, cfg, data, minibatch=8, bank=bank,
                              use_kernel=True)
    ref = legacy.run_vmap(jax.random.PRNGKey(7), jnp.zeros(3), 2,
                          n_chains=4)
    np.testing.assert_array_equal(np.asarray(tr), np.asarray(ref))


@pytest.mark.parametrize("variant", ["plain", "scalar", "diag"])
def test_chain_batched_kernel_equals_per_chain_calls(variant):
    key = jax.random.PRNGKey(0)
    C, P = 4, 1000
    ks = jax.random.split(key, 8)
    th = jax.random.normal(ks[0], (C, P))
    g = jax.random.normal(ks[1], (C, P))
    seeds = jnp.arange(1, C + 1, dtype=jnp.uint32) * 7919
    scale = jnp.linspace(10.0, 40.0, C)
    f_s = jnp.linspace(0.1, 0.4, C)
    kw = dict(h=1e-4, prior_prec=1.0, alpha=1.0, temperature=1.0)
    if variant == "plain":
        extra = dict(mu_g=None, mu_s=None, lam_g=None, lam_s=None)
    elif variant == "scalar":
        extra = dict(mu_g=jax.random.normal(ks[2], (P,)),
                     mu_s=jax.random.normal(ks[3], (C, P)),
                     lam_g=jnp.float32(0.7),
                     lam_s=jnp.abs(jax.random.normal(ks[4], (C,))) + 0.1)
    else:
        extra = dict(mu_g=jax.random.normal(ks[2], (P,)),
                     mu_s=jax.random.normal(ks[3], (C, P)),
                     lam_g=jnp.abs(jax.random.normal(ks[5], (P,))) + 0.1,
                     lam_s=jnp.abs(jax.random.normal(ks[6], (C, P))) + 0.1)

    batched = ops.fused_update_chains_flat(th, g, seeds, scale=scale,
                                           f_s=f_s, **kw, **extra)
    for c in range(C):
        one = ops.fused_update_flat(
            th[c], g[c], seeds[c], scale=scale[c], f_s=f_s[c], **kw,
            mu_g=extra["mu_g"],
            mu_s=None if extra["mu_s"] is None else extra["mu_s"][c],
            lam_g=extra["lam_g"],
            lam_s=None if extra["lam_s"] is None else extra["lam_s"][c])
        np.testing.assert_array_equal(np.asarray(batched[c]),
                                      np.asarray(one), err_msg=f"chain {c}")


# ---------------------------------------------------------------------------
# model-axis surrogate work
# ---------------------------------------------------------------------------

def test_mesh_refresh_matches_serial_refresh():
    data, _ = _problem(jax.random.PRNGKey(6), S=4, n=24, d=3)
    theta = jnp.array([0.1, -0.2, 0.3])
    from repro.launch.mesh import make_host_mesh
    serial = refresh_bank(log_lik, data, theta)
    mesh = refresh_bank_mesh(log_lik, data, theta, make_host_mesh())
    np.testing.assert_allclose(np.asarray(mesh.means),
                               np.asarray(serial.means), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mesh.precs),
                               np.asarray(serial.precs), rtol=1e-6)


# ---------------------------------------------------------------------------
# true SPMD: multi-device data/model axes in a subprocess
# ---------------------------------------------------------------------------

def test_engine_multidevice_matches_host_mesh_subprocess():
    """4 chains on a (2, 2) forced-host-device mesh reproduce the 1x1 host
    mesh run exactly, and the model-axis refresh splits S over 2 groups."""
    script = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler, MeshChainEngine, make_bank,
                        refresh_bank, refresh_bank_mesh,
                        analytic_gaussian_likelihood_surrogate)
from repro.launch.mesh import make_sim_mesh

def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

key = jax.random.PRNGKey(0)
S, n, d = 4, 24, 3
x = jax.random.normal(key, (S, n, d)) + jnp.arange(S)[:, None, None]
mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
bank = make_bank(mu_s, prec_s, "diag")
cfg = SamplerConfig(method="fsgld", step_size=1e-4, num_shards=S,
                    local_updates=3, prior_precision=1.0)
mesh = make_sim_mesh(data=2, model=2)
eng = MeshChainEngine(log_lik, cfg, {"x": x}, minibatch=6, bank=bank,
                      mesh=mesh)
tr = eng.run(jax.random.PRNGKey(7), jnp.zeros(d), 3, n_chains=4,
             reassign="permutation")
samp = FederatedSampler(log_lik, cfg, {"x": x}, minibatch=6, bank=bank)
ref = samp.run_vmap(jax.random.PRNGKey(7), jnp.zeros(d), 3, n_chains=4,
                    reassign="permutation")
np.testing.assert_array_equal(np.asarray(tr), np.asarray(ref))
theta = jnp.array([0.1, -0.2, 0.3])
bm = refresh_bank_mesh(log_lik, {"x": x}, theta, mesh)
bs = refresh_bank(log_lik, {"x": x}, theta)
np.testing.assert_allclose(np.asarray(bm.means), np.asarray(bs.means),
                           rtol=1e-6)
print("MESH_ENGINE_SPMD_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "MESH_ENGINE_SPMD_OK" in r.stdout, (r.stdout, r.stderr[-2000:])
