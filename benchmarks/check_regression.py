"""CI steps/s regression gate.

Compares a freshly produced BENCH json (repro-bench-v1 envelope,
benchmarks/common.py) against a committed baseline and fails when any
matched throughput row regresses by more than the threshold:

    python benchmarks/check_regression.py bench-smoke.json \
        benchmarks/BENCH_chains.json --threshold 0.30

Gate semantics:
  * rows whose note carries ``speedup-floor=X`` are gated ABSOLUTELY
    (derived >= X, no baseline needed): they are same-run executor
    ratios (packed / per-leaf steps/s), machine-independent by
    construction — see ``check_speedup_floors``;
  * rows whose note carries ``calib-floor=X`` / ``calib-ceiling=Y`` are
    gated ABSOLUTELY too (floor <= derived <= ceiling): calibration
    metrics of fixed-seed problems (benchmarks/bench_calibration.py)
    are statistical properties, not throughput — see
    ``check_calibration_bounds``;
  * ``frontier-floor=X`` / ``frontier-ceiling=Y`` marks gate the
    rival-sampler frontier the same way (benchmarks/bench_frontier.py):
    FSGLD MSE ceilings and 0/1 indicator rows with floor 1 — see
    ``check_frontier_bounds``;
  * ``client-floor=X`` / ``client-ceiling=Y`` marks gate the streamed
    client axis (benchmarks/bench_clients.py): absolute peak
    device/host memory ceilings at 10^6 synthetic clients plus the
    streamed-vs-resident bitwise parity indicator — see
    ``check_client_bounds``;
  * ``obs-floor=X`` / ``obs-ceiling=Y`` marks gate the observability
    lane (benchmarks/bench_obs.py): 0/1 span-export indicators with
    floor 1 (the telemetry overhead ratio rides the existing
    ``speedup-floor=`` mark) — see ``check_obs_bounds``;
  * no baseline file            -> SKIP (exit 0) — the lane still runs
    and uploads its artifact, the gate just has nothing to compare to;
  * scale mismatch              -> SKIP (exit 0) — a SCALE=0.01 smoke run
    is not comparable to a SCALE=1 baseline;
  * every SKIP is ANNOTATED: a ``::warning::`` line with the one-line
    reason surfaces in the GitHub checks UI instead of a silent green
    (``_skip``);
  * only rows whose note marks them as throughput ("chain-steps/s") and
    that exist in BOTH files by name are gated; new/removed rows are
    reported, not failed;
  * ratios are NORMALIZED by a machine speed factor before thresholding:
    the baseline was recorded on a different machine than the CI runner,
    and a uniform speed difference must not fail every row. The factor is
    the median current/baseline ratio over the CONTROL rows (the legacy
    ``chains/vmap/`` lanes, which bypass the engine code paths under
    gate), so an engine-wide regression cannot hide inside its own
    normalizer; when no control rows match, the all-row median is the
    fallback (weaker: a slowdown hitting most rows is then absorbed).

Known blind spots of control-row normalization (accepted for a smoke
lane): a regression confined to the CONTROL path itself is not gated
(the control is the reference, and it measures the legacy executor, not
the engine paths this gate protects), and an optimization that speeds
up ONLY the control path lowers every engine row's normalized ratio and
can fail the lane with no real regression — when intentionally changing
the legacy vmap path, regenerate the baseline in the same commit.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import statistics
import sys

THROUGHPUT_MARK = "chain-steps/s"
CONTROL_PREFIX = "chains/vmap/"
FLOOR_MARK = "speedup-floor="
FED_PREFIX = "chains/fed/"
CALIB_FLOOR_MARK = "calib-floor="
CALIB_CEIL_MARK = "calib-ceiling="
FRONTIER_FLOOR_MARK = "frontier-floor="
FRONTIER_CEIL_MARK = "frontier-ceiling="
CLIENT_FLOOR_MARK = "client-floor="
CLIENT_CEIL_MARK = "client-ceiling="
OBS_FLOOR_MARK = "obs-floor="
OBS_CEIL_MARK = "obs-ceiling="


def _skip(reason: str) -> int:
    """A skipped gate must be VISIBLE, not a silent green exit 0: print
    the one-line reason AND a GitHub Actions ``::warning::`` annotation
    (a no-op plain line outside Actions), then skip."""
    print(f"gate SKIPPED: {reason}")
    print(f"::warning title=bench regression gate skipped::{reason}")
    return 0


def _rows(env: dict) -> dict:
    return {r["name"]: r for r in env.get("rows", [])
            if THROUGHPUT_MARK in r.get("note", "")
            and math.isfinite(r.get("derived", float("nan")))}


def check_speedup_floors(env: dict) -> list:
    """ABSOLUTE gate on speedup ratio rows: a row whose note carries
    ``speedup-floor=X`` fails when derived < X. Unlike the baseline
    comparison this needs no baseline and no machine-speed normalization
    — both sides of the ratio ran on the same backend in the same
    process (e.g. packed vs per-leaf kernel steps/s), so the floor is
    portable across machines. Returns the failing row names."""
    failed = []
    for r in env.get("rows", []):
        note = r.get("note", "")
        if FLOOR_MARK not in note:
            continue
        floor = float(note.split(FLOOR_MARK, 1)[1].split(";")[0].split()[0])
        got = r.get("derived", float("nan"))
        ok = math.isfinite(got) and got >= floor
        print(f"{'ok  ' if ok else 'FAIL'} {r['name']}: speedup "
              f"{got:.2f}x (floor {floor:.2f}x)")
        if not ok:
            failed.append(r["name"])
    return failed


def _mark_value(note: str, mark: str):
    if mark not in note:
        return None
    return float(note.split(mark, 1)[1].split(";")[0].split()[0])


def _check_absolute_bounds(env: dict, floor_mark: str,
                           ceil_mark: str) -> list:
    """ABSOLUTE gate on marked rows: a row whose note carries
    ``<floor_mark>X`` and/or ``<ceil_mark>Y`` fails when derived falls
    outside [X, Y]. Like the speedup floors this needs no baseline —
    the bounds are committed statistical properties of fixed-seed
    problems, portable across machines. Returns failing row names."""
    failed = []
    for r in env.get("rows", []):
        note = r.get("note", "")
        lo = _mark_value(note, floor_mark)
        hi = _mark_value(note, ceil_mark)
        if lo is None and hi is None:
            continue
        got = r.get("derived", float("nan"))
        ok = (math.isfinite(got)
              and (lo is None or got >= lo)
              and (hi is None or got <= hi))
        bounds = ", ".join(
            ([f"floor {lo:g}"] if lo is not None else [])
            + ([f"ceiling {hi:g}"] if hi is not None else []))
        print(f"{'ok  ' if ok else 'FAIL'} {r['name']}: "
              f"{got:.6g} ({bounds})")
        if not ok:
            failed.append(r["name"])
    return failed


def check_calibration_bounds(env: dict) -> list:
    """Calibration rows (benchmarks/bench_calibration.py): ensemble
    NLL/ECE ceilings, coverage bracketed from both sides."""
    return _check_absolute_bounds(env, CALIB_FLOOR_MARK, CALIB_CEIL_MARK)


def check_frontier_bounds(env: dict) -> list:
    """Rival-frontier rows (benchmarks/bench_frontier.py): FSGLD
    posterior-mean MSE ceilings plus the indicator gates (DSGLD degrades
    under delay where FSGLD survives; compressed cells move strictly
    fewer bytes than exact; the FA-LD engine is bitwise-identical to its
    pure-JAX oracle) — indicators are 0/1 derived values with floor 1."""
    return _check_absolute_bounds(env, FRONTIER_FLOOR_MARK,
                                  FRONTIER_CEIL_MARK)


def check_client_bounds(env: dict) -> list:
    """Streamed client-axis rows (benchmarks/bench_clients.py): peak
    device-memory and host-RSS ceilings at 10^6 synthetic clients — the
    committed proof that streaming holds only the resident window on
    device — plus the streamed-vs-resident bitwise parity indicator
    (0/1 derived with floor 1)."""
    return _check_absolute_bounds(env, CLIENT_FLOOR_MARK, CLIENT_CEIL_MARK)


def check_obs_bounds(env: dict) -> list:
    """Observability rows (benchmarks/bench_obs.py): 0/1 indicators
    that the engine/server spans actually export (streamed prefetch
    overlap events, serving prefill/decode latency) — floor 1. The
    telemetry-overhead ratio row is gated by ``check_speedup_floors``
    like every other same-run executor ratio."""
    return _check_absolute_bounds(env, OBS_FLOOR_MARK, OBS_CEIL_MARK)


def check_fed_bytes(env: dict) -> list:
    """The compressed-rounds lanes must REPORT their wire cost: every
    ``chains/fed/`` throughput row carries a finite positive
    ``bytes_per_round``, and the compressed lanes upload strictly fewer
    bytes than the uncompressed control — a compressor whose estimate
    stops beating the exact exchange is a broken spec, gated here (no
    baseline needed; the comparison is within one run)."""
    fed = [r for r in env.get("rows", [])
           if r["name"].startswith(FED_PREFIX)
           and THROUGHPUT_MARK in r.get("note", "")]
    if not fed:
        return []
    failed = []
    exact = [r for r in fed if "/uncompressed/" in r["name"]]
    exact_bytes = min((r.get("bytes_per_round") or float("inf"))
                      for r in exact) if exact else float("inf")
    for r in fed:
        b = r.get("bytes_per_round")
        ok = b is not None and math.isfinite(b) and b > 0
        if ok and r not in exact:
            ok = b < exact_bytes
        print(f"{'ok  ' if ok else 'FAIL'} {r['name']}: "
              f"bytes/round {b} (uncompressed {exact_bytes})")
        if not ok:
            failed.append(r["name"])
    return failed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max tolerated fractional steps/s drop")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        cur = json.load(f)
    # absolute speedup floors gate even without a baseline (they compare
    # two executors inside the SAME run, not a run against history)
    floor_failed = check_speedup_floors(cur)
    floor_failed += check_fed_bytes(cur)
    floor_failed += check_calibration_bounds(cur)
    floor_failed += check_frontier_bounds(cur)
    floor_failed += check_client_bounds(cur)
    floor_failed += check_obs_bounds(cur)
    if floor_failed:
        print(f"absolute gate(s) violated: {floor_failed}",
              file=sys.stderr)
        return 1

    if not os.path.exists(args.baseline):
        return _skip(f"no baseline at {args.baseline} — nothing to "
                     "compare against (absolute gates above still ran)")
    with open(args.baseline) as f:
        base = json.load(f)
    if cur.get("schema") != base.get("schema"):
        return _skip(f"schema mismatch (current {cur.get('schema')} vs "
                     f"baseline {base.get('schema')}) — regenerate the "
                     "baseline with this commit's benchmarks")
    if cur.get("scale") != base.get("scale"):
        return _skip(f"scale mismatch (current REPRO_BENCH_SCALE="
                     f"{cur.get('scale')} vs baseline "
                     f"{base.get('scale')}) — runs at different problem "
                     "sizes are not comparable")

    cur_rows, base_rows = _rows(cur), _rows(base)
    shared = sorted(set(cur_rows) & set(base_rows))
    if not shared:
        return _skip("no throughput rows overlap between current run "
                     "and baseline — row names may have been renamed; "
                     "regenerate the baseline")
    for name in sorted(set(base_rows) - set(cur_rows)):
        print(f"~ {name}: in baseline only (not gated)")
    for name in sorted(set(cur_rows) - set(base_rows)):
        print(f"~ {name}: new row (not gated)")

    ratios = {n: (cur_rows[n]["derived"] / base_rows[n]["derived"]
                  if base_rows[n]["derived"] else float("inf"))
              for n in shared}
    control = [r for n, r in ratios.items()
               if n.startswith(CONTROL_PREFIX)]
    speed = statistics.median(control if control
                              else list(ratios.values()))
    print(f"machine speed factor ({'control' if control else 'all'}-row "
          f"median ratio): {speed:.2f}x")

    failed = []
    for name in shared:
        c, b = cur_rows[name]["derived"], base_rows[name]["derived"]
        rel = ratios[name] / speed if speed else float("inf")
        flag = "FAIL" if rel < 1.0 - args.threshold else "ok"
        print(f"{flag:4s} {name}: {c:.6g} vs baseline {b:.6g} "
              f"({ratios[name]:.2f}x raw, {rel:.2f}x speed-normalized)")
        if flag == "FAIL":
            failed.append(name)
    if failed:
        print(f"steps/s regressed >{args.threshold:.0%} on "
              f"{len(failed)} row(s): {failed}", file=sys.stderr)
        return 1
    print(f"gate passed: {len(shared)} row(s) within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
