"""Paper Table 1: Bayesian MLP (3 hidden layers: 18, 18, 8; ReLU; softmax)
on SUSY-like label-imbalanced shards.

IID case: per-shard positive proportions pi_s ~ Beta(100, 100);
non-IID   : pi_s ~ Beta(0.5, 0.5)   (half the shards mostly-positive).

Claims checked (paper Table 1): for non-IID data FSGLD's held-out average
log-likelihood beats DSGLD's clearly; for IID both are comparable; FSGLD
has smaller std across repetitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer
from repro import api
from repro.core import fit_bank_fisher, sample_local_likelihood
from repro.data import susy_shards, susy_test_set

DIM = 18
SIZES = [(DIM, 18), (18, 18), (18, 8), (8, 2)]
OFFS = []
_o = 0
for a, b in SIZES:
    OFFS.append((_o, _o + a * b, _o + a * b + b))
    _o += a * b + b
P = _o  # 854 params, flat vector


def mlp_logits(theta, x):
    h = x
    for i, (a, b) in enumerate(SIZES):
        w = theta[OFFS[i][0]:OFFS[i][1]].reshape(a, b)
        bias = theta[OFFS[i][1]:OFFS[i][2]]
        h = h @ w + bias
        if i + 1 < len(SIZES):
            h = jax.nn.relu(h)
    return h


def log_lik(theta, batch):
    logits = mlp_logits(theta, batch["x"])
    lp = jax.nn.log_softmax(logits)
    y = batch["y"].astype(jnp.int32)
    return jnp.sum(jnp.take_along_axis(lp, y[:, None], 1))


def avg_loglik(trace, batch, max_samples=60):
    tr = trace[:: max(1, trace.shape[0] // max_samples)]
    def one(theta):
        return log_lik(theta, batch) / batch["y"].shape[0]
    return float(jnp.mean(jax.vmap(one)(tr)))


def run():
    key = jax.random.PRNGKey(0)
    S = 10
    # paper-scale clients matter: with small shards the DSGLD local pull
    # N_s/(f_s m) is weak and the pathology (and FSGLD's win) vanishes.
    shard_size = int(20_000 * max(SCALE, 1))
    test = susy_test_set(jax.random.fold_in(key, 7), size=4000)
    rows = []
    summary = {}
    for regime, beta_a in (("iid", 100.0), ("noniid", 0.5)):
        shards, pi = susy_shards(jax.random.fold_in(key, 1), num_shards=S,
                                 shard_size=shard_size, beta_a=beta_a)
        theta0 = 0.1 * jax.random.normal(key, (P,))
        # SHORT in-basin local chains for the means (long local runs walk
        # into distinct ReLU basins and weight-space Gaussians become
        # meaningless) + empirical-Fisher precisions (paper App. F.2),
        # which carry the correct N_s scaling so the conducive anti-force
        # balances the data restoring force pointwise.
        samples = sample_local_likelihood(
            log_lik, shards, theta0, jax.random.fold_in(key, 2),
            minibatch=50, step_size=1e-5, num_steps=400, burn_in=200,
            thin=2, prior_precision=1.0)
        means = jax.tree.leaves(samples)[0].reshape(S, -1, P).mean(1)
        bank = fit_bank_fisher(log_lik, shards, means)

        rounds = int(250 * max(SCALE, 1))
        for method in ("dsgld", "fsgld"):
            samp = api.FSGLD(
                api.Posterior(log_lik, prior_precision=1.0), shards,
                minibatch=50, step_size=1e-5, method=method,
                surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                           if method == "fsgld"
                           else api.SurrogateSpec(kind="none")),
                schedule=api.Schedule(rounds=rounds, local_steps=40,
                                      thin=20))
            lls = []
            with Timer() as t:
                for rep in range(3):
                    tr = samp.sample(jax.random.PRNGKey(20 + rep),
                                     theta0)[0]
                    lls.append(avg_loglik(tr[tr.shape[0] // 2:], test))
            us = t.us_per(3 * rounds * 40)
            mean = float(jnp.mean(jnp.array(lls)))
            std = float(jnp.std(jnp.array(lls)))
            summary[(regime, method)] = (mean, std)
            rows.append(Row(f"table1/{regime}_{method}_test_ll", us, mean))
            rows.append(Row(f"table1/{regime}_{method}_test_ll_std", us,
                            std))
    rows.append(Row("table1/noniid_fsgld_beats_dsgld", 0.0, float(
        summary[("noniid", "fsgld")][0] >= summary[("noniid", "dsgld")][0])))
    rows.append(Row("table1/iid_parity_gap", 0.0, abs(
        summary[("iid", "fsgld")][0] - summary[("iid", "dsgld")][0]),
        note="paper: small (methods comparable on IID)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
