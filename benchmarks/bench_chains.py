"""Mesh chain-runtime scaling benchmark: chains x shards sweep.

Measures wall time per FSGLD chain-step for the shard_map engine
(core/engine.py) against the legacy vmap executor, with the per-leaf
chain-batched Pallas kernel (PR 1) and the packed single-launch executor
(PR 2), on two posteriors:

  * the Sec 5.1 Gaussian-mean model (one flat leaf, diag bank) — the
    elementwise-update cost floor;
  * a multi-leaf BNN (2-layer MLP, 'scalar' bank) — the config where
    per-leaf dispatch dominates and packing pays.

All engine rows run THROUGH the ``repro.api`` facade (PR 3): 'mesh' is
``Execution(executor='vmap')``, 'mesh+kernel' is 'per_leaf',
'mesh+packed' is 'packed' — proving the facade adds no dispatch cost
over driving the engine directly. The 'vmap' control rows keep the
pre-engine ``FederatedSampler.run_vmap`` host loop (the machine-speed
normalizer in check_regression.py).

derived = chain-steps/second aggregate throughput (higher is better);
us_per_call = wall microseconds per chain-step. The ``packed_speedup``
rows carry packed / per-leaf steps/s (PR 2 acceptance: >= 1.5x on the
BNN config; PR 4 adds the ``sghmc_packed_speedup`` row at a 5x floor —
both gated ABSOLUTELY by check_regression.py via the ``speedup-floor=``
note marker, machine-independent because both sides share the backend);
``dispatch`` rows estimate the per-run-call dispatch overhead vs the
marginal cost of one extra scanned round (t(R) ~ a + bR fitted from two
round counts). Tiny shapes for the CI bench-smoke lane via
REPRO_BENCH_SCALE=0.01; paper-scale via SCALE=10.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, bench_main
from repro import api
from repro.configs.base import SamplerConfig
from repro.core import FederatedSampler, make_bank
from repro.core.surrogate import analytic_gaussian_likelihood_surrogate


def _gauss_problem(key, S, n, d):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def gauss_log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _bnn_problem(key, S, n, din, hid, dout):
    """Multi-leaf MLP regression posterior + 'scalar' surrogate bank."""
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (S, n, din))
    w_true = jax.random.normal(ks[1], (din, dout)) / din ** 0.5
    y = x @ w_true + 0.1 * jax.random.normal(ks[2], (S, n, dout))
    theta0 = {
        "w1": jax.random.normal(ks[3], (din, hid)) / din ** 0.5,
        "b1": jnp.zeros(hid),
        "w2": jax.random.normal(ks[4], (hid, dout)) / hid ** 0.5,
        "b2": jnp.zeros(dout),
    }
    means = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (S,) + t.shape)
        + 0.01 * jax.random.normal(ks[5], (S,) + t.shape), theta0)
    precs = jax.tree.map(lambda t: jnp.linspace(1.0, 2.0, S), theta0)
    return {"x": x, "y": y}, make_bank(means, precs, "scalar"), theta0


def bnn_log_lik(theta, batch):
    h = jnp.tanh(batch["x"] @ theta["w1"] + theta["b1"])
    pred = h @ theta["w2"] + theta["b2"]
    return -0.5 * jnp.sum((batch["y"] - pred) ** 2)


def _time_run(runner, key, theta0, rounds, n_chains, t_local, repeats=3):
    # warm up with the SAME round count: the scanned executor compiles one
    # program per R, so a 1-round warmup would leave compile in the timing.
    # best-of-N keeps scheduler noise out of the committed baseline.
    jax.block_until_ready(runner(key, theta0, rounds, n_chains))
    dt = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = runner(key, theta0, rounds, n_chains)
        jax.block_until_ready(out)
        dt = min(dt, time.perf_counter() - t0)
    steps = rounds * t_local * n_chains
    return 1e6 * dt / steps, steps / dt, dt


def _facade_runner(fsgld, t_local):
    """Engine rows run through the repro.api facade (same engine, same
    executor caches — sample() forwards rounds/chains per call)."""
    def go(k, t0_, r, nc):
        return fsgld.sample(k, t0_, rounds=r, n_chains=nc)
    return go


def _facade(log_lik, data, bank, m, t_local, executor, surrogate_kind,
            kernel="sgld"):
    return api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), data, minibatch=m,
        step_size=1e-5, kernel=kernel, friction=0.1,
        surrogate=api.SurrogateSpec(kind=surrogate_kind, bank=bank),
        schedule=api.Schedule(rounds=4, local_steps=t_local, thin=t_local),
        execution=api.Execution(executor=executor))


def _gauss_rows(key, rows):
    d = max(int(4096 * SCALE), 64)
    n = max(int(256 * SCALE), 16)
    rounds, t_local = 4, 8
    shard_sweep = (4, 16) if SCALE >= 1 else (4,)
    chain_sweep = (1, 4, 8) if SCALE >= 1 else (1, 4)

    for S in shard_sweep:
        data, bank = _gauss_problem(jax.random.fold_in(key, S), S, n, d)
        cfg = SamplerConfig(method="fsgld", step_size=1e-5, num_shards=S,
                            local_updates=t_local, prior_precision=1.0)
        theta0 = jnp.zeros(d)
        m = min(32, n)
        for C in chain_sweep:
            samp = FederatedSampler(gauss_log_lik, cfg, data, minibatch=m,
                                    bank=bank)
            f_mesh = _facade(gauss_log_lik, data, bank, m, t_local,
                             "vmap", "diag")
            f_leaf = _facade(gauss_log_lik, data, bank, m, t_local,
                             "per_leaf", "diag")
            f_pack = _facade(gauss_log_lik, data, bank, m, t_local,
                             "packed", "diag")

            def legacy(k, t0_, r, nc):
                # the CONTROL row: the pre-engine host vmap loop, kept as
                # the machine-speed normalizer for check_regression.py
                return samp.run_vmap(k, t0_, r, n_chains=nc,
                                     collect_every=t_local)

            runners = [("vmap", legacy),
                       ("mesh", _facade_runner(f_mesh, t_local)),
                       ("mesh+kernel", _facade_runner(f_leaf, t_local)),
                       ("mesh+packed", _facade_runner(f_pack, t_local))]
            for tag, runner in runners:
                us, thru, _ = _time_run(runner, jax.random.PRNGKey(1),
                                        theta0, rounds, C, t_local)
                rows.append(Row(f"chains/{tag}/S{S}/C{C}", us, thru,
                                note="derived = chain-steps/s"))


def _bnn_rows(key, rows):
    din = max(int(64 * SCALE), 8)
    hid = max(int(256 * SCALE), 16)
    dout = max(int(32 * SCALE), 4)
    n = max(int(256 * SCALE), 16)
    S, C = 4, 4
    rounds, t_local = 4, 8
    m = min(16, n)
    data, bank, theta0 = _bnn_problem(jax.random.fold_in(key, 99), S, n,
                                      din, hid, dout)
    f_leaf = _facade(bnn_log_lik, data, bank, m, t_local, "per_leaf",
                     "scalar")
    f_pack = _facade(bnn_log_lik, data, bank, m, t_local, "packed",
                     "scalar")

    thru = {}
    t_lo = None
    for tag, eng in [("perleaf", f_leaf), ("packed", f_pack)]:
        us, th, dt = _time_run(_facade_runner(eng, t_local),
                               jax.random.PRNGKey(1), theta0, rounds, C,
                               t_local)
        thru[tag] = th
        if tag == "packed":
            t_lo = dt
        rows.append(Row(f"chains/bnn/{tag}/S{S}/C{C}", us, th,
                        note="derived = chain-steps/s"))
    rows.append(Row(f"chains/bnn/packed_speedup/S{S}/C{C}", 0.0,
                    thru["packed"] / thru["perleaf"],
                    note="derived = packed / per-leaf steps/s; "
                         "speedup-floor=1.5"))

    # dispatch overhead: fit t(R) ~ a + b*R on the packed engine — a is
    # the per-run-call host dispatch cost, b the marginal scanned round
    # (t_lo reuses the timed packed run above: identical arguments)
    r_hi = 4 * rounds
    _, _, t_hi = _time_run(_facade_runner(f_pack, t_local),
                           jax.random.PRNGKey(1), theta0, r_hi, C,
                           t_local)
    b = max((t_hi - t_lo) / (r_hi - rounds), 0.0)
    a = max(t_lo - b * rounds, 0.0)
    rows.append(Row(f"chains/bnn/dispatch/S{S}/C{C}", 1e6 * a, 1e6 * b,
                    note="us_per_call = us dispatch per run() call; "
                         "derived = marginal us per scanned round"))

    # SGHMC on the fused executors (PR 4): same BNN posterior, momentum
    # riding the packed layout's second buffer. The vmap row is the
    # pure-jnp reference executor — on this CPU container the Pallas
    # kernels run INTERPRETED, so vmap wins here; on a real TPU the
    # packed single-launch path is the fast one (the gated floor below
    # is therefore packed vs per-leaf — same-backend, dispatch-count
    # economics — not packed vs vmap).
    sghmc_thru = {}
    for tag, ex in [("vmap", "vmap"), ("perleaf", "per_leaf"),
                    ("packed", "packed")]:
        eng = _facade(bnn_log_lik, data, bank, m, t_local, ex, "scalar",
                      kernel="sghmc")
        us, th, _ = _time_run(_facade_runner(eng, t_local),
                              jax.random.PRNGKey(1), theta0, rounds, C,
                              t_local)
        sghmc_thru[tag] = th
        rows.append(Row(f"chains/bnn/sghmc/{tag}/S{S}/C{C}", us, th,
                        note="derived = chain-steps/s"))
    rows.append(Row(
        f"chains/bnn/sghmc_packed_speedup/S{S}/C{C}", 0.0,
        sghmc_thru["packed"] / sghmc_thru["perleaf"],
        note="derived = packed / per-leaf steps/s; speedup-floor=5.0"))


def _fed_rows(key, rows):
    """Compressed vs uncompressed communication rounds (PR 5): the same
    Gaussian posterior through the facade with a registry scenario. Every
    row reports steps/s AND the estimated wire bytes per chain per
    communication round in BOTH directions — upload plus broadcast,
    uncompressed legs at 4 bytes/coordinate (the ``bytes_per_round``
    envelope column, ``Compression.bytes_per_round``). The
    ``compress_overhead`` ratio is gated absolutely: in-scan compression
    at round boundaries must not halve throughput (both sides share the
    backend, so the floor is machine-portable like the packed floors)."""
    from repro.fed import SCENARIOS, Compression

    d = max(int(4096 * SCALE), 64)
    n = max(int(256 * SCALE), 16)
    S, C = 4, 4
    rounds, t_local = 4, 8
    data, bank = _gauss_problem(jax.random.fold_in(key, 77), S, n, d)
    theta0 = jnp.zeros(d)
    m = min(32, n)

    thru = {}
    lanes = [("uncompressed", "identity", Compression()),
             ("topk-1%", "topk-1%", SCENARIOS["topk-1%"].compression),
             ("qsgd-8bit", "qsgd-8bit", SCENARIOS["qsgd-8bit"].compression)]
    # ONE facade: scenarios swap per sample() call (the engine caches one
    # executor per federation spec)
    f = _facade(gauss_log_lik, data, bank, m, t_local, "vmap", "diag")
    for tag, scenario, comp in lanes:
        def runner(k, t0_, r, nc, _s=scenario):
            return f.sample(k, t0_, rounds=r, n_chains=nc, federation=_s)

        us, th, _ = _time_run(runner, jax.random.PRNGKey(1), theta0,
                              rounds, C, t_local)
        thru[tag] = th
        rows.append(Row(f"chains/fed/{tag}/S{S}/C{C}", us, th,
                        note="derived = chain-steps/s",
                        bytes_per_round=comp.bytes_per_round(d)))
    rows.append(Row(
        f"chains/fed/compress_overhead/S{S}/C{C}", 0.0,
        min(thru["topk-1%"], thru["qsgd-8bit"]) / thru["uncompressed"],
        note="derived = compressed / uncompressed steps/s; "
             "speedup-floor=0.5"))


def run():
    key = jax.random.PRNGKey(0)
    rows = []
    _gauss_rows(key, rows)
    _bnn_rows(key, rows)
    _fed_rows(key, rows)
    return rows


if __name__ == "__main__":
    raise SystemExit(bench_main(run))
