"""Mesh chain-runtime scaling benchmark: chains x shards sweep.

Measures wall time per FSGLD chain-step for the shard_map engine
(core/engine.py) against the legacy vmap executor, with and without the
chain-batched fused Pallas kernel, on the Sec 5.1 Gaussian-mean model at a
parameter size where the elementwise update is the visible cost.

derived = chain-steps/second aggregate throughput (higher is better);
us_per_call = wall microseconds per chain-step. Tiny shapes for the CI
bench-smoke lane via REPRO_BENCH_SCALE=0.01; paper-scale via SCALE=10.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, bench_main
from repro.configs.base import SamplerConfig
from repro.core import FederatedSampler, MeshChainEngine, make_bank
from repro.core.surrogate import analytic_gaussian_likelihood_surrogate


def _problem(key, S, n, d):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _time_run(runner, key, theta0, rounds, n_chains, t_local):
    # one warm-up round compiles; sync before timing steady-state rounds
    jax.block_until_ready(runner(key, theta0, 1, n_chains))
    t0 = time.perf_counter()
    out = runner(key, theta0, rounds, n_chains)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    steps = rounds * t_local * n_chains
    return 1e6 * dt / steps, steps / dt


def run():
    d = max(int(4096 * SCALE), 64)
    n = max(int(256 * SCALE), 16)
    rounds, t_local = 4, 8
    key = jax.random.PRNGKey(0)
    shard_sweep = (4, 16) if SCALE >= 1 else (4,)
    chain_sweep = (1, 4, 8) if SCALE >= 1 else (1, 4)

    rows = []
    for S in shard_sweep:
        data, bank = _problem(jax.random.fold_in(key, S), S, n, d)
        cfg = SamplerConfig(method="fsgld", step_size=1e-5, num_shards=S,
                            local_updates=t_local, prior_precision=1.0)
        theta0 = jnp.zeros(d)
        m = min(32, n)
        for C in chain_sweep:
            samp = FederatedSampler(log_lik, cfg, data, minibatch=m,
                                    bank=bank)
            eng_k = MeshChainEngine(log_lik, cfg, data, m, bank=bank,
                                    use_kernel=True)

            def legacy(k, t0_, r, nc):
                return samp.run_vmap(k, t0_, r, n_chains=nc,
                                     collect_every=t_local)

            def mesh(k, t0_, r, nc):
                return samp.run(k, t0_, r, n_chains=nc,
                                collect_every=t_local)

            def mesh_kernel(k, t0_, r, nc):
                return eng_k.run(k, t0_, r, n_chains=nc,
                                 collect_every=t_local)

            for tag, runner in [("vmap", legacy), ("mesh", mesh),
                                ("mesh+kernel", mesh_kernel)]:
                us, thru = _time_run(runner, jax.random.PRNGKey(1), theta0,
                                     rounds, C, t_local)
                rows.append(Row(f"chains/{tag}/S{S}/C{C}", us, thru,
                                note="derived = chain-steps/s"))
    return rows


if __name__ == "__main__":
    raise SystemExit(bench_main(run))
