"""Calibration gates for the K-draw ensemble serving claim.

Two fixed-size conjugate-ish problems where the FSGLD chain demonstrably
reaches the posterior, scored with ``repro.eval.calibration``:

  * Bayesian LOGISTIC regression (classification): K tail draws from the
    chain vs the single freshest draw — ensemble NLL/ECE rows plus the
    Jensen gap (mean single-draw NLL − ensemble NLL, provably >= 0).
  * Bayesian LINEAR regression (known noise): posterior-predictive
    samples from K draws — the central 90% interval must actually cover
    ~90% of held-out targets (bracketed from BOTH sides: an
    overconfident posterior under-covers, a diffuse one over-covers).

Rows carry ABSOLUTE bounds in their notes (``calib-floor=`` /
``calib-ceiling=``), enforced same-run by
``benchmarks/check_regression.py::check_calibration_bounds`` — no
baseline file and no machine-speed normalization needed: the bounds are
statistical properties of a fixed-seed problem, not throughput.

Sizes are FIXED (REPRO_BENCH_SCALE is ignored): calibration is a
statistical claim and shrinking N only widens the noise on the very
quantities under gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, Timer
from repro import api
from repro.eval import (ece_binary, interval_coverage, nll_categorical,
                        nll_gaussian_mixture)

# committed gate bounds — see the module docstring for why they are
# absolute (same-run statistical properties, not machine throughput)
LOGREG_NLL_CEILING = 0.55    # chance is log 2 ~ 0.693; measured ~0.48
LOGREG_ECE_CEILING = 0.12    # measured ~0.06 with K=16 draws
JENSEN_GAP_FLOOR = 0.0       # exact inequality (float64 scoring)
LINREG_COVER_FLOOR = 0.82    # nominal 0.90, finite-N noise ~ +-0.03
LINREG_COVER_CEILING = 0.97
LINREG_NLL_CEILING = 1.0     # analytic optimum 0.5+0.5*log(2*pi*0.25)~0.22

K_DRAWS = 16


def _logreg_rows():
    d, n_train, n_test, S = 4, 800, 400, 4
    k_w, k_x, k_y, k_xt, k_yt, k_run = jax.random.split(
        jax.random.PRNGKey(11), 6)
    w_true = jax.random.normal(k_w, (d,))
    x = jax.random.normal(k_x, (n_train, d))
    y = (jax.random.uniform(k_y, (n_train,))
         < jax.nn.sigmoid(x @ w_true)).astype(jnp.float32)
    xt = jax.random.normal(k_xt, (n_test, d))
    yt = (jax.random.uniform(k_yt, (n_test,))
          < jax.nn.sigmoid(xt @ w_true)).astype(jnp.int32)
    shards = {"x": x.reshape(S, n_train // S, d),
              "y": y.reshape(S, n_train // S)}

    def log_lik(theta, batch):
        z = batch["x"] @ theta
        return jnp.sum(batch["y"] * jax.nn.log_sigmoid(z)
                       + (1 - batch["y"]) * jax.nn.log_sigmoid(-z))

    rounds, local = 600, 5
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), shards,
        minibatch=50, step_size=2e-4, method="fsgld",
        surrogate=api.SurrogateSpec(kind="diag", fit="fisher"),
        schedule=api.Schedule(rounds=rounds, local_steps=local, thin=10))
    with Timer() as t:
        trace = samp.sample(k_run, jnp.zeros(d))[0]
    us = t.us_per(rounds * local)
    # K decorrelated tail draws (thin=5 rounds between collected states)
    draws = trace[-K_DRAWS:]                      # (K, d)
    p1_k = jax.nn.sigmoid(draws @ xt.T)           # (K, n_test)
    p1_64 = np.asarray(p1_k, np.float64)
    two_col = np.stack([1.0 - p1_64, p1_64], -1)  # (K, n_test, 2)
    nll_ens = nll_categorical(two_col, yt)
    nll_singles = [nll_categorical(two_col[k:k + 1], yt)
                   for k in range(K_DRAWS)]
    gap = float(np.mean(nll_singles) - nll_ens)
    ece_ens = ece_binary(p1_k, yt)
    return [
        Row("calib/logreg/ensemble-nll", us, nll_ens,
            note=f"ensemble test NLL (K={K_DRAWS}); "
                 f"calib-ceiling={LOGREG_NLL_CEILING}"),
        Row("calib/logreg/single-nll-mean", us,
            float(np.mean(nll_singles)),
            note="mean single-draw NLL (reported, not gated)"),
        Row("calib/logreg/jensen-gap", us, gap,
            note="mean-single NLL minus ensemble NLL, >=0 by Jensen; "
                 f"calib-floor={JENSEN_GAP_FLOOR}"),
        Row("calib/logreg/ensemble-ece", us, ece_ens,
            note=f"ensemble test ECE (K={K_DRAWS}); "
                 f"calib-ceiling={LOGREG_ECE_CEILING}"),
    ]


def _linreg_rows():
    d, n_train, n_test, S, sigma = 8, 1024, 500, 4, 0.5
    k_w, k_x, k_e, k_xt, k_et, k_run, k_pred = jax.random.split(
        jax.random.PRNGKey(23), 7)
    w_true = jax.random.normal(k_w, (d,))
    x = jax.random.normal(k_x, (n_train, d))
    y = x @ w_true + sigma * jax.random.normal(k_e, (n_train,))
    xt = jax.random.normal(k_xt, (n_test, d))
    yt = xt @ w_true + sigma * jax.random.normal(k_et, (n_test,))
    shards = {"x": x.reshape(S, n_train // S, d),
              "y": y.reshape(S, n_train // S)}

    def log_lik(theta, batch):
        r = batch["y"] - batch["x"] @ theta
        return -0.5 * jnp.sum(r * r) / sigma ** 2

    # analytic diagonal surrogates (f1_linreg idiom): exact local
    # precisions, so the conducive correction is as good as it gets
    from repro.core import make_bank

    def fit_shard(xs, ys):
        prec = xs.T @ xs / sigma ** 2
        mu = jnp.linalg.solve(prec + jnp.eye(d), xs.T @ ys / sigma ** 2)
        return mu, jnp.diag(prec)
    mus, precs = jax.vmap(fit_shard)(shards["x"], shards["y"])
    bank = make_bank(mus, precs, "diag")

    rounds, local, k_keep = 600, 5, 128
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), shards,
        minibatch=64, step_size=5e-5, method="fsgld",
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=rounds, local_steps=local, thin=2))
    with Timer() as t:
        trace = samp.sample(k_run, jnp.zeros(d))[0]
    us = t.us_per(rounds * local)
    draws = trace[-k_keep:]                        # (k_keep, d)
    means_k = draws @ xt.T                         # (k_keep, n_test)
    # posterior-predictive samples: one observation-noise draw per
    # (posterior draw, test point)
    noise = sigma * jax.random.normal(k_pred, means_k.shape)
    samples = means_k + noise
    cov = interval_coverage(samples, yt, level=0.9)
    scales = np.full(means_k.shape, sigma)
    nll = nll_gaussian_mixture(means_k, scales, yt)
    return [
        Row("calib/linreg/coverage90", us, cov,
            note=f"central 90% predictive-interval coverage (K={k_keep} "
                 f"draws); calib-floor={LINREG_COVER_FLOOR}; "
                 f"calib-ceiling={LINREG_COVER_CEILING}"),
        Row("calib/linreg/mixture-nll", us, nll,
            note=f"K-component predictive-mixture NLL; "
                 f"calib-ceiling={LINREG_NLL_CEILING}"),
    ]


def run():
    return _logreg_rows() + _linreg_rows()


if __name__ == "__main__":
    from benchmarks.common import bench_main
    raise SystemExit(bench_main(run))
