"""Observability overhead benchmark: the PR 10 acceptance gates.

Two families of rows:

  * ``obs/telemetry/*`` — the in-scan telemetry cost: the SAME Gaussian
    facade run with ``Execution.telemetry`` off and on (full probe
    metrics), best-of-N timed. The ``overhead`` row is the on/off
    steps/s ratio gated ABSOLUTELY via ``speedup-floor=0.95``
    (telemetry must cost < 5% throughput at production round lengths —
    T=100 local steps per round, the quickstart's configuration: the
    one probe evaluation amortizes over a round's gradient work, so
    overhead scales as ~1/T); both sides share one process/backend and
    the off/on repeats interleave, so the floor is machine-portable
    like the packed-kernel floors. FIXED problem
    size (SCALE ignored, like the calib/frontier lanes): the claim is
    about rounds whose gradient work dwarfs the per-round metric ops —
    at toy sizes the fixed per-round cost dominates and the ratio
    measures dispatch, not telemetry.
  * ``obs/spans/*`` — 0/1 indicator rows (``obs-floor=1``,
    check_regression.py) proving the host-side spans actually EXPORT:
    a streamed prefetch run writes a ``stream.prefetch_overlap`` event
    (the PR 9 overlap measurement) and a serving request writes
    ``serve.prefill``/``serve.decode`` spans plus the ``serve.request``
    event into the trace JSONL.

Every row uses fixed problem sizes; REPRO_BENCH_SCALE is ignored.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, bench_main
from repro import api
from repro.core import make_bank
from repro.core.surrogate import analytic_gaussian_likelihood_surrogate
from repro.obs import trace as obs_trace

T_LOCAL = 100  # the quickstart's round length: probe amortizes over T grads


def _gauss_problem(key, S, n, d):
    mus = jax.random.uniform(key, (S, d), minval=-4, maxval=4)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    return {"x": x}, make_bank(mu_s, prec_s, "diag")


def gauss_log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _telemetry_rows(key, rows, repeats=20):
    d, n = 2048, 128           # fixed: big enough that T=40 grads/round
    S, C, rounds = 4, 4, 4     # dwarf the per-round metric ops
    data, bank = _gauss_problem(jax.random.fold_in(key, 5), S, n, d)
    theta0 = jnp.zeros(d)
    f = api.FSGLD(
        api.Posterior(gauss_log_lik, prior_precision=1.0), data,
        minibatch=min(32, n), step_size=1e-5,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=rounds, local_steps=T_LOCAL,
                              thin=T_LOCAL))

    lanes = [("off", None), ("on", api.Telemetry())]
    runners = {}
    for tag, tel in lanes:
        def runner(_tel=tel):
            return f.sample(jax.random.PRNGKey(1), theta0, rounds=rounds,
                            n_chains=C, telemetry=_tel)
        runners[tag] = runner
        jax.block_until_ready(runner())  # same-shape warmup: no compile

    # INTERLEAVED pairwise ratios: each repeat times off then on
    # back-to-back, so container-level drift (cpufreq, noisy
    # neighbours) hits both sides of that repeat's ratio alike — on
    # this shared CPU box separate per-lane blocks swing the ratio
    # +-30%. The committed overhead is the MEDIAN pairwise ratio (a
    # robust location estimate; best-of-N picks each lane's luckiest
    # moment, which need not be the same moment for both lanes).
    best = {tag: float("inf") for tag, _ in lanes}
    ratios = []
    for _ in range(repeats):
        dt = {}
        for tag, _ in lanes:
            t0 = time.perf_counter()
            jax.block_until_ready(runners[tag]())
            dt[tag] = time.perf_counter() - t0
            best[tag] = min(best[tag], dt[tag])
        ratios.append(dt["off"] / dt["on"])
    ratios.sort()
    mid = len(ratios) // 2
    median = (ratios[mid] if len(ratios) % 2
              else 0.5 * (ratios[mid - 1] + ratios[mid]))

    steps = rounds * T_LOCAL * C
    for tag, _ in lanes:
        rows.append(Row(f"obs/telemetry/{tag}/S{S}/C{C}",
                        1e6 * best[tag] / steps, steps / best[tag],
                        note="derived = chain-steps/s"))
    rows.append(Row(
        f"obs/telemetry/overhead/S{S}/C{C}", 0.0, median,
        note="derived = median interleaved telemetry-on / telemetry-off "
             "steps/s ratio; speedup-floor=0.95"))


def _span_rows(key, rows):
    """0/1 indicators: the spans the engine and server emit actually
    land in an exported trace JSONL (names checked, not just counts)."""
    # -- streamed prefetch overlap (the PR 9 double buffer) --
    data, bank = _gauss_problem(jax.random.fold_in(key, 9), 12, 24, 3)
    f = api.FSGLD(
        api.Posterior(gauss_log_lik, prior_precision=1.0), data,
        minibatch=8, step_size=1e-4,
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=6, local_steps=3, n_chains=4,
                              reassign="permutation", thin=3),
        execution=api.Execution(stream=api.Stream(resident=8, window=2)))
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "trace.jsonl")
        obs_trace.configure(path)
        try:
            jax.block_until_ready(
                f.sample(jax.random.PRNGKey(3), jnp.zeros(3)))
        finally:
            obs_trace.configure()
        recs = obs_trace.read_jsonl(path)
    names = {r["name"] for r in recs}
    overlap = [r for r in recs if r["name"] == "stream.prefetch_overlap"]
    ok = bool(overlap and "stream.dispatch" in names
              and "stream.stage" in names
              and all("overlap_frac" in r for r in overlap))
    rows.append(Row(
        "obs/spans/stream_overlap", 0.0, float(ok),
        note="derived = 1 when a streamed run exports stream.stage/"
             "dispatch spans + the prefetch_overlap event; obs-floor=1"))

    # -- serving request latency spans --
    spec = api.Serving(draws=1, arch="qwen3-1.7b", smoke=True, batch=2,
                       prompt_len=4, gen=3)
    server = api.FSGLD.serve(spec)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "trace.jsonl")
        obs_trace.configure(path)
        try:
            res = server.generate(gen=3, batch=2, prompt_len=4)
        finally:
            obs_trace.configure()
        recs = obs_trace.read_jsonl(path)
    names = {r["name"] for r in recs}
    req = [r for r in recs if r["name"] == "serve.request"]
    ok = bool(req and {"serve.prefill", "serve.decode"} <= names
              and res.prefill_s > 0
              and all("tokens_per_s" in r for r in req))
    rows.append(Row(
        "obs/spans/serve_latency", 0.0, float(ok),
        note="derived = 1 when a served request exports serve.prefill/"
             "decode spans + the serve.request event; obs-floor=1"))


def run():
    key = jax.random.PRNGKey(0)
    rows = []
    _telemetry_rows(key, rows)
    _span_rows(key, rows)
    return rows


if __name__ == "__main__":
    raise SystemExit(bench_main(run))
