"""Paper Figure 5: Bayesian metric learning (Yang et al. 2007) on
isolet-like class-disjoint federated shards.

A = sum_k gamma_k v_k v_k^T (v_k = top-K eigenvectors of the data);
p(y_ij | pair) = sigma(y_ij * (mu - ||x_i - x_j||_A^2)), y in {+1,-1};
diagonal Gaussian prior on (gamma, mu). With z_k = ((x_i-x_j)^T v_k)^2 the
model is Bayesian logistic regression on pair features z — theta = (gamma,
mu) in R^{K+1}. Surrogates: diagonal Gaussians fitted to per-client SGLD
runs against the local likelihood (paper Sec 5.2, 'MCMC-based q_s').

Claims checked: FSGLD converges to better train/test log-likelihood than
DSGLD and with smaller variance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer
from repro import api
from repro.core import fit_bank_fisher, sample_local_likelihood
from repro.data import metric_pairs, metric_test_pairs

K = 10


def _features(data, vecs, z_scale=None):
    diff = data["xi"] - data["xj"]
    z = (diff @ vecs) ** 2                      # (..., K)
    if z_scale is None:
        z_scale = z.reshape(-1, K).std(0) + 1e-6
    z = z / z_scale                             # standardized: keeps the
    y = 2.0 * data["y"] - 1.0                   # Langevin step well inside
    return {"z": z, "y": y}, z_scale            # the stability limit


def log_lik(theta, batch):
    logit = theta[K] - batch["z"] @ theta[:K]
    return jnp.sum(jax.nn.log_sigmoid(batch["y"] * logit))


def avg_loglik(trace, batch):
    def one(theta):
        return log_lik(theta, batch) / batch["y"].shape[0]
    return float(jnp.mean(jax.vmap(one)(trace)))


def run():
    key = jax.random.PRNGKey(0)
    S = 10
    pairs_per_shard = int(400 * max(SCALE, 1))
    data, centers = metric_pairs(key, num_classes=20, dim=32, num_shards=S,
                                 pairs_per_shard=pairs_per_shard,
                                 class_sep=1.5)
    xall = jnp.concatenate([data["xi"].reshape(-1, 32),
                            data["xj"].reshape(-1, 32)])
    _, vecs = jnp.linalg.eigh(jnp.cov(xall, rowvar=False))
    vecs = vecs[:, -K:]                          # top-K eigenvectors
    shards, z_scale = _features(data, vecs)
    test, _ = _features(metric_test_pairs(jax.random.fold_in(key, 9),
                                          centers, num_pairs=600), vecs,
                        z_scale)

    theta0 = jnp.zeros(K + 1)
    # --- client-side surrogate fitting (once) ---
    samples = sample_local_likelihood(
        log_lik, shards, theta0, jax.random.fold_in(key, 1), minibatch=64,
        step_size=1e-5, num_steps=int(600 * max(SCALE, 1)), burn_in=300,
        thin=2, prior_precision=0.1)
    # Laplace/empirical-Fisher surrogates (paper App. F.2): correctly
    # N_s-scaled precisions, stable under delayed communication
    means = samples.mean(1)
    bank = fit_bank_fisher(log_lik, shards, means)

    rows = []
    total_steps = int(4000 * max(SCALE, 1))
    results = {}
    for method in ("dsgld", "fsgld"):
        samp = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), shards,
            minibatch=64, step_size=1e-5, method=method,
            surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                       if method == "fsgld"
                       else api.SurrogateSpec(kind="none")),
            schedule=api.Schedule(rounds=total_steps // 40,
                                  local_steps=40, thin=20))
        finals = []
        with Timer() as t:
            for rep in range(3):
                trace = samp.sample(jax.random.PRNGKey(10 + rep),
                                    theta0)[0]
                finals.append(trace[trace.shape[0] // 2:])
        us = t.us_per(3 * total_steps)
        tr_ll = [avg_loglik(tr, jax.tree.map(lambda a: a.reshape(
            (-1,) + a.shape[2:]), shards)) for tr in finals]
        te_ll = [avg_loglik(tr, test) for tr in finals]
        results[method] = (tr_ll, te_ll)
        rows.append(Row(f"fig5/{method}_train_ll", us,
                        float(jnp.mean(jnp.array(tr_ll)))))
        rows.append(Row(f"fig5/{method}_test_ll", us,
                        float(jnp.mean(jnp.array(te_ll)))))
        rows.append(Row(f"fig5/{method}_test_ll_std", us,
                        float(jnp.std(jnp.array(te_ll)))))
    rows.append(Row("fig5/fsgld_beats_dsgld_test", 0.0, float(
        jnp.mean(jnp.array(results["fsgld"][1]))
        >= jnp.mean(jnp.array(results["dsgld"][1])))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
