"""Paper Figure 1: gradient-estimator variance, Bernoulli likelihood.

30 coin tosses split into 3 equally-available shards with means 0.1 / 0.5 /
0.9 (federated non-IID). Mini-batches of 5. Compares the variance of the
SGLD (centralized), DSGLD, and FSGLD gradient estimators across theta.

Paper claim: DSGLD variance >> SGLD variance even in this simple case;
conducive gradients close most of the gap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer


def _grad_loglik(theta, x):
    return jnp.sum(x / theta - (1 - x) / (1 - theta))


def _grid_gaussian_fit(loglik_grid, grid):
    w = jnp.exp(loglik_grid - loglik_grid.max())
    w = w / w.sum()
    mu = jnp.sum(w * grid)
    var = jnp.sum(w * (grid - mu) ** 2)
    return mu, 1.0 / jnp.maximum(var, 1e-8)


def _gradient_matched_fit(grad_grid, loglik_grid, grid):
    """Remark 3: choose q_s minimising || grad log p(x_s|.) - grad log q_s ||
    over the region that matters — likelihood-weighted least squares of the
    *gradient field* onto the linear family -prec*(theta - mu)."""
    w = jnp.exp(loglik_grid - loglik_grid.max())
    w = w / w.sum()
    tbar = jnp.sum(w * grid)
    gbar = jnp.sum(w * grad_grid)
    cov_tg = jnp.sum(w * (grid - tbar) * (grad_grid - gbar))
    var_t = jnp.sum(w * (grid - tbar) ** 2)
    slope = cov_tg / jnp.maximum(var_t, 1e-10)
    prec = jnp.maximum(-slope, 1e-6)
    mu = tbar + gbar / prec
    return mu, prec


def run():
    key = jax.random.PRNGKey(0)
    S, n_s, m = 3, 10, 5
    means = jnp.array([0.1, 0.5, 0.9])
    x = (jax.random.uniform(key, (S, n_s)) < means[:, None]).astype(
        jnp.float32)
    N = S * n_s
    pooled = x.reshape(-1)
    draws = int(3000 * max(SCALE, 1))
    grid = jnp.linspace(0.02, 0.98, 97)

    # Gaussian surrogates of each shard likelihood. 'density' = moment fit
    # of the likelihood itself; 'gradient' = Remark-3 gradient-field fit
    # (beyond-paper: much better for skewed Bernoulli likelihoods).
    def shard_loglik(s, th):
        return jnp.sum(x[s] * jnp.log(th) + (1 - x[s]) * jnp.log(1 - th))
    fits = {"density": [], "gradient": []}
    for s in range(S):
        ll = jax.vmap(lambda th: shard_loglik(s, th))(grid)
        gg = jax.vmap(jax.grad(lambda th: shard_loglik(s, th)))(grid)
        fits["density"].append(_grid_gaussian_fit(ll, grid))
        fits["gradient"].append(_gradient_matched_fit(gg, ll, grid))
    banks = {}
    for kind, lst in fits.items():
        mus = jnp.stack([m for m, _ in lst])
        precs = jnp.stack([p for _, p in lst])
        prec_g = precs.sum()
        banks[kind] = (mus, precs, (precs * mus).sum() / prec_g, prec_g)
    mus, precs, mu_g, prec_g = banks["gradient"]

    def estimators(theta, k):
        k1, k2, k3 = jax.random.split(k, 3)
        idx = jax.random.randint(k1, (m,), 0, N)
        v_sgld = (N / m) * _grad_loglik(theta, pooled[idx])
        s = jax.random.randint(k2, (), 0, S)
        idx_s = jax.random.randint(k3, (m,), 0, n_s)
        v_dsgld = S * (n_s / m) * _grad_loglik(theta, x[s][idx_s])
        g_s = -prec_g * (theta - mu_g) + S * precs[s] * (theta - mus[s])
        return v_sgld, v_dsgld, v_dsgld + g_s

    rows = []
    thetas = jnp.array([0.3, 0.5, 0.7])
    fn = jax.jit(jax.vmap(estimators, in_axes=(None, 0)))
    ratio_acc, red_acc = [], []
    with Timer() as t:
        for th in thetas:
            vs, vd, vf = fn(th, jax.random.split(key, draws))
            sd_s, sd_d, sd_f = (float(jnp.std(v)) for v in (vs, vd, vf))
            ratio_acc.append(sd_d / sd_s)
            red_acc.append(sd_f / sd_d)
            rows.append(Row(f"fig1/std_sgld@{float(th):.1f}", 0, sd_s))
            rows.append(Row(f"fig1/std_dsgld@{float(th):.1f}", 0, sd_d))
            rows.append(Row(f"fig1/std_fsgld@{float(th):.1f}", 0, sd_f))
    us = t.us_per(3 * draws * 3)
    for r in rows:
        r.us_per_call = us
    mean_ratio = sum(ratio_acc) / len(ratio_acc)
    rows.append(Row("fig1/dsgld_over_sgld_std_ratio", us, mean_ratio,
                    note="paper: >1 (DSGLD noisier)"))
    rows.append(Row("fig1/fsgld_over_dsgld_std_ratio", us,
                    sum(red_acc) / len(red_acc),
                    note="beyond-paper gradient-matched q_s: < 1"))
    assert mean_ratio > 1.5, "paper claim violated: DSGLD not noisier"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
