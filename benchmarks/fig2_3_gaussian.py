"""Paper Figures 2-3: 2-D Gaussian mean under non-IID shards and delayed
communication — expressed through NAMED federation scenarios.

S=10 shards of 200 points from N(mu_s, I), mu_s ~ U[-6,6]^2; h=1e-4, m=10.
The delayed-communication axis is the registry's ``delayed-kx`` schedule
(communicate every k-th round, one local step per round — exactly k
shard-local updates between reassignments, the paper's x-axis) instead of
a hand-rolled local-update loop: DSGLD collapses toward the mixture of
local posteriors as the delay grows; FSGLD (analytic likelihood
surrogates, exactly the paper's choice) stays on the true posterior and
is insensitive to the delay.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer
from repro import api
from repro.core import analytic_gaussian_likelihood_surrogate, make_bank


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


# (method, registry scenario): the delayed-communication contrast of
# Figs. 2-3, enumerated by name — the schedule is lowered into the
# engine's scan, not rewired into the driver loop.
CASES = [
    ("dsgld", "identity"),
    ("dsgld", "delayed-10x"),
    ("dsgld", "delayed-100x"),
    ("fsgld", "identity"),
    ("fsgld", "delayed-100x"),
]


def run():
    key = jax.random.PRNGKey(0)
    S, n, d = 10, 200, 2
    mus = jax.random.uniform(key, (S, d), minval=-6, maxval=6)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    N = S * n
    post_mean = x.reshape(-1, d).sum(0) / (1 + N)

    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    total_steps = int(30_000 * max(SCALE, 1))

    rows = []
    for method, scenario in CASES:
        samp = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), {"x": x},
            minibatch=10, step_size=1e-4, method=method,
            surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                       if method == "fsgld"
                       else api.SurrogateSpec(kind="none")),
            schedule=api.Schedule(rounds=total_steps, local_steps=1),
            federation=scenario)
        with Timer() as t:
            trace = samp.sample(jax.random.PRNGKey(2), jnp.zeros(d))[0]
        trace = trace[trace.shape[0] // 2:]
        mse = float(jnp.sum((trace.mean(0) - post_mean) ** 2))
        rows.append(Row(f"fig2/{method}_{scenario}_mse",
                        t.us_per(total_steps), mse))
    by = {r.name: r.derived for r in rows}
    # paper claims encoded as derived indicator rows
    rows.append(Row("fig3/dsgld_degrades_with_delay", 0.0,
                    float(by["fig2/dsgld_delayed-100x_mse"]
                          > 5 * by["fig2/dsgld_identity_mse"])))
    rows.append(Row("fig3/fsgld_insensitive_to_delay", 0.0,
                    float(by["fig2/fsgld_delayed-100x_mse"]
                          < 3 * max(by["fig2/fsgld_identity_mse"], 1e-5))))
    rows.append(Row("fig3/fsgld_beats_dsgld_at_100x", 0.0,
                    float(by["fig2/fsgld_delayed-100x_mse"]
                          < 0.1 * by["fig2/dsgld_delayed-100x_mse"])))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
