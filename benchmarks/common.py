"""Shared helpers for the per-figure/table benchmarks.

Each benchmark module exposes ``run() -> list[Row]``; benchmarks/run.py
prints the aggregate ``name,us_per_call,derived`` CSV (us_per_call = wall
time per sampler step / estimator evaluation on this CPU container;
``derived`` = the figure's headline metric).

Scale with REPRO_BENCH_SCALE (default 1; paper-scale ~10).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Optional

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1"))


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: float
    note: str = ""
    # bytes on the wire per chain per communication round, BOTH
    # directions — client→server upload plus server→client broadcast,
    # uncompressed legs counted at 4 bytes/coordinate (the compressed-
    # rounds lanes); None on rows where the wire cost is not the point.
    # Additive envelope column: absent->null in old baselines, ignored by
    # consumers that don't know it.
    bytes_per_round: Optional[float] = None

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived:.6g}"

    def ok(self) -> bool:
        """A row with a non-finite metric is a FAILED measurement — the CI
        bench lane must gate on it, not archive it."""
        fine = (math.isfinite(self.us_per_call)
                and math.isfinite(self.derived))
        if self.bytes_per_round is not None:
            fine = fine and math.isfinite(self.bytes_per_round) \
                and self.bytes_per_round > 0
        return fine


def rows_as_json(rows: list, *, failures: int = 0,
                 lane_seconds: Optional[dict] = None) -> dict:
    """The standard BENCH json envelope every benchmark emits (and CI
    uploads as an artifact): schema tag + scale + rows + failure count.
    ``lane_seconds`` maps lane name -> wall-clock seconds that lane took
    (additive column: absent in old baselines, ignored by consumers that
    don't know it)."""
    env = {
        "schema": "repro-bench-v1",
        "scale": SCALE,
        "failures": failures,
        "rows": [dataclasses.asdict(r) for r in rows],
    }
    if lane_seconds is not None:
        env["lane_seconds"] = {
            k: round(float(v), 3) for k, v in lane_seconds.items()}
    return env


def write_json(rows: list, path: str, *, failures: int = 0,
               lane_seconds: Optional[dict] = None) -> None:
    with open(path, "w") as f:
        json.dump(rows_as_json(rows, failures=failures,
                               lane_seconds=lane_seconds), f, indent=1)


def bench_main(run_fn) -> int:
    """Shared __main__ for single-benchmark modules: print the CSV, honor
    ``--json PATH``, exit non-zero when any row is non-finite or run_fn
    raises (so CI lanes actually gate)."""
    import argparse
    import traceback

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the standard BENCH json envelope here")
    args = ap.parse_args()
    try:
        rows = list(run_fn())
    except Exception:  # noqa: BLE001 - report, then fail the lane
        traceback.print_exc()
        return 1
    bad = [r for r in rows if not r.ok()]
    for r in rows:
        print(r.csv())
    for r in bad:
        print(f"# NON-FINITE: {r.name}")
    if args.json:
        write_json(rows, args.json, failures=len(bad))
    return 1 if bad else 0


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    def us_per(self, calls: int) -> float:
        return 1e6 * self.dt / max(calls, 1)
