"""Shared helpers for the per-figure/table benchmarks.

Each benchmark module exposes ``run() -> list[Row]``; benchmarks/run.py
prints the aggregate ``name,us_per_call,derived`` CSV (us_per_call = wall
time per sampler step / estimator evaluation on this CPU container;
``derived`` = the figure's headline metric).

Scale with REPRO_BENCH_SCALE (default 1; paper-scale ~10).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1"))


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: float
    note: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived:.6g}"


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    def us_per(self, calls: int) -> float:
        return 1e6 * self.dt / max(calls, 1)
