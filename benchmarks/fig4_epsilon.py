"""Paper Figure 4: the bound constants — eps_s^2 (FSGLD, Theorem 2) vs
gamma_s^2 (DSGLD, Theorem 1 / Assumption 1) on the Gaussian-mean model,
grid-approximated over theta in [-6,6]^2.

gamma_s^2 = max_{theta, x_i in shard s} ||grad log p(x_i|theta)||^2
eps_s^2   = max_theta avg_i ||grad log p(x_i|theta)
                              - N_s^-1 grad log q_s(theta)||^2

With the analytic surrogate q_s = N(theta | xbar_s, I/N_s) the FSGLD
residual is x_i - xbar_s (theta-independent): eps_s^2 << gamma_s^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, Timer


def run():
    key = jax.random.PRNGKey(0)
    S, n, d = 10, 200, 2
    mus = jax.random.uniform(key, (S, d), minval=-6, maxval=6)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    g = jnp.linspace(-6, 6, 25)
    grid = jnp.stack(jnp.meshgrid(g, g), -1).reshape(-1, d)

    rows = []
    with Timer() as t:
        # grad log p(x_i|theta) = x_i - theta
        def gamma2(s):
            diff = x[s][:, None, :] - grid[None, :, :]
            return jnp.max(jnp.sum(diff ** 2, -1))

        def eps2(s):
            xbar = x[s].mean(0)
            res = x[s] - xbar  # theta cancels with the exact surrogate
            return jnp.mean(jnp.sum(res ** 2, -1))

        g2 = jnp.stack([gamma2(s) for s in range(S)])
        e2 = jnp.stack([eps2(s) for s in range(S)])
    us = t.us_per(S * 2)
    for s in range(S):
        rows.append(Row(f"fig4/gamma2_shard{s}", us, float(g2[s])))
        rows.append(Row(f"fig4/eps2_shard{s}", us, float(e2[s])))
    ratio = float(jnp.max(e2 / g2))
    rows.append(Row("fig4/max_eps2_over_gamma2", us, ratio,
                    note="paper: << 1 for every shard"))
    assert ratio < 0.25, f"paper claim violated: eps^2 !<< gamma^2 ({ratio})"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
