"""Paper Remark 1: the exploration knob alpha scaling the conducive
gradient (Eq. 7). alpha=0 recovers DSGLD; alpha=1 is FSGLD; intermediate
values trade variance reduction against surrogate trust.

Ablation on the Sec 5.1 Gaussian-mean model with 100 local updates
(the regime where DSGLD collapses to the local-posterior mixture).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer
from repro import api
from repro.core import analytic_gaussian_likelihood_surrogate, make_bank


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def run():
    key = jax.random.PRNGKey(0)
    S, n, d = 10, 200, 2
    mus = jax.random.uniform(key, (S, d), minval=-6, maxval=6)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, n, d))
    post_mean = x.reshape(-1, d).sum(0) / (1 + S * n)
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    steps = int(20_000 * max(SCALE, 1))

    rows = []
    mses = {}
    for alpha in (0.0, 0.25, 0.5, 1.0, 1.5):
        samp = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), {"x": x},
            minibatch=10, step_size=1e-4, alpha=alpha,
            surrogate=api.SurrogateSpec(kind="diag", bank=bank),
            schedule=api.Schedule(rounds=steps // 100, local_steps=100,
                                  thin=10))
        with Timer() as t:
            tr = samp.sample(jax.random.PRNGKey(2), jnp.zeros(d))[0]
        tr = tr[tr.shape[0] // 2:]
        mse = float(jnp.sum((tr.mean(0) - post_mean) ** 2))
        mses[alpha] = mse
        rows.append(Row(f"remark1/alpha{alpha}_mse", t.us_per(steps), mse))
    # with EXACT surrogates alpha=1 should be optimal (full cancellation)
    rows.append(Row("remark1/alpha1_best", 0.0,
                    float(mses[1.0] <= min(mses.values()) * 1.5)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
