"""Fused-update kernel micro-benchmark: wall time per call of the Pallas
kernel (interpret mode on this CPU container) vs the unfused pure-jnp path,
plus the HBM-traffic model that justifies the fusion on TPU
(7 passes -> 2.5 passes over P)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer, bench_main
from repro.kernels import ops, ref


def run():
    # SCALE < 1 shrinks below the default 1M params (CI bench-smoke runs
    # SCALE=0.01 -> ~10k); floor keeps at least a few kernel blocks live.
    P = max(int(2**20 * SCALE), 2**12)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    th = jax.random.normal(ks[0], (P,))
    g = jax.random.normal(ks[1], (P,))
    mg = jax.random.normal(ks[2], (P,))
    ms = jax.random.normal(ks[3], (P,))
    lg = jnp.abs(jax.random.normal(ks[4], (P,))) + 0.1
    ls = jnp.abs(jax.random.normal(ks[5], (P,))) + 0.1
    kw = dict(h=1e-4, scale=100.0, f_s=0.1, prior_prec=1.0, alpha=1.0,
              temperature=1.0)
    seed = jnp.uint32(1)

    fused = jax.jit(lambda *a: ops.fused_update_flat(
        a[0], a[1], seed, mu_g=a[2], mu_s=a[3], lam_g=a[4], lam_s=a[5],
        **kw))
    unfused = jax.jit(lambda *a: ref.fsgld_update_flat(
        a[0], a[1], seed, mu_g=a[2], mu_s=a[3], lam_g=a[4], lam_s=a[5],
        **kw))
    args = (th, g, mg, ms, lg, ls)
    fused(*args).block_until_ready()
    unfused(*args).block_until_ready()

    reps = 5
    with Timer() as tf:
        for _ in range(reps):
            fused(*args).block_until_ready()
    with Timer() as tu:
        for _ in range(reps):
            unfused(*args).block_until_ready()

    rows = [
        Row("kernel/fused_us", tf.us_per(reps), tf.us_per(reps),
            note="interpret mode; TPU path identical"),
        Row("kernel/unfused_us", tu.us_per(reps), tu.us_per(reps)),
        # HBM model: unfused reads th,g,mg,ms,lg,ls + writes noise + out
        # (8P x 4B); fused reads 6 operands + writes out, noise in-register
        Row("kernel/hbm_passes_unfused", 0.0, 8.0),
        Row("kernel/hbm_passes_fused", 0.0, 7.0,
            note="xi never materialised; scalar variant: 5.0"),
    ]
    return rows


if __name__ == "__main__":
    raise SystemExit(bench_main(run))
