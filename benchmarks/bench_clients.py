"""Streamed client-axis scaling benchmark: memory vs client count.

Sweeps synthetic client populations of 10^2 / 10^4 / 10^6
(``repro.fed.SyntheticClientSource`` — lazy, per-client fold_in
generation) through the facade with ``Stream(resident=K)``: only K
clients are ever materialized on device, the host prefetches the next
window's shards while the scan segment runs, and fault-free streamed
runs are bitwise identical to the resident path.

Published rows per client count N:

  * ``clients/streamed/N*/throughput``     — chain-steps/s (baseline-
    compared like every throughput row when a baseline carries it);
  * ``clients/streamed/N*/peak_device_mb`` — peak live device bytes
    across stream windows (``jax.live_arrays()`` sampled from the
    engine's ``stream_hook``), gated ABSOLUTELY by the committed
    ``client-ceiling=`` mark: materializing all 10^6 clients (~4 GB of
    token shards) would blow the ceiling by an order of magnitude;
  * ``clients/parity/N100``                — 0/1 indicator (floor 1):
    streamed final states bitwise equal the resident oracle at the one
    N where the oracle comfortably fits;
  * ``clients/streamed/peak_host_rss_mb``  — process peak RSS after the
    10^6-client run (covers host staging buffers the device gate can't
    see), also ceiling-gated.

The shapes are FIXED (SCALE ignored): the memory ceilings are absolute
committed gates (benchmarks/check_regression.py ``client-floor=`` /
``client-ceiling=``), so the problem size must not drift with the
environment.
"""
from __future__ import annotations

import resource
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row, bench_main
from repro import api

# fixed shapes — see module docstring (ceilings are committed absolutes)
SHARD, SEQ, VOCAB = 32, 16, 256
CHAINS, ROUNDS, T_LOCAL = 4, 6, 4
RESIDENT, WINDOW = 32, 2
SWEEP = (100, 10_000, 1_000_000)
# ceilings: 10^6 clients materialized would be ~4 GB device-side alone;
# the streamed path holds K=32 client shards (~130 KB) plus chain state
DEVICE_CEIL_MB = 512.0
RSS_CEIL_MB = 3072.0


def token_log_lik(theta, batch):
    return jnp.sum(jax.nn.log_softmax(theta)[batch["labels"]])


def _live_mb() -> float:
    return sum(a.size * a.dtype.itemsize
               for a in jax.live_arrays()) / 2**20


def _fsgld(src, stream):
    return api.FSGLD(
        api.Posterior(token_log_lik), src, minibatch=8, step_size=1e-4,
        method="dsgld", surrogate=api.SurrogateSpec(kind="none"),
        schedule=api.Schedule(rounds=ROUNDS, local_steps=T_LOCAL,
                              n_chains=CHAINS, reassign="permutation"),
        execution=api.Execution(executor="vmap", collect=False,
                                stream=stream))


def run():
    rows = []
    theta0 = jnp.zeros((VOCAB,))
    for N in SWEEP:
        src = api.SyntheticClientSource(
            jax.random.PRNGKey(7), num_clients=N, shard_size=SHARD,
            seq_len=SEQ, vocab_size=VOCAB)
        f = _fsgld(src, api.Stream(resident=RESIDENT, window=WINDOW))
        dev_peak = [0.0]
        f.engine.stream_hook = lambda i, win, _p=dev_peak: \
            _p.__setitem__(0, max(_p[0], _live_mb()))
        # warm up (compiles the full-window + tail executor variants),
        # then best-of-2 — same discipline as bench_chains
        jax.block_until_ready(f.sample(jax.random.PRNGKey(1), theta0))
        dt = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            out = f.sample(jax.random.PRNGKey(1), theta0)
            jax.block_until_ready(out)
            dt = min(dt, time.perf_counter() - t0)
        steps = ROUNDS * T_LOCAL * CHAINS
        rows.append(Row(f"clients/streamed/N{N}/throughput",
                        1e6 * dt / steps, steps / dt,
                        note="derived = chain-steps/s"))
        rows.append(Row(
            f"clients/streamed/N{N}/peak_device_mb", 0.0, dev_peak[0],
            note=f"derived = peak live device MB across stream windows "
                 f"(resident K={RESIDENT} of {N} clients); "
                 f"client-ceiling={DEVICE_CEIL_MB:g}"))
        if N == SWEEP[0]:
            ref = _fsgld(src, None).sample(jax.random.PRNGKey(1), theta0)
            same = all(bool(jnp.array_equal(a, b)) for a, b in
                       zip(jax.tree.leaves(ref), jax.tree.leaves(out)))
            rows.append(Row(
                f"clients/parity/N{N}", 0.0, float(same),
                note="derived = 1 iff streamed final states are bitwise "
                     "identical to the resident oracle; client-floor=1"))
        del f, src, out
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    rows.append(Row(
        "clients/streamed/peak_host_rss_mb", 0.0, rss_mb,
        note="derived = process peak RSS MB after the 10^6-client "
             "streamed run (materialize-all would need ~4 GB of shards "
             "on top of the interpreter); "
             f"client-ceiling={RSS_CEIL_MB:g}"))
    return rows


if __name__ == "__main__":
    raise SystemExit(bench_main(run))
