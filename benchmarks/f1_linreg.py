"""Paper Appendix F.1: Bayesian linear regression on three datasets
(synthetic stand-ins matched in (n, d) to concrete / noise / conductivity).

Analytic surrogates: q_s(theta) = N(theta | mu_s, Sigma_s) with
Sigma_s^-1 = X_s^T X_s / sigma^2 and mu_s the shard least-squares solution
(the exact local likelihood). Claims: FSGLD reaches lower/faster test MSE
than DSGLD and with lower variance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, SCALE, Timer
from repro import api
from repro.core import make_bank
from repro.data import linreg_datasets, split_shards


def run():
    key = jax.random.PRNGKey(0)
    datasets = linreg_datasets(key)
    S = 10
    rows = []
    for name, ds in datasets.items():
        n = ds["x"].shape[0]
        n_train = int(0.8 * n) // S * S
        xtr, ytr = ds["x"][:n_train], ds["y"][:n_train]
        xte, yte = ds["x"][n_train:], ds["y"][n_train:]
        sig2 = float(ds["sigma"]) ** 2
        shards = split_shards({"x": xtr, "y": ytr}, S)

        def log_lik(theta, batch):
            r = batch["y"] - batch["x"] @ theta
            return -0.5 * jnp.sum(r * r) / sig2

        # analytic diagonal surrogates (diagonal of the exact precision)
        def fit_shard(xs, ys):
            prec_full = xs.T @ xs / sig2
            mu = jnp.linalg.solve(prec_full
                                  + 1e-6 * jnp.eye(xs.shape[1]),
                                  xs.T @ ys / sig2)
            return mu, jnp.diag(prec_full)
        mus, precs = jax.vmap(fit_shard)(shards["x"], shards["y"])
        bank = make_bank(mus, precs, "diag")

        d = xtr.shape[1]
        total_steps = int(4000 * max(SCALE, 1))
        for method in ("dsgld", "fsgld"):
            samp = api.FSGLD(
                api.Posterior(log_lik, prior_precision=1.0), shards,
                minibatch=10, step_size=1e-6, method=method,
                surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                           if method == "fsgld"
                           else api.SurrogateSpec(kind="none")),
                schedule=api.Schedule(rounds=total_steps // 40,
                                      local_steps=40, thin=20))
            mses = []
            with Timer() as t:
                for rep in range(3):
                    tr = samp.sample(jax.random.PRNGKey(30 + rep),
                                     jnp.zeros(d))[0]
                    tr = tr[tr.shape[0] // 2:]
                    pred = jnp.mean(tr @ xte.T, axis=0)
                    mses.append(float(jnp.mean((pred - yte) ** 2)))
            us = t.us_per(3 * total_steps)
            rows.append(Row(f"f1/{name}_{method}_test_mse", us,
                            float(jnp.mean(jnp.array(mses)))))
            rows.append(Row(f"f1/{name}_{method}_test_mse_std", us,
                            float(jnp.std(jnp.array(mses)))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
