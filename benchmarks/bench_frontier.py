"""Rival-sampler frontier: convergence vs wire bytes, head-to-head.

The paper's FSGLD, the DSGLD baseline it corrects, and FA-LD
(arXiv:2112.05120, server-averaged Langevin clients) race on ONE
fixed-seed Gaussian-mean posterior — S=10 strongly non-IID shards,
mu_s ~ U[-6,6]^d — across federation scenarios that span the
communication axis: exact every-round exchange, 5x-delayed rounds, and
ELF-style bidirectionally compressed rounds (arXiv:2303.04622). Every
cell reports

  * ``derived``          — posterior-mean MSE of the second-half trace
                           against the analytic posterior mean (the
                           convergence axis), and
  * ``bytes_per_round``  — estimated wire bytes per chain per
                           communication round, BOTH directions
                           (``Compression.bytes_per_round``; the note
                           carries the whole-run total), the cost axis,

so the CSV IS the convergence-vs-bytes frontier. Three claims are gated
same-run by ``check_regression.py::check_frontier_bounds`` via
``frontier-floor=`` / ``frontier-ceiling=`` note marks (absolute,
machine-portable — statistics of a fixed-seed problem, like the calib
bounds):

  * conducive gradients survive delay: FSGLD's delayed-5x MSE stays
    under an absolute ceiling while DSGLD's indicator — delayed MSE
    blowing up by >5x over FSGLD's — fails for DSGLD;
  * compression saves wire: every compressed cell's bytes_per_round is
    strictly below the exact exchange's;
  * FA-LD is exactly its oracle: a small engine run with
    ``aggregation='fald'`` is bitwise-identical to
    ``repro.rivals.fald_run_vmap`` (indicator row, floor 1).

Sizes are FIXED (REPRO_BENCH_SCALE is ignored): the gates are
statistical properties and shrinking the run only widens the noise on
the quantities under gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, Timer
from repro import api
from repro.core import analytic_gaussian_likelihood_surrogate, make_bank
from repro.fed import SCENARIOS
from repro.rivals import fald_run_vmap

# committed gate bounds — measured ~{fsgld: 2e-3..2e-2} on the fixed
# seed; the ceilings leave ~5x headroom, the DSGLD-degrades factor is
# the same 5x margin fig2_3 uses
FSGLD_MSE_CEILING = 0.1
DSGLD_DEGRADES_FACTOR = 5.0

S, N_PER, D = 10, 200, 64
ROUNDS, LOCAL, CHAINS, MINIBATCH = 4000, 1, 4, 10

# the frontier grid: every method crossed with the communication axis.
# The compressed cell is the BIDIRECTIONAL qsgd scenario — hard top-k
# (elf-bidir-topk-1%) destabilizes the non-averaging methods on this
# problem (error feedback accumulates the full drift and dumps it one
# giant coordinate at a time); FA-LD tolerates it (the averaging
# re-synchronizes clients), which the oracle gate row exercises.
METHODS = ("dsgld", "fsgld", "fald")
SCENARIO_NAMES = ("identity", "delayed-5x", "elf-bidir-qsgd-8bit")


def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)


def _problem():
    key = jax.random.PRNGKey(0)
    mus = jax.random.uniform(key, (S, D), minval=-6, maxval=6)
    x = mus[:, None, :] + jax.random.normal(jax.random.fold_in(key, 1),
                                            (S, N_PER, D))
    post_mean = x.reshape(-1, D).sum(0) / (1 + S * N_PER)
    mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(x)
    bank = make_bank(mu_s, prec_s, "diag")
    return x, post_mean, bank


def _cell(x, bank, method, scenario):
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), {"x": x},
        minibatch=MINIBATCH, step_size=1e-4, method=method,
        surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                   if method == "fsgld"
                   else api.SurrogateSpec(kind="none")),
        schedule=api.Schedule(rounds=ROUNDS, local_steps=LOCAL,
                              n_chains=CHAINS),
        federation=scenario)
    with Timer() as t:
        trace = samp.sample(jax.random.PRNGKey(2), jnp.zeros(D))
    return trace, t.us_per(ROUNDS * LOCAL * CHAINS)


def _fald_oracle_row():
    """Tiny bitwise engine-vs-oracle run: the regression pin that keeps
    the frontier's FA-LD cells honest (indicator, frontier-floor=1)."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (4, 8, 3))
    theta0 = jnp.zeros(3)
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), {"x": x},
        minibatch=4, method="fald",
        surrogate=api.SurrogateSpec(kind="none"),
        schedule=api.Schedule(rounds=4, local_steps=2, n_chains=4),
        federation="elf-bidir-topk-1%")
    eng = np.asarray(samp.sample(jax.random.PRNGKey(3), theta0))
    orc = np.asarray(fald_run_vmap(
        log_lik, samp.cfg, samp.data, 4, jax.random.PRNGKey(3), theta0,
        4, n_chains=4, federation="elf-bidir-topk-1%"))
    return Row("frontier/gate/fald_matches_oracle", 0.0,
               float(np.array_equal(eng, orc)),
               note="engine aggregation='fald' bitwise == rivals.fald "
                    "oracle (compressed bidir scenario); "
                    "frontier-floor=1")


def run():
    x, post_mean, bank = _problem()
    rows, mse = [], {}
    for method in METHODS:
        for scenario in SCENARIO_NAMES:
            trace, us = _cell(x, bank, method, scenario)
            half = trace[:, trace.shape[1] // 2:]          # (C, T/2, D)
            m = float(jnp.sum((half.mean((0, 1)) - post_mean) ** 2))
            mse[(method, scenario)] = m
            fed = SCENARIOS[scenario]
            bpr = fed.compression.bytes_per_round(D)
            n_comm = ROUNDS // fed.schedule.delay
            note = (f"derived = posterior-mean MSE (second-half trace); "
                    f"total wire ~{bpr * n_comm / 1e3:.1f} kB/chain over "
                    f"{n_comm} comm rounds")
            if method == "fsgld":
                note += f"; frontier-ceiling={FSGLD_MSE_CEILING}"
            rows.append(Row(f"frontier/{method}/{scenario}", us, m,
                            note=note, bytes_per_round=bpr))
    # claim 1: FSGLD converges under delay where DSGLD degrades
    rows.append(Row(
        "frontier/gate/dsgld_degrades_fsgld_survives_delay", 0.0,
        float(mse[("dsgld", "delayed-5x")]
              > DSGLD_DEGRADES_FACTOR * mse[("fsgld", "delayed-5x")]),
        note=f"dsgld delayed-5x MSE > {DSGLD_DEGRADES_FACTOR:g}x fsgld's "
             f"(the paper's conducive-gradient claim on the frontier); "
             f"frontier-floor=1"))
    # claim 2: every compressed cell moves strictly fewer bytes than the
    # exact exchange (within this run — no baseline needed)
    exact = SCENARIOS["identity"].compression.bytes_per_round(D)
    comp_b = [SCENARIOS[s].compression.bytes_per_round(D)
              for s in SCENARIO_NAMES
              if not SCENARIOS[s].compression.identity]
    rows.append(Row(
        "frontier/gate/compressed_below_exact", 0.0,
        float(bool(comp_b) and max(comp_b) < exact),
        note=f"compressed bytes/round (max {max(comp_b):g}) strictly "
             f"below exact exchange ({exact:g}); frontier-floor=1"))
    # claim 3: FA-LD engine == oracle, bitwise
    rows.append(_fald_oracle_row())
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    raise SystemExit(bench_main(run))
