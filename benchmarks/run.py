"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

  fig1  — gradient-estimator variance (Bernoulli, non-IID shards)
  fig2/3 — Gaussian mean: DSGLD mixture-collapse vs FSGLD, local-update sweep
  fig4  — bound constants eps_s^2 vs gamma_s^2
  fig5  — Bayesian metric learning (class-disjoint shards)
  table1 — Bayesian MLP, IID vs non-IID label imbalance
  f1    — Bayesian linear regression (App. F.1)
  kernel — fused FSGLD Pallas update micro-bench

REPRO_BENCH_SCALE=10 approaches paper-scale chain lengths.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_kernel, f1_linreg, fig1_variance,
                            fig2_3_gaussian, fig4_epsilon,
                            fig5_metric_learning, remark1_alpha,
                            table1_bnn)
    modules = [
        ("fig1", fig1_variance), ("fig2_3", fig2_3_gaussian),
        ("fig4", fig4_epsilon), ("fig5", fig5_metric_learning),
        ("table1", table1_bnn), ("f1", f1_linreg),
        ("remark1", remark1_alpha), ("kernel", bench_kernel),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                print(row.csv(), flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
