"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

  fig1  — gradient-estimator variance (Bernoulli, non-IID shards)
  fig2/3 — Gaussian mean: DSGLD mixture-collapse vs FSGLD under named
           delayed-communication federation scenarios
  fig4  — bound constants eps_s^2 vs gamma_s^2
  fig5  — Bayesian metric learning (class-disjoint shards)
  table1 — Bayesian MLP, IID vs non-IID label imbalance
  f1    — Bayesian linear regression (App. F.1)
  remark1 — alpha exploration knob sweep
  kernel — fused FSGLD Pallas update micro-bench
  chains — mesh chain-runtime scaling (chains x shards)
  calib — K-draw ensemble calibration gates (NLL/ECE/coverage with
          absolute calib-floor=/calib-ceiling= bounds in the notes,
          enforced by check_regression.py; fixed sizes, SCALE ignored)
  frontier — rival samplers head-to-head (DSGLD / FSGLD / FA-LD across
          federation scenarios): posterior-mean MSE vs wire bytes per
          round, with absolute frontier-floor=/frontier-ceiling= gates
          (check_regression.py; fixed sizes, SCALE ignored)
  clients — streamed client-axis scaling (10^2/10^4/10^6 synthetic
          clients, resident-K windows): steps/s + peak device/host
          memory with absolute client-floor=/client-ceiling= gates
          (check_regression.py; fixed sizes, SCALE ignored)
  obs   — observability overhead: telemetry-on vs -off steps/s (gated
          < 5% via speedup-floor=0.95) + 0/1 span-export indicators
          (streamed prefetch overlap, serving latency) with absolute
          obs-floor= gates (check_regression.py)

REPRO_BENCH_SCALE=10 approaches paper-scale chain lengths;
REPRO_BENCH_SCALE=0.01 is the CI bench-smoke setting.

Exit status is the CI gate: non-zero when any sub-benchmark raises OR
emits a non-finite row (a NaN throughput is a failed measurement, not a
result). ``--json`` writes the standard BENCH envelope for artifact
upload; ``--only kernel,chains`` selects lanes.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    from benchmarks import (bench_calibration, bench_chains,
                            bench_clients, bench_frontier, bench_kernel,
                            bench_obs, f1_linreg, fig1_variance,
                            fig2_3_gaussian, fig4_epsilon,
                            fig5_metric_learning, remark1_alpha,
                            table1_bnn)
    from benchmarks.common import write_json

    modules = [
        ("fig1", fig1_variance), ("fig2_3", fig2_3_gaussian),
        ("fig4", fig4_epsilon), ("fig5", fig5_metric_learning),
        ("table1", table1_bnn), ("f1", f1_linreg),
        ("remark1", remark1_alpha), ("kernel", bench_kernel),
        ("chains", bench_chains), ("calib", bench_calibration),
        ("frontier", bench_frontier), ("clients", bench_clients),
        ("obs", bench_obs),
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write aggregated BENCH json here")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    args = ap.parse_args(argv)
    if args.only:
        wanted = set(args.only.split(","))
        unknown = wanted - {name for name, _ in modules}
        if unknown:
            print(f"unknown benchmarks: {sorted(unknown)}", file=sys.stderr)
            return 2
        modules = [(n, m) for n, m in modules if n in wanted]

    print("name,us_per_call,derived")
    all_rows = []
    failures = 0
    lane_seconds = {}
    for name, mod in modules:
        t0 = time.time()
        try:
            rows = list(mod.run())
        except Exception:  # noqa: BLE001 - count and keep going
            failures += 1
            lane_seconds[name] = time.time() - t0
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
            continue
        bad = [r for r in rows if not r.ok()]
        for row in rows:
            print(row.csv(), flush=True)
        if bad:
            failures += 1
            print(f"# {name} FAILED: non-finite rows "
                  f"{[r.name for r in bad]}", flush=True)
        all_rows.extend(rows)
        lane_seconds[name] = time.time() - t0
        print(f"# {name} done in {lane_seconds[name]:.1f}s", flush=True)
    if args.json:
        write_json(all_rows, args.json, failures=failures,
                   lane_seconds=lane_seconds)
    if failures:
        print(f"# {failures} benchmark(s) FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
