"""Calibration metrics for the served posterior (NLL / ECE / coverage).

The point of serving K draws instead of one (``repro.serve``) is BETTER
predictive distributions — these metrics are how that claim is scored,
and the bench lane gates on them (``benchmarks/bench_calibration.py``
rows, floors enforced by ``benchmarks/check_regression.py``): the
K-draw ensemble must beat the single-draw baseline on NLL/ECE and its
predictive intervals must actually cover.

Conventions: everything takes plain arrays (no model objects), computes
in float64 on the host, and returns python floats — the metrics are host-side scoring
code, not jit targets. Classification metrics take per-draw
probabilities ``probs_k`` of shape (K, N, C) (K=1 for a point model);
the predictive distribution is the draw mean. Analytic goldens for each
metric live in tests/test_calibration.py.
"""
from __future__ import annotations

import numpy as np

__all__ = ["nll_categorical", "nll_gaussian_mixture", "ece_from_probs",
           "ece_binary", "interval_coverage"]


def _predictive(probs_k) -> np.ndarray:
    p = np.asarray(probs_k, np.float64)
    assert p.ndim == 3, f"probs_k must be (K, N, C), got {p.shape}"
    return p.mean(0)  # (N, C) Bayesian model average


def nll_categorical(probs_k, labels, *, eps: float = 1e-12) -> float:
    """Mean negative log-likelihood of ``labels`` (N,) under the
    ensemble predictive mean — THE proper score posterior averaging is
    supposed to improve (log p̄ >= mean_k log p_k by Jensen)."""
    pred = _predictive(probs_k)
    labels = np.asarray(labels).astype(np.int64)
    p_true = pred[np.arange(pred.shape[0]), labels]
    return float(-np.mean(np.log(np.clip(p_true, eps, None))))


def ece_from_probs(probs_k, labels, *, n_bins: int = 15) -> float:
    """Expected calibration error of the predictive mean: confidence =
    max-prob, equal-width bins on [0, 1], weighted mean |acc − conf|
    (the standard Guo et al. estimator). 0 = perfectly calibrated."""
    pred = _predictive(probs_k)
    labels = np.asarray(labels).astype(np.int64)
    conf = pred.max(-1)
    correct = (pred.argmax(-1) == labels).astype(np.float64)
    # right-closed bins; conf==0 lands in bin 0
    idx = np.clip(np.ceil(conf * n_bins).astype(np.int64) - 1, 0,
                  n_bins - 1)
    ece, n = 0.0, conf.shape[0]
    for b in range(n_bins):
        m = idx == b
        if not m.any():
            continue
        ece += (m.sum() / n) * abs(correct[m].mean() - conf[m].mean())
    return float(ece)


def ece_binary(p1_k, labels, *, n_bins: int = 15) -> float:
    """Binary convenience wrapper: ``p1_k`` (K, N) per-draw P(y=1) ->
    two-column ``ece_from_probs``."""
    p1 = np.asarray(p1_k, np.float64)
    assert p1.ndim == 2, f"p1_k must be (K, N), got {p1.shape}"
    probs = np.stack([1.0 - p1, p1], -1)
    return ece_from_probs(probs, labels, n_bins=n_bins)


def nll_gaussian_mixture(means_k, scales_k, targets) -> float:
    """Regression NLL under the K-component predictive mixture
    (1/K) Σ_k N(y | mu_k, sigma_k²) — the ensemble's predictive
    distribution for a Gaussian likelihood head. ``means_k``/``scales_k``
    are (K, N); K=1 is the plain Gaussian NLL."""
    mu = np.asarray(means_k, np.float64)
    sig = np.asarray(scales_k, np.float64)
    y = np.asarray(targets, np.float64)[None]
    assert mu.ndim == 2 and mu.shape == sig.shape, (mu.shape, sig.shape)
    logp_k = (-0.5 * ((y - mu) / sig) ** 2 - np.log(sig)
              - 0.5 * np.log(2 * np.pi))  # (K, N)
    # logsumexp over draws, stable
    m = logp_k.max(0)
    logp = m + np.log(np.exp(logp_k - m).mean(0))
    return float(-logp.mean())


def interval_coverage(samples, targets, *, level: float = 0.9) -> float:
    """Fraction of ``targets`` (N,) inside the central ``level``
    predictive interval of ``samples`` (K, N) — K posterior-predictive
    draws per example. A calibrated posterior covers ≈ ``level``; the
    bench gate brackets it from both sides (under- AND over-confidence
    fail)."""
    s = np.asarray(samples, np.float64)
    assert s.ndim == 2, f"samples must be (K, N), got {s.shape}"
    alpha = (1.0 - level) / 2
    lo = np.quantile(s, alpha, axis=0)
    hi = np.quantile(s, 1.0 - alpha, axis=0)
    y = np.asarray(targets, np.float64)
    return float(np.mean((y >= lo) & (y <= hi)))
