"""Posterior-quality evaluation: calibration of the served ensemble."""
from repro.eval.calibration import (  # noqa: F401
    ece_binary,
    ece_from_probs,
    interval_coverage,
    nll_categorical,
    nll_gaussian_mixture,
)
