from repro.checkpoint.np_checkpoint import restore, save  # noqa: F401
