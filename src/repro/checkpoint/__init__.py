from repro.checkpoint.np_checkpoint import (  # noqa: F401
    CorruptCheckpointError,
    DrawMeta,
    read_meta,
    restore,
    save,
    tree_fingerprint,
)
from repro.checkpoint.draw_bank import (  # noqa: F401
    list_draws,
    load_bank,
    save_draw,
)
from repro.checkpoint.snapshot import (  # noqa: F401
    latest_snapshot,
    list_snapshots,
    save_snapshot,
)
