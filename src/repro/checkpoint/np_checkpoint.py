"""Minimal sharded-pytree checkpointing (npz + key-path manifest).

Posterior SAMPLING means checkpoints carry (params == current chain state,
sampler step, PRNG key) — resuming a chain mid-trajectory is exact.

Since the draw-bank redesign every checkpoint is a versioned envelope
(``schema: repro-ckpt-v2``) carrying a :class:`DrawMeta` — which sampler
produced the draw (method), how far along the chain it was taken (round),
under which federation scenario, from which seed, and at what storage
dtype — plus a structural ``config_hash`` of the parameter tree
(key-paths, shapes, dtypes). The hash is what lets a SERVER refuse a
draw bank whose architecture/config does not match the model it is about
to serve, instead of shape-erroring halfway through a prefill. Legacy
(pre-envelope) checkpoints restore fine: ``meta`` comes back None.

Every write is ATOMIC: the checkpoint is staged under a dot-prefixed
temp directory and renamed into place (fresh target), or its files are
``os.replace``d one by one (existing target) — and the manifest carries
a content hash of the array payload (``arrays_sha256``), so a write
preempted between the two replaces surfaces at restore time as a
:class:`CorruptCheckpointError` instead of silently resuming from a
torn state. Readers distinguish *corruption* (torn/garbled bytes —
retryable, skippable in a bank) from *refusal* (wrong arch/config — a
configuration error that must stop the caller).
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

SCHEMA = "repro-ckpt-v2"


class CorruptCheckpointError(ValueError):
    """The checkpoint's bytes are unreadable or torn (preempted write,
    truncated file, content-hash mismatch) — as opposed to a REFUSAL
    (wrong arch/config), which stays a plain ValueError. Bank readers
    skip corrupt draws and degrade; resume loaders fall back to the
    previous snapshot."""


@dataclasses.dataclass(frozen=True)
class DrawMeta:
    """Provenance envelope of one posterior draw.

    ``config_hash`` is filled automatically at save time when left None
    (it is a pure function of the parameter tree's structure); pass
    ``scenario`` as the federation registry name ('identity' when the
    sampler ran without one)."""
    method: str = "fsgld"
    round: int = 0
    scenario: str = "identity"
    seed: int = 0
    dtype: str = "float32"
    arch: Optional[str] = None
    chain: int = 0
    config_hash: Optional[str] = None


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def tree_fingerprint(tree: PyTree) -> str:
    """Structural hash of a parameter tree: key paths + shapes + dtypes
    (values excluded — two draws of the same model share it, two archs
    never do). This is the ``DrawMeta.config_hash``."""
    names, leaves, _ = _flatten_with_names(tree)
    desc = [[n, list(np.shape(l)), str(np.asarray(l).dtype
                                       if not hasattr(l, "dtype")
                                       else l.dtype)]
            for n, l in zip(names, leaves)]
    blob = json.dumps(desc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def _write_file(path: str, blob: bytes):
    with open(path, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())


def save(path: str, tree: PyTree, *, step: int = 0, extra: dict = None,
         meta: Optional[DrawMeta] = None):
    """Write the tree + v2 envelope ATOMICALLY (staged under a
    dot-prefixed temp dir, then renamed/replaced into place — a
    preemption mid-save never leaves a half-written checkpoint where a
    reader, or ``--resume``, expects a whole one). ``meta`` (a DrawMeta)
    records draw provenance; its config_hash is computed here when
    unset."""
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    blob = buf.getvalue()
    if meta is not None and meta.config_hash is None:
        meta = dataclasses.replace(meta, config_hash=tree_fingerprint(tree))
    manifest = {"schema": SCHEMA, "names": names, "step": step,
                "extra": extra or {},
                "fingerprint": tree_fingerprint(tree),
                "arrays_sha256": hashlib.sha256(blob).hexdigest(),
                "meta": dataclasses.asdict(meta) if meta is not None
                else None}
    mblob = json.dumps(manifest).encode()

    abspath = os.path.abspath(path)
    parent, base = os.path.split(abspath)
    os.makedirs(parent, exist_ok=True)
    tmp = os.path.join(parent, f".tmp-{base}-{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    _write_file(os.path.join(tmp, "arrays.npz"), blob)
    _write_file(os.path.join(tmp, "manifest.json"), mblob)
    if not os.path.exists(abspath):
        # fresh target: publishing is ONE rename — fully atomic
        os.rename(tmp, abspath)
    else:
        # in-place overwrite: replace file by file (arrays first). A
        # preemption between the two replaces leaves a mixed pair, which
        # restore() detects via arrays_sha256 and refuses as corrupt.
        os.replace(os.path.join(tmp, "arrays.npz"),
                   os.path.join(abspath, "arrays.npz"))
        os.replace(os.path.join(tmp, "manifest.json"),
                   os.path.join(abspath, "manifest.json"))
        os.rmdir(tmp)


def _read_manifest(path: str) -> dict:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f)
    except json.JSONDecodeError as e:
        raise CorruptCheckpointError(
            f"checkpoint manifest at {path!r} is not valid JSON "
            f"(torn write?): {e}") from e


def read_meta(path: str) -> Optional[DrawMeta]:
    """The checkpoint's DrawMeta, or None for legacy (v1) checkpoints."""
    manifest = _read_manifest(path)
    m = manifest.get("meta")
    if m is None:
        return None
    known = {f.name for f in dataclasses.fields(DrawMeta)}
    return DrawMeta(**{k: v for k, v in m.items() if k in known})


def restore(path: str, like: PyTree):
    """Restore into the structure of ``like`` (names must match). Reads
    both the v2 envelope and legacy manifests (no schema/meta keys).
    Returns (tree, step, extra) — use :func:`read_meta` for the
    provenance envelope.

    Unreadable or torn bytes (missing/garbled arrays.npz, an
    ``arrays_sha256`` that no longer matches — i.e. a save preempted
    between its two file replaces) raise :class:`CorruptCheckpointError`;
    a key-path mismatch (wrong model) stays a plain ValueError refusal.
    """
    manifest = _read_manifest(path)
    apath = os.path.join(path, "arrays.npz")
    try:
        with open(apath, "rb") as f:
            blob = f.read()
    except OSError as e:
        raise CorruptCheckpointError(
            f"checkpoint at {path!r} has no readable arrays.npz: "
            f"{e}") from e
    want_sha = manifest.get("arrays_sha256")
    if want_sha is not None and \
            hashlib.sha256(blob).hexdigest() != want_sha:
        raise CorruptCheckpointError(
            f"checkpoint at {path!r} is torn: arrays.npz content hash "
            "does not match its manifest (write preempted mid-replace?)")
    try:
        data = np.load(io.BytesIO(blob), allow_pickle=False)
        names, leaves, treedef = _flatten_with_names(like)
        if names != manifest["names"]:
            raise ValueError(
                f"checkpoint/skeleton mismatch at {path}: the stored tree "
                "has different key paths than the restore target")
        new = [data[f"a{i}"] for i in range(len(leaves))]
    except ValueError:
        raise
    except Exception as e:  # truncated/garbled archive, missing entries
        raise CorruptCheckpointError(
            f"checkpoint arrays at {path!r} are unreadable "
            f"({type(e).__name__}: {e})") from e
    tree = jax.tree_util.tree_unflatten(treedef, new)
    return tree, manifest["step"], manifest["extra"]
