"""Minimal sharded-pytree checkpointing (npz + key-path manifest).

Posterior SAMPLING means checkpoints carry (params == current chain state,
sampler step, PRNG key) — resuming a chain mid-trajectory is exact.

Since the draw-bank redesign every checkpoint is a versioned envelope
(``schema: repro-ckpt-v2``) carrying a :class:`DrawMeta` — which sampler
produced the draw (method), how far along the chain it was taken (round),
under which federation scenario, from which seed, and at what storage
dtype — plus a structural ``config_hash`` of the parameter tree
(key-paths, shapes, dtypes). The hash is what lets a SERVER refuse a
draw bank whose architecture/config does not match the model it is about
to serve, instead of shape-erroring halfway through a prefill. Legacy
(pre-envelope) checkpoints restore fine: ``meta`` comes back None.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

SCHEMA = "repro-ckpt-v2"


@dataclasses.dataclass(frozen=True)
class DrawMeta:
    """Provenance envelope of one posterior draw.

    ``config_hash`` is filled automatically at save time when left None
    (it is a pure function of the parameter tree's structure); pass
    ``scenario`` as the federation registry name ('identity' when the
    sampler ran without one)."""
    method: str = "fsgld"
    round: int = 0
    scenario: str = "identity"
    seed: int = 0
    dtype: str = "float32"
    arch: Optional[str] = None
    chain: int = 0
    config_hash: Optional[str] = None


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def tree_fingerprint(tree: PyTree) -> str:
    """Structural hash of a parameter tree: key paths + shapes + dtypes
    (values excluded — two draws of the same model share it, two archs
    never do). This is the ``DrawMeta.config_hash``."""
    names, leaves, _ = _flatten_with_names(tree)
    desc = [[n, list(np.shape(l)), str(np.asarray(l).dtype
                                       if not hasattr(l, "dtype")
                                       else l.dtype)]
            for n, l in zip(names, leaves)]
    blob = json.dumps(desc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def save(path: str, tree: PyTree, *, step: int = 0, extra: dict = None,
         meta: Optional[DrawMeta] = None):
    """Write the tree + v2 envelope. ``meta`` (a DrawMeta) records draw
    provenance; its config_hash is computed here when unset."""
    os.makedirs(path, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    if meta is not None and meta.config_hash is None:
        meta = dataclasses.replace(meta, config_hash=tree_fingerprint(tree))
    manifest = {"schema": SCHEMA, "names": names, "step": step,
                "extra": extra or {},
                "fingerprint": tree_fingerprint(tree),
                "meta": dataclasses.asdict(meta) if meta is not None
                else None}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _read_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def read_meta(path: str) -> Optional[DrawMeta]:
    """The checkpoint's DrawMeta, or None for legacy (v1) checkpoints."""
    manifest = _read_manifest(path)
    m = manifest.get("meta")
    if m is None:
        return None
    known = {f.name for f in dataclasses.fields(DrawMeta)}
    return DrawMeta(**{k: v for k, v in m.items() if k in known})


def restore(path: str, like: PyTree):
    """Restore into the structure of ``like`` (names must match). Reads
    both the v2 envelope and legacy manifests (no schema/meta keys).
    Returns (tree, step, extra) — use :func:`read_meta` for the
    provenance envelope."""
    manifest = _read_manifest(path)
    data = np.load(os.path.join(path, "arrays.npz"))
    names, leaves, treedef = _flatten_with_names(like)
    if names != manifest["names"]:
        raise ValueError(
            f"checkpoint/skeleton mismatch at {path}: the stored tree "
            "has different key paths than the restore target")
    new = [data[f"a{i}"] for i in range(len(leaves))]
    tree = jax.tree_util.tree_unflatten(treedef, new)
    return tree, manifest["step"], manifest["extra"]
