"""Minimal sharded-pytree checkpointing (npz + key-path manifest).

Posterior SAMPLING means checkpoints carry (params == current chain state,
sampler step, PRNG key) — resuming a chain mid-trajectory is exact.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(path: str, tree: PyTree, *, step: int = 0, extra: dict = None):
    os.makedirs(path, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    arrays = {f"a{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"names": names, "step": step, "extra": extra or {}}
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore(path: str, like: PyTree):
    """Restore into the structure of ``like`` (names must match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    names, leaves, treedef = _flatten_with_names(like)
    assert names == manifest["names"], "checkpoint/skeleton mismatch"
    new = [data[f"a{i}"] for i in range(len(leaves))]
    tree = jax.tree_util.tree_unflatten(treedef, new)
    return tree, manifest["step"], manifest["extra"]
