"""Preemption-safe run snapshots: the full scan carry, atomically.

A snapshot directory holds numbered checkpoints of EVERYTHING the
sampler's scan carries across rounds — chain state, PRNG key, the
federation carry (shard ids, compression reference/error), chain-health
words, and the trace collected so far::

    snaps/
      snap-000004/ {arrays.npz, manifest.json}   # after round 4
      snap-000008/ ...

Each snapshot is written through the v2 checkpoint layer into a FRESH
``snap-{round:06d}`` directory, so publishing is one rename — a
preemption mid-save never leaves a torn snapshot where ``--resume``
expects a whole one. Readers walk newest→oldest, skipping corrupt
snapshots with a warning, so losing the latest write costs at most one
snapshot interval, never the run.

The payload is a flat dict of named arrays (``repro.core.engine``
decides the keys); this module only guarantees atomicity, pruning, and
newest-valid selection.
"""
from __future__ import annotations

import os
import re
import shutil
import warnings
from typing import Any, Dict, Optional, Tuple

from repro.checkpoint.np_checkpoint import (CorruptCheckpointError, restore,
                                            save)
from repro.obs import trace as obs_trace

PyTree = Any

_SNAP_RE = re.compile(r"^snap-(\d{6})$")


def _snap_dirname(r: int) -> str:
    return f"snap-{r:06d}"


def list_snapshots(snap_dir: str):
    """(rounds_done, path) pairs of complete snapshots, oldest first."""
    if not os.path.isdir(snap_dir):
        return []
    out = []
    for name in sorted(os.listdir(snap_dir)):
        m = _SNAP_RE.match(name)
        path = os.path.join(snap_dir, name)
        if m and os.path.exists(os.path.join(path, "manifest.json")):
            out.append((int(m.group(1)), path))
    return out


def save_snapshot(snap_dir: str, payload: Dict[str, Any], *,
                  rounds_done: int, keep: int = 2) -> str:
    """Atomically publish the scan carry after ``rounds_done`` rounds,
    then prune to the newest ``keep`` snapshots. Returns the snapshot
    path."""
    with obs_trace.span("snapshot.save", round=int(rounds_done)):
        os.makedirs(snap_dir, exist_ok=True)
        final = os.path.join(snap_dir, _snap_dirname(rounds_done))
        if os.path.exists(final):      # re-running the same segment
            shutil.rmtree(final)
        save(final, payload, step=rounds_done)
        for r, path in list_snapshots(snap_dir)[:-keep]:
            shutil.rmtree(path, ignore_errors=True)
    return final


def latest_snapshot(snap_dir: str, like: Dict[str, Any]
                    ) -> Tuple[Optional[Dict[str, Any]], int]:
    """The newest VALID snapshot restored into ``like``'s structure, as
    (payload, rounds_done) — or (None, 0) when the directory holds none.
    Corrupt snapshots (torn writes) are skipped with a warning; a
    structural mismatch (wrong run config) raises."""
    with obs_trace.span("snapshot.restore", dir=snap_dir):
        for rounds_done, path in reversed(list_snapshots(snap_dir)):
            try:
                payload, step, _ = restore(path, like)
            except CorruptCheckpointError as e:
                warnings.warn(f"skipping corrupt snapshot {path!r}: {e}")
                obs_trace.event("snapshot.corrupt", path=path, error=str(e))
                continue
            return payload, int(step)
    return None, 0
