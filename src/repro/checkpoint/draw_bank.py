"""Versioned draw-bank directories: the chain→server streaming format.

A draw bank is a directory of numbered single-draw checkpoints::

    bank/
      draw-000000/ {arrays.npz, manifest.json}   # repro-ckpt-v2 + DrawMeta
      draw-000001/ ...

Writers (``repro.launch.train --draw-bank``, or anything calling
:func:`save_draw`) append draws ATOMICALLY — the draw is staged under a
dot-prefixed temp name and renamed into place — so a server polling the
directory between requests (``repro.serve.EnsembleServer.refresh``)
never observes a half-written draw. Readers take the FRESHEST K draws;
every draw is fingerprint-checked against the serving skeleton and a
bank whose arch/config hash mismatches is REFUSED with a real error
instead of shape-erroring mid-prefill.

A legacy single-checkpoint directory (one ``manifest.json`` at the top
level, as written by older ``repro.launch.train --ckpt``) reads as a
one-draw bank — the K=1 fallback that keeps old checkpoints servable.
"""
from __future__ import annotations

import os
import re
import warnings
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.np_checkpoint import (CorruptCheckpointError,
                                            DrawMeta, read_meta, restore,
                                            save, tree_fingerprint)

PyTree = Any

_DRAW_RE = re.compile(r"^draw-(\d{6})$")


def _draw_dirname(i: int) -> str:
    return f"draw-{i:06d}"


def list_draws(bank_dir: str) -> List[str]:
    """Complete draw paths, oldest first. A draw is complete once its
    manifest exists (the rename in save_draw makes manifest visibility
    atomic with the arrays)."""
    if not os.path.isdir(bank_dir):
        return []
    out = []
    for name in sorted(os.listdir(bank_dir)):
        m = _DRAW_RE.match(name)
        path = os.path.join(bank_dir, name)
        if m and os.path.exists(os.path.join(path, "manifest.json")):
            out.append(path)
    return out


def save_draw(bank_dir: str, tree: PyTree, meta: DrawMeta, *,
              step: int = 0) -> str:
    """Append one draw to the bank (atomic: staged + renamed). Returns
    the draw's final path."""
    os.makedirs(bank_dir, exist_ok=True)
    existing = [int(_DRAW_RE.match(n).group(1))
                for n in os.listdir(bank_dir) if _DRAW_RE.match(n)]
    idx = max(existing) + 1 if existing else 0
    final = os.path.join(bank_dir, _draw_dirname(idx))
    tmp = os.path.join(bank_dir, f".tmp-{_draw_dirname(idx)}")
    save(tmp, tree, step=step, meta=meta)
    os.rename(tmp, final)
    return final


def load_bank(bank_dir: str, like: PyTree, *, k: Optional[int] = None,
              expect_arch: Optional[str] = None
              ) -> Tuple[PyTree, List[Optional[DrawMeta]]]:
    """Load the freshest ``k`` draws (all when None) STACKED along a new
    leading draw axis — the ensemble the server fans decode out over.

    Refusal contract: every draw's structural fingerprint must match
    ``like`` (the serving skeleton from ``init_params``), and when
    ``expect_arch`` is given every DrawMeta.arch must agree — a
    mismatched bank raises ValueError up front instead of shape-erroring
    halfway through a prefill.

    Degradation contract: a CORRUPT draw (torn write, truncated/garbled
    arrays, content-hash mismatch — :class:`CorruptCheckpointError`) is
    skipped with a warning and an OLDER healthy draw backfills the
    ensemble when available, so one bad write degrades the bank to the
    healthy K-j draws instead of taking serving down. Only when the
    directory holds no servable draw at all does this raise — naming the
    directory and every per-draw refusal reason.

    Returns (stacked tree with (K, ...) leaves, per-draw metas
    oldest→freshest; metas are None for legacy draws)."""
    paths = list_draws(bank_dir)
    if not paths:
        # legacy fallback: the directory IS a single old-style checkpoint
        if os.path.exists(os.path.join(bank_dir, "manifest.json")):
            paths = [bank_dir]
        elif not os.path.isdir(bank_dir):
            raise ValueError(
                f"no draws in bank {bank_dir!r}: the directory does not "
                "exist (pass a draw-bank dir written by repro.launch.train "
                "--draw-bank, or a legacy single-checkpoint dir)")
        else:
            raise ValueError(
                f"no draws in bank {bank_dir!r}: the directory exists but "
                "holds no complete draw-NNNNNN checkpoint and no legacy "
                "top-level manifest.json — the writer may not have "
                "finished its first draw yet")
    if k is not None and k > len(paths):
        raise ValueError(
            f"bank {bank_dir!r} holds {len(paths)} draw(s), "
            f"{k} requested")

    want_k = k if k is not None else len(paths)
    want = tree_fingerprint(like)
    draws, metas, bad = [], [], []
    # walk freshest -> oldest, backfilling past corrupt draws until the
    # requested ensemble size is met (or the bank is exhausted)
    for p in reversed(paths):
        if len(draws) == want_k:
            break
        try:
            meta = read_meta(p)
        except CorruptCheckpointError as e:
            bad.append((p, str(e)))
            continue
        if meta is not None and meta.config_hash is not None \
                and meta.config_hash != want:
            raise ValueError(
                f"draw bank refused: {p} was drawn from a different "
                f"arch/config (hash {meta.config_hash} != serving "
                f"skeleton {want}" +
                (f"; bank arch={meta.arch!r}" if meta.arch else "") + ")")
        if expect_arch is not None and meta is not None \
                and meta.arch is not None and meta.arch != expect_arch:
            raise ValueError(
                f"draw bank refused: {p} is arch {meta.arch!r}, "
                f"server expects {expect_arch!r}")
        try:
            tree, _, _ = restore(p, like)
        except CorruptCheckpointError as e:
            bad.append((p, str(e)))
            continue
        except ValueError as e:
            raise ValueError(f"draw bank refused: {e}") from e
        draws.append(tree)
        metas.append(meta)
    if not draws:
        reasons = "; ".join(f"{p}: {r}" for p, r in bad)
        raise ValueError(
            f"no servable draws in bank {bank_dir!r}: all "
            f"{len(paths)} present draw(s) are corrupt ({reasons})")
    if bad:
        warnings.warn(
            f"bank {bank_dir!r}: skipped {len(bad)} corrupt draw(s) "
            f"({'; '.join(p for p, _ in bad)}); serving {len(draws)} of "
            f"{want_k} requested draw(s)")
    draws.reverse()            # oldest -> freshest, the documented order
    metas.reverse()
    stacked = jax.tree.map(lambda *ls: jnp.stack(
        [jnp.asarray(l) for l in ls]), *draws)
    return stacked, metas
