"""Scan-over-layers language models for all six assigned families.

Parameters are plain nested dicts. The layer stack is grouped into repeating
*periods* (``cfg.layer_pattern``); all full periods are stacked along a
leading axis and applied with ``jax.lax.scan`` so HLO size / compile time are
depth-independent (essential for the 100-layer x 512-device dry-run on this
CPU container). Remainder layers (when num_layers % period != 0) are applied
unscanned.

Modes:
  forward(params, cfg, tokens, ...)      -> (hidden, aux)   train / prefill
  loss_fn(params, cfg, batch)            -> scalar sum log-lik (+ aux)
  init_cache / decode_step               -> single-token serving
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L

ACT_DTYPE = jnp.bfloat16


def _ambient_mesh():
    """The legacy `with mesh:` context mesh, if any (dry-run / production
    path). Returns None on the bare CPU test path."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:  # noqa: BLE001
        return None
    return None


def _shard_batch(x):
    """Anchor activation sharding: batch over (pod?, data), rest replicated.
    Without this anchor GSPMD drops batch sharding at the remat+scan
    boundary and silently replicates whole-layer compute on every device
    (16-64x redundant flops — caught by the roofline analyzer)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    from jax.sharding import PartitionSpec as P
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not baxes or x.shape[0] % \
            int(np.prod([mesh.shape[a] for a in baxes])) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(baxes, *([None] * (x.ndim - 1))))


def _cast_floating(tree, dtype=ACT_DTYPE):
    """Cast float leaves to the compute dtype at point-of-use. Master params
    stay fp32 (the sampler needs fp32 Langevin updates); doing the cast
    *inside* the layer scan keeps the FSDP all-gathers in bf16."""
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, tree)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(d):
    return jnp.zeros((d,), jnp.float32)


def _dense_init(key, fan_in, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            * fan_in ** -0.5).astype(dtype)


def _init_ffn(key, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    if cfg.moe is not None:
        e = cfg.moe.num_experts
        p = {"router": _dense_init(ks[0], d, (d, e), jnp.float32),
             "experts_wo": _dense_init(ks[1], f, (e, f, d), dtype)}
        if cfg.ffn_type in ("silu", "geglu"):
            p["experts_wi_gate"] = _dense_init(ks[2], d, (e, d, f), dtype)
            p["experts_wi_up"] = _dense_init(ks[3], d, (e, d, f), dtype)
        else:
            p["experts_wi_up"] = _dense_init(ks[2], d, (e, d, f), dtype)
        return p
    p = {"wo": _dense_init(ks[1], f, (f, d), dtype)}
    if cfg.ffn_type in ("silu", "geglu"):
        p["wi_gate"] = _dense_init(ks[2], d, (d, f), dtype)
        p["wi_up"] = _dense_init(ks[3], d, (d, f), dtype)
    else:
        p["wi_up"] = _dense_init(ks[2], d, (d, f), dtype)
    return p


def _init_attn(key, cfg: ArchConfig, dtype, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    qd, kvd = cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {"wq": _dense_init(ks[0], d, (d, qd), dtype),
         "wk": _dense_init(ks[1], d, (d, kvd), dtype),
         "wv": _dense_init(ks[2], d, (d, kvd), dtype),
         "wo": _dense_init(ks[3], qd, (qd, d), dtype)}
    if cfg.qk_norm and not cross:
        p["q_norm"] = _norm_init(hd)
        p["k_norm"] = _norm_init(hd)
    return p


def _init_layer(key, kind: str, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 6)
    p = {"norm": _norm_init(cfg.d_model), "ffn_norm": _norm_init(cfg.d_model),
         "ffn": _init_ffn(ks[0], cfg, dtype)}
    d = cfg.d_model
    if kind in ("attn", "swa"):
        p["attn"] = _init_attn(ks[1], cfg, dtype)
    elif kind == "xattn" and cfg.family == "vlm":
        p["xattn"] = _init_attn(ks[1], cfg, dtype, cross=True)
        p["xattn"]["gate"] = jnp.zeros((1,), jnp.float32)
        p["xnorm"] = _norm_init(d)
    elif kind == "xattn":  # audio decoder layer: self-attn + cross-attn
        p["attn"] = _init_attn(ks[1], cfg, dtype)
        p["xattn"] = _init_attn(ks[2], cfg, dtype, cross=True)
        p["xnorm"] = _norm_init(d)
    elif kind == "rglru":
        p["rec"] = {
            "w_x": _dense_init(ks[1], d, (d, d), dtype),
            "w_gate": _dense_init(ks[2], d, (d, d), dtype),
            "w_out": _dense_init(ks[3], d, (d, d), dtype),
            "conv_w": _dense_init(ks[4], 4, (4, d), dtype),
            "w_rec": _dense_init(ks[5], d, (d, d), jnp.float32),
            "w_inp": _dense_init(ks[0], d, (d, d), jnp.float32),
            "lam": jnp.full((d,), 0.5, jnp.float32),
        }
    elif kind == "rwkv":
        H, hd = cfg.num_heads, cfg.head_dim
        lora = 64
        p["mix"] = {
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_v": jnp.full((d,), 0.5, jnp.float32),
            "mu_w": jnp.full((d,), 0.5, jnp.float32),
            "w_r": _dense_init(ks[1], d, (d, H * hd), dtype),
            "w_k": _dense_init(ks[2], d, (d, H * hd), dtype),
            "w_v": _dense_init(ks[3], d, (d, H * hd), dtype),
            "w_o": _dense_init(ks[4], H * hd, (H * hd, d), dtype),
            "w0": jnp.full((d,), -1.0, jnp.float32),
            "w_lora_a": _dense_init(ks[5], d, (d, lora), jnp.float32),
            "w_lora_b": _dense_init(ks[0], lora, (lora, d), jnp.float32),
            "u": jnp.zeros((H, hd), jnp.float32),
        }
    else:
        raise ValueError(kind)
    return p


def _period_kinds(cfg: ArchConfig):
    pat = cfg.layer_pattern
    n_full = cfg.num_layers // len(pat)
    rem = cfg.num_layers % len(pat)
    return pat, n_full, pat[:rem]


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    pat, n_full, rem = _period_kinds(cfg)
    k_emb, k_head, k_blocks, k_rem, k_enc = jax.random.split(key, 5)

    def init_period(k):
        ks = jax.random.split(k, len(pat))
        return {f"l{i}": _init_layer(ks[i], kind, cfg, dtype)
                for i, kind in enumerate(pat)}

    params = {
        "embed": _dense_init(k_emb, cfg.d_model, (cfg.vocab_size, cfg.d_model),
                             dtype),
        "blocks": jax.vmap(init_period)(jax.random.split(k_blocks, n_full)),
        "final_norm": _norm_init(cfg.d_model),
        "head": _dense_init(k_head, cfg.d_model,
                            (cfg.d_model, cfg.vocab_size), dtype),
    }
    if rem:
        ks = jax.random.split(k_rem, len(rem))
        params["rem_blocks"] = {f"l{i}": _init_layer(ks[i], kind, cfg, dtype)
                                for i, kind in enumerate(rem)}
    if cfg.encoder_layers:
        enc_cfg = cfg
        ks = jax.random.split(k_enc, cfg.encoder_layers)

        def init_enc_layer(k):
            p = _init_layer(k, "attn", enc_cfg, dtype)
            return p
        params["encoder"] = {
            "blocks": jax.vmap(init_enc_layer)(ks),
            "final_norm": _norm_init(cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# layer application (full-sequence mode)
# ---------------------------------------------------------------------------

def _self_attn(x, p, cfg: ArchConfig, positions, *, window=None,
               causal=True):
    B, S, _ = x.shape
    h = L.rms_norm(x, p["norm"])
    q = (h @ p["attn"]["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (h @ p["attn"]["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (h @ p["attn"]["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["attn"]["q_norm"])
        k = L.rms_norm(k, p["attn"]["k_norm"])
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    o = L.chunked_attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=causal,
                            window=window)
    return x + o.reshape(B, S, -1) @ p["attn"]["wo"]


def _cross_attn(x, p, cfg: ArchConfig, enc_out, gated: bool):
    B, S, _ = x.shape
    Te = enc_out.shape[1]
    h = L.rms_norm(x, p["xnorm"])
    xp = p["xattn"]
    q = (h @ xp["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (enc_out @ xp["wk"]).reshape(B, Te, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ xp["wv"]).reshape(B, Te, cfg.num_kv_heads, cfg.head_dim)
    qpos = jnp.zeros((B, S), jnp.int32)
    kpos = jnp.zeros((B, Te), jnp.int32)
    o = L.chunked_attention(q, k, v, q_positions=qpos, kv_positions=kpos,
                            causal=False)
    o = o.reshape(B, S, -1) @ xp["wo"]
    if gated:
        o = jnp.tanh(xp["gate"]).astype(o.dtype) * o
    return x + o


def _ffn_residual(x, p, cfg: ArchConfig):
    h = L.rms_norm(x, p["ffn_norm"])
    if cfg.moe is not None:
        y, aux = L.moe_ffn(h, p["ffn"], top_k=cfg.moe.top_k,
                           ffn_type=cfg.ffn_type,
                           capacity_factor=cfg.moe.capacity_factor)
        return x + y, aux
    return x + L.ffn_apply(h, p["ffn"], cfg.ffn_type), jnp.float32(0.0)


def _apply_layer(kind: str, p, x, cfg: ArchConfig, positions, enc_out):
    if kind == "attn":
        x = _self_attn(x, p, cfg, positions)
    elif kind == "swa":
        x = _self_attn(x, p, cfg, positions, window=cfg.swa_window)
    elif kind == "xattn" and cfg.family == "vlm":
        x = _cross_attn(x, p, cfg, enc_out, gated=True)
    elif kind == "xattn":
        x = _self_attn(x, p, cfg, positions)
        x = _cross_attn(x, p, cfg, enc_out, gated=False)
    elif kind == "rglru":
        h = L.rms_norm(x, p["norm"])
        y, _ = L.rglru_forward(h, p["rec"])
        x = x + y
    elif kind == "rwkv":
        h = L.rms_norm(x, p["norm"])
        y, _ = L.rwkv_forward(h, p["mix"])
        x = x + y
    else:
        raise ValueError(kind)
    x, aux = _ffn_residual(x, p, cfg)
    return x, aux


def _apply_period(params_period, x, cfg: ArchConfig, positions, enc_out,
                  kinds):
    aux = jnp.float32(0.0)
    for i, kind in enumerate(kinds):
        x, a = _apply_layer(kind, params_period[f"l{i}"], x, cfg, positions,
                            enc_out)
        aux = aux + a
    return x, aux


def forward(params: dict, cfg: ArchConfig, tokens: jax.Array, *,
            enc_embeds: Optional[jax.Array] = None):
    """tokens: (B, S) int32. enc_embeds: stubbed modality-frontend output
    (audio frames / image patches), (B, T_enc, D), required for vlm/audio.

    Returns (hidden (B,S,D) pre-head, aux_loss scalar).
    """
    pat, n_full, rem = _period_kinds(cfg)
    B, S = tokens.shape
    x = _shard_batch(params["embed"][tokens].astype(ACT_DTYPE))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    enc_out = None
    if cfg.family == "vlm":
        enc_out = enc_embeds.astype(ACT_DTYPE)
    elif cfg.family == "audio":
        enc_out = encoder_forward(params, cfg, enc_embeds)

    def body(carry, period_params):
        x, aux = carry
        x = _shard_batch(x)
        period_params = _cast_floating(period_params)
        x, a = _apply_period(period_params, x, cfg, positions, enc_out, pat)
        return (_shard_batch(x), aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["blocks"])
    for i, kind in enumerate(rem):
        x, a = _apply_layer(kind, _cast_floating(params["rem_blocks"][f"l{i}"]),
                            x, cfg, positions, enc_out)
        aux = aux + a
    x = L.rms_norm(x, params["final_norm"])
    return x, aux


def encoder_forward(params: dict, cfg: ArchConfig, enc_embeds: jax.Array):
    """Bidirectional encoder over stubbed frame embeddings (audio)."""
    B, T, _ = enc_embeds.shape
    x = enc_embeds.astype(ACT_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, p):
        x = _shard_batch(x)
        p = _cast_floating(p)
        x = _self_attn(x, p, cfg, positions, causal=False)
        x, _ = _ffn_residual(x, p, cfg)
        return _shard_batch(x), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["blocks"])
    return L.rms_norm(x, params["encoder"]["final_norm"])


# ---------------------------------------------------------------------------
# loss (chunked cross-entropy; log-likelihood convention for SG-MCMC)
# ---------------------------------------------------------------------------

def chunked_log_lik(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                    chunk: int = 512) -> jax.Array:
    """Sum_t log p(label_t | hidden_t). Never materialises (B,S,V): scans
    over sequence chunks (vocab up to 256k makes full logits ~33 GB/group)."""
    B, S, D = hidden.shape
    nb = L.cdiv(S, chunk)
    pad = nb * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(B, nb, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nb, chunk).transpose(1, 0, 2)

    def body(tot, blk):
        h, lab = blk
        logits = jnp.einsum("bsd,dv->bsv", h, head,
                            preferred_element_type=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0] - logz
        ll = jnp.where(lab >= 0, ll, 0.0)
        return tot + ll.sum(), None

    # NOTE (§Perf iteration 6, hypothesis REFUTED): we expected scan
    # linearization to stack the (nb,B,chunk,V) logits as backward
    # residuals; measurement shows XLA already avoids it (gemma-7b train
    # HBM unchanged at 5.58e12 B/dev with or without this checkpoint).
    # The checkpoint is kept as cheap insurance for other backends.
    tot, _ = jax.lax.scan(jax.checkpoint(body), jnp.float32(0.0), (hc, lc))
    return tot


def log_lik_fn(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Total log-likelihood of a (mini)batch — the quantity whose gradient
    SGLD/DSGLD/FSGLD scale by N_s/(f_s m). ``batch``: tokens, labels,
    optional enc_embeds."""
    hidden, aux = forward(params, cfg, batch["tokens"],
                          enc_embeds=batch.get("enc_embeds"))
    ll = chunked_log_lik(hidden, params["head"].astype(ACT_DTYPE),
                         batch["labels"])
    # the router load-balance term enters as a likelihood *regulariser*
    return ll - 0.01 * aux * batch["tokens"].size


# ---------------------------------------------------------------------------
# cache-populating prefill (serving: one forward pass fills the decode
# cache; decode then continues token-by-token from position S)
# ---------------------------------------------------------------------------

def _prefill_layer_cache(kind: str, cfg: ArchConfig, h, p, positions,
                         x_seq_cache_len: int, carry_states):
    """Compute the decode-cache entry for one layer given its normed input
    h (B,S,D). For attention: project k/v and lay them out exactly as
    decode would have written them (ring layout for SWA)."""
    B, S, _ = h.shape
    if kind in ("attn", "swa") or (kind == "xattn"
                                   and cfg.family == "audio"):
        k = (h @ p["attn"]["wk"]).reshape(B, S, cfg.num_kv_heads,
                                          cfg.head_dim)
        v = (h @ p["attn"]["wv"]).reshape(B, S, cfg.num_kv_heads,
                                          cfg.head_dim)
        if cfg.qk_norm:
            k = L.rms_norm(k, p["attn"]["k_norm"])
        k = L.rope(k, positions, cfg.rope_theta)
        Sc = x_seq_cache_len
        if kind == "swa":
            W = min(cfg.swa_window, Sc)
            # last W positions, placed at their ring slots pos % W
            kw, vw = k[:, -W:], v[:, -W:]
            pw = positions[:, -W:]
            slots = pw % W
            kc = jnp.zeros((B, W) + k.shape[2:], k.dtype)
            vc = jnp.zeros((B, W) + v.shape[2:], v.dtype)
            pc = jnp.full((B, W), -1, jnp.int32)
            bidx = jnp.arange(B)[:, None]
            kc = kc.at[bidx, slots].set(kw)
            vc = vc.at[bidx, slots].set(vw)
            pc = pc.at[bidx, slots].set(pw)
            return {"k": kc, "v": vc, "pos": pc}
        pad = Sc - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pc = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
        return {"k": kc, "v": vc, "pos": pc.astype(jnp.int32)}
    if kind == "xattn" and cfg.family == "vlm":
        return {}
    # recurrent layers: the forward pass already produced the final state
    return carry_states


def prefill_with_cache(params: dict, cfg: ArchConfig, tokens: jax.Array,
                       cache_len: int, *,
                       enc_embeds: Optional[jax.Array] = None):
    """Forward over the prompt AND build the decode cache in one pass.

    Returns (last_logits (B,V), cache) where ``cache`` matches
    init_cache(cfg, B, cache_len) layout; decode_step continues from
    position tokens.shape[1].
    """
    pat, n_full, rem = _period_kinds(cfg)
    B, S = tokens.shape
    assert cache_len >= S
    x = _shard_batch(params["embed"][tokens].astype(ACT_DTYPE))
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    enc_out = None
    if cfg.family == "vlm":
        enc_out = enc_embeds.astype(ACT_DTYPE)
    elif cfg.family == "audio":
        enc_out = encoder_forward(params, cfg, enc_embeds)

    def apply_and_cache(kind, p, x):
        h = L.rms_norm(x, p["norm"])
        states = None
        if kind == "rglru":
            y, h_last = L.rglru_forward(h, p["rec"])
            # conv history: last W-1 inputs of the conv
            xin = h @ p["rec"]["w_x"]
            Wc = p["rec"]["conv_w"].shape[0]
            hist = jnp.pad(xin, ((0, 0), (Wc - 1, 0), (0, 0)))[:, -(Wc - 1):]
            states = {"h": h_last, "conv": hist.astype(ACT_DTYPE)}
            x = x + y
        elif kind == "rwkv":
            y, st = L.rwkv_forward(h, p["mix"])
            states = {"S": st["S"],
                      "x_prev": st["x_prev"].astype(ACT_DTYPE)}
            x = x + y
        else:
            x, _ = (
                (_self_attn(x, p, cfg, positions,
                            window=cfg.swa_window if kind == "swa"
                            else None), None)
                if kind in ("attn", "swa") else (x, None))
            if kind == "xattn" and cfg.family == "vlm":
                x = _cross_attn(x, p, cfg, enc_out, gated=True)
            elif kind == "xattn":
                x = _self_attn(x, p, cfg, positions)
                x = _cross_attn(x, p, cfg, enc_out, gated=False)
        cache = _prefill_layer_cache(kind, cfg, h, p, positions, cache_len,
                                     states)
        x, _ = _ffn_residual(x, p, cfg)
        return x, cache

    def body(x, period_params):
        period_params = _cast_floating(period_params)
        caches = {}
        for i, kind in enumerate(pat):
            x, c = apply_and_cache(kind, period_params[f"l{i}"], x)
            caches[f"l{i}"] = c
        return _shard_batch(x), caches

    x, blocks_cache = jax.lax.scan(body, x, params["blocks"])
    cache = {"blocks": blocks_cache}
    if rem:
        rb = {}
        for i, kind in enumerate(rem):
            x, c = apply_and_cache(
                kind, _cast_floating(params["rem_blocks"][f"l{i}"]), x)
            rb[f"l{i}"] = c
        cache["rem_blocks"] = rb
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1],
                        params["head"].astype(ACT_DTYPE),
                        preferred_element_type=jnp.float32)
    return logits, cache


# ---------------------------------------------------------------------------
# decode (single-token serving step)
# ---------------------------------------------------------------------------

def _layer_cache(kind: str, cfg: ArchConfig, batch: int, seq_len: int,
                 dtype):
    hd, K = cfg.head_dim, cfg.num_kv_heads
    if kind == "attn":
        S = seq_len
        return {"k": jnp.zeros((batch, S, K, hd), dtype),
                "v": jnp.zeros((batch, S, K, hd), dtype),
                "pos": jnp.full((batch, S), -1, jnp.int32)}
    if kind == "swa":
        W = min(cfg.swa_window, seq_len)
        return {"k": jnp.zeros((batch, W, K, hd), dtype),
                "v": jnp.zeros((batch, W, K, hd), dtype),
                "pos": jnp.full((batch, W), -1, jnp.int32)}
    if kind == "xattn" and cfg.family == "vlm":
        return {}
    if kind == "xattn":  # audio: self-attention cache
        S = seq_len
        return {"k": jnp.zeros((batch, S, K, hd), dtype),
                "v": jnp.zeros((batch, S, K, hd), dtype),
                "pos": jnp.full((batch, S), -1, jnp.int32)}
    if kind == "rglru":
        return L.rglru_init_state(batch, cfg.d_model, 4, dtype)
    if kind == "rwkv":
        return L.rwkv_init_state(batch, cfg.num_heads, cfg.head_dim,
                                 cfg.d_model, dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               dtype=ACT_DTYPE) -> dict:
    pat, n_full, rem = _period_kinds(cfg)

    def one_period(_):
        return {f"l{i}": _layer_cache(kind, cfg, batch, seq_len, dtype)
                for i, kind in enumerate(pat)}

    cache = {"blocks": jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape).copy()
        if n_full else x, one_period(0))}
    # stacked leading dim == n_full periods
    if rem:
        cache["rem_blocks"] = {
            f"l{i}": _layer_cache(kind, cfg, batch, seq_len, dtype)
            for i, kind in enumerate(rem)}
    return cache


def _update_kv(cache, k_new, v_new, pos, ring: bool):
    """k_new/v_new: (B,1,K,hd); pos: (B,) absolute position."""
    S = cache["k"].shape[1]
    slot = (pos % S) if ring else jnp.minimum(pos, S - 1)

    def upd(buf, s, new):
        return jax.lax.dynamic_update_slice(buf, new, (s, 0, 0))

    k = jax.vmap(upd)(cache["k"], slot, k_new)
    v = jax.vmap(upd)(cache["v"], slot, v_new)
    posbuf = jax.vmap(lambda b, s, p: b.at[s].set(p))(cache["pos"], slot, pos)
    return {"k": k, "v": v, "pos": posbuf}


def _decode_self_attn(x, p, cfg: ArchConfig, cache, pos, *, ring):
    B = x.shape[0]
    h = L.rms_norm(x, p["norm"])
    q = (h @ p["attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    k = (h @ p["attn"]["wk"]).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    v = (h @ p["attn"]["wv"]).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["attn"]["q_norm"])
        k = L.rms_norm(k, p["attn"]["k_norm"])
    q = L.rope(q, pos[:, None], cfg.rope_theta)
    k = L.rope(k, pos[:, None], cfg.rope_theta)
    cache = _update_kv(cache, k.astype(cache["k"].dtype),
                       v.astype(cache["v"].dtype), pos, ring)
    o = L.decode_attention(q, cache["k"], cache["v"], cache["pos"], pos)
    return x + o.reshape(B, 1, -1) @ p["attn"]["wo"], cache


def _decode_layer(kind: str, p, x, cfg: ArchConfig, cache, pos, enc_out):
    if kind == "attn":
        x, cache = _decode_self_attn(x, p, cfg, cache, pos, ring=False)
    elif kind == "swa":
        x, cache = _decode_self_attn(x, p, cfg, cache, pos, ring=True)
    elif kind == "xattn" and cfg.family == "vlm":
        x = _cross_attn(x, p, cfg, enc_out, gated=True)
    elif kind == "xattn":
        x, cache = _decode_self_attn(x, p, cfg, cache, pos, ring=False)
        x = _cross_attn(x, p, cfg, enc_out, gated=False)
    elif kind == "rglru":
        h = L.rms_norm(x, p["norm"])
        y, cache = L.rglru_decode(h, p["rec"], cache)
        x = x + y
    elif kind == "rwkv":
        h = L.rms_norm(x, p["norm"])
        y, cache = L.rwkv_decode(h, p["mix"], cache)
        x = x + y
    else:
        raise ValueError(kind)
    x, _ = _ffn_residual(x, p, cfg)
    return x, cache


def broadcast_cache(cache: dict, k: int) -> dict:
    """Fan a single prefilled decode cache out to K posterior draws:
    every leaf gains a leading draw axis (K, ...). This is the
    cache-sharing half of ensemble serving — prefill runs ONCE (anchor
    draw), the prompt region of the KV cache / recurrent state is shared
    by construction, and only the decode fan-out diverges per draw
    (each draw's decode writes its own k/v rows for generated tokens)."""
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (k,) + l.shape), cache)


def ensemble_decode_step(draws: dict, cfg: ArchConfig, caches: dict,
                         token: jax.Array, pos: jax.Array, *,
                         enc_out: Optional[jax.Array] = None):
    """One serving step across K posterior draws sharing ONE token
    stream: ``draws``/``caches`` carry a leading (K, ...) draw axis,
    ``token`` (B,1) and ``pos`` (B,) are shared — the served sequence is
    a single stream whose next token comes from the ensemble predictive
    mean, not K diverging streams. Returns (logits (K,B,V), caches).

    The draw axis is a plain vmapped batch axis, so under a mesh it
    rides a mesh axis exactly like chains do during sampling
    (``repro.sharding.rules.ensemble_specs``)."""
    fn = lambda p, c: decode_step(p, cfg, c, token, pos,  # noqa: E731
                                  enc_out=enc_out)
    return jax.vmap(fn)(draws, caches)


def decode_step(params: dict, cfg: ArchConfig, cache: dict,
                token: jax.Array, pos: jax.Array, *,
                enc_out: Optional[jax.Array] = None):
    """One serving step. token: (B,1) int32; pos: (B,) absolute positions.
    Returns (logits (B, V), new_cache)."""
    pat, n_full, rem = _period_kinds(cfg)
    B = token.shape[0]
    # serving: cast params to bf16 ONCE, before the layer scan — otherwise
    # the per-step FSDP all-gathers move fp32 weights and convert after
    # (2x the ICI bytes; §Perf iteration 3).
    params = _cast_floating(params)
    x = params["embed"][token[:, 0]][:, None, :].astype(ACT_DTYPE)
    if enc_out is not None:
        enc_out = enc_out.astype(ACT_DTYPE)

    def body(x, inp):
        pp, cc = inp
        x = _shard_batch(x)
        for i, kind in enumerate(pat):
            x, c2 = _decode_layer(kind, pp[f"l{i}"], x, cfg, cc[f"l{i}"],
                                  pos, enc_out)
            cc = {**cc, f"l{i}": c2}
        return x, cc

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                           cache["blocks"]))
    new_cache = {"blocks": new_blocks}
    if rem:
        rb = {}
        for i, kind in enumerate(rem):
            x, c2 = _decode_layer(
                kind, params["rem_blocks"][f"l{i}"], x,
                cfg, cache["rem_blocks"][f"l{i}"], pos, enc_out)
            rb[f"l{i}"] = c2
        new_cache["rem_blocks"] = rb
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, new_cache
