"""Layer primitives shared by all assigned architecture families.

Everything is written against plain pytrees (nested dicts of jnp arrays) and
``jnp``/``jax.lax`` only — no flax. All sequence mixers come in two modes:

* ``forward_*``  — full-sequence (training / prefill), compile-memory bounded
  (chunked online-softmax attention, chunked linear-attention recurrences);
* ``decode_*``   — one-token step against a cache / recurrent state.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# norms / rope / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — chunked online-softmax (full-sequence mode)
#
# Forward is a lax.scan over KV blocks (flash-style online softmax).
# WITHOUT a custom VJP, jax.linearize of that scan saves the per-block
# probability matrices for backward: (nb, B, H, Sq, block_k) f32 residual
# stacks = ~17 GB per layer at 32k seq — the dominant HBM term in the
# roofline baseline (EXPERIMENTS.md §Perf iteration 1). The custom VJP
# below stores only (q, k, v, out, m, l) and RECOMPUTES p per block in the
# backward scan — the standard flash-attention backward, in pure JAX.
# ---------------------------------------------------------------------------

def _blockify(k, v, kv_positions, block_k):
    B, Sk = kv_positions.shape
    nb = cdiv(Sk, block_k)
    pad = nb * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)
    K, hd = k.shape[2], k.shape[3]
    kb = k.reshape(B, nb, block_k, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_k, K, hd).transpose(1, 0, 2, 3, 4)
    pb = kv_positions.reshape(B, nb, block_k).transpose(1, 0, 2)
    return kb, vb, pb, nb, pad


def _expand_heads(t, G):  # (B, bk, K, hd) -> (B, bk, K*G, hd)
    B, bk, K, hd = t.shape
    t = jnp.broadcast_to(t[:, :, :, None, :], (B, bk, K, G, hd))
    return t.reshape(B, bk, K * G, hd)


def _block_mask(pos, q_positions, causal, window):
    valid = pos[:, None, None, :] >= 0
    if causal:
        valid &= pos[:, None, None, :] <= q_positions[:, None, :, None]
    if window is not None:
        valid &= (pos[:, None, None, :]
                  > q_positions[:, None, :, None] - window)
    return valid  # (B, 1, Sq, bk)


def _flash_fwd_scan(q, k, v, q_positions, kv_positions, causal, window,
                    block_k):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    kb, vb, pb, nb, _ = _blockify(k, v, kv_positions, block_k)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, pos = blk
        kh = _expand_heads(kblk, G)
        vh = _expand_heads(vblk, G)
        s = jnp.einsum("bqhd,bchd->bhqc", q, kh,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_block_mask(pos, q_positions, causal, window), s,
                      NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p.astype(v.dtype), vh,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B, H, Sq, hd)
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash_attention(q, k, v, q_positions, kv_positions, causal, window,
                     block_k):
    out, _, _ = _flash_fwd_scan(q, k, v, q_positions, kv_positions, causal,
                                window, block_k)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B, Sq, H, hd)


def _flash_fwd(q, k, v, q_positions, kv_positions, causal, window, block_k):
    out, m, l = _flash_fwd_scan(q, k, v, q_positions, kv_positions, causal,
                                window, block_k)
    res = (q, k, v, q_positions, kv_positions, out, m, l)
    return out.transpose(0, 2, 1, 3).astype(q.dtype), res


def _flash_bwd(causal, window, block_k, res, dout):
    q, k, v, q_positions, kv_positions, out, m, l = res
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = hd ** -0.5
    kb, vb, pb, nb, pad = _blockify(k, v, kv_positions, block_k)
    do = dout.transpose(0, 2, 1, 3).astype(jnp.float32)  # (B, H, Sq, hd)
    linv = 1.0 / jnp.maximum(l, 1e-30)
    # D_t = sum_d do_td * out_td   (B, H, Sq)
    D = jnp.sum(do * out, axis=-1)

    def body(dq, blk):
        kblk, vblk, pos = blk
        kh = _expand_heads(kblk, G).astype(jnp.float32)
        vh = _expand_heads(vblk, G).astype(jnp.float32)
        qf = q.astype(jnp.float32)
        s = jnp.einsum("bqhd,bchd->bhqc", qf, kh) * scale
        valid = _block_mask(pos, q_positions, causal, window)
        p = jnp.where(valid, jnp.exp(s - m[..., None]), 0.0) \
            * linv[..., None]                                 # (B,H,Sq,bk)
        dvh = jnp.einsum("bhqc,bhqd->bchd", p, do)
        dp = jnp.einsum("bhqd,bchd->bhqc", do, vh)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bhqc,bchd->bqhd", ds, kh)
        dkh = jnp.einsum("bhqc,bqhd->bchd", ds, qf)
        # reduce the expanded heads back to K kv-heads
        dkb = dkh.reshape(B, -1, K, G, hd).sum(3)
        dvb = dvh.reshape(B, -1, K, G, hd).sum(3)
        return dq, (dkb, dvb)

    dq0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, pb))
    Skp = nb * block_k

    def unblock(t):
        t = t.transpose(1, 0, 2, 3, 4).reshape(B, Skp, K, hd)
        return t[:, :Skp - pad] if pad else t

    return (dq.astype(q.dtype), unblock(dkb).astype(k.dtype),
            unblock(dvb).astype(v.dtype), None, None)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Sk, K, hd)
    v: jax.Array,                 # (B, Sk, K, hd)
    *,
    q_positions: jax.Array,       # (B, Sq) absolute positions of queries
    kv_positions: jax.Array,      # (B, Sk) absolute positions of keys
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (None = full)
    block_k: int = 512,
) -> jax.Array:
    """Flash-style attention in pure JAX: scan over KV blocks with running
    max / normaliser so the (Sq x Sk) score matrix is never materialised,
    with a flash-style custom VJP (residuals: q,k,v,out,m,l only — the
    backward recomputes per-block probabilities).

    SPMD note: heads stay in the H layout throughout (H divides the 'model'
    axis for every assigned arch except whisper). KV heads are expanded
    K -> H per block via broadcast-reshape, which GSPMD re-shards; a
    (K, G) grouped reshape instead BREAKS propagation when the axis size
    does not divide K, silently replicating all-head compute on every
    model device (16x redundant flops — caught by the roofline analyzer).
    """
    assert q.shape[2] % k.shape[2] == 0, (q.shape, k.shape)
    return _flash_attention(q, k, v, q_positions, kv_positions, causal,
                            window, block_k)


def decode_attention(
    q: jax.Array,            # (B, 1, H, hd)
    k_cache: jax.Array,      # (B, S, K, hd)
    v_cache: jax.Array,      # (B, S, K, hd)
    kv_positions: jax.Array,  # (B, S) ; -1 marks empty slots
    q_position: jax.Array,   # (B,) current absolute position
) -> jax.Array:
    """Single-token attention over a (possibly ring-buffer) cache."""
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg,
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    valid = (kv_positions >= 0) & (kv_positions <= q_position[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# feed-forward (dense + MoE)
# ---------------------------------------------------------------------------

def ffn_apply(x: jax.Array, p: dict, ffn_type: str) -> jax.Array:
    if ffn_type == "silu":
        h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    elif ffn_type == "geglu":
        h = jax.nn.gelu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    elif ffn_type == "gelu":
        h = jax.nn.gelu(x @ p["wi_up"])
    else:
        raise ValueError(ffn_type)
    return h @ p["wo"]


def _moe_group(x: jax.Array, p: dict, *, top_k: int, ffn_type: str,
               capacity_factor: float) -> tuple[jax.Array, jax.Array]:
    """Sort-based (one-hot-free) top-k MoE dispatch for one token group.

    x: (T, D). Returns (T, D) output and the router aux (load-balance) loss.
    Capacity-dropped tokens fall back to a zero expert contribution, like
    GShard. The sort keeps dispatch O(T log T) instead of the O(T*E*C)
    one-hot einsum, which does not fit HBM at 32k sequence lengths.
    """
    T, D = x.shape
    E = p["experts_wo"].shape[0]
    gates = jax.nn.softmax(
        (x.astype(jnp.float32) @ p["router"].astype(jnp.float32)), axis=-1)
    top_w, top_i = jax.lax.top_k(gates, top_k)              # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_i[:, 0], E), axis=0)
    router_prob = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(density * router_prob)

    cap = max(top_k, int(math.ceil(T * top_k / E * capacity_factor)))

    slot_e = top_i.reshape(-1)                              # (T*k,)
    slot_w = top_w.reshape(-1)
    slot_t = jnp.arange(T * top_k) // top_k                 # token of each slot
    order = jnp.argsort(slot_e, stable=True)
    sorted_e = slot_e[order]
    counts = jnp.bincount(slot_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * top_k) - starts[sorted_e]
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, E * cap)  # overflow slot

    buf = jnp.zeros((E * cap + 1, D), x.dtype)
    buf = buf.at[dest].set(x[slot_t[order]])
    h = buf[: E * cap].reshape(E, cap, D)

    if ffn_type in ("silu", "geglu"):
        act = jax.nn.silu if ffn_type == "silu" else jax.nn.gelu
        hh = act(jnp.einsum("ecd,edf->ecf", h, p["experts_wi_gate"])) \
            * jnp.einsum("ecd,edf->ecf", h, p["experts_wi_up"])
    else:
        hh = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", h, p["experts_wi_up"]))
    out_slots = jnp.einsum("ecf,efd->ecd", hh, p["experts_wo"])
    out_slots = out_slots.reshape(E * cap, D)
    out_slots = jnp.concatenate(
        [out_slots, jnp.zeros((1, D), out_slots.dtype)], axis=0)

    gathered = out_slots[dest] * (slot_w[order] * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[slot_t[order]].add(gathered)
    return y, aux.astype(jnp.float32)


def _moe_dense_dispatch(x: jax.Array, p: dict, *, top_k: int,
                        ffn_type: str, capacity_factor: float
                        ) -> tuple[jax.Array, jax.Array]:
    """GShard-style grouped one-hot einsum dispatch.

    x: (G, Tg, D) token groups. All routing is expressed as cumsum + one-hot
    matmuls — no argsort / scatter — so GSPMD keeps the group dim sharded
    over 'data'. (The sort-based dispatch in _moe_group is kept as the
    dense-routing oracle for tests; under SPMD XLA replicates its scatter
    across the data axis and all-reduces ~34 GB of expert buffers per MoE
    layer — §Perf iteration 2.)
    """
    G, Tg, D = x.shape
    E = p["experts_wo"].shape[0]
    cap = max(top_k, int(math.ceil(Tg * top_k / E * capacity_factor)))
    gates = jax.nn.softmax(
        jnp.einsum("gtd,de->gte", x, p["router"].astype(x.dtype),
                   preferred_element_type=jnp.float32), axis=-1)

    # iterative top-k with capacity accounting (standard GShard routing)
    remaining = gates
    count_so_far = jnp.zeros((G, 1, E), jnp.float32)
    dispatch = jnp.zeros((G, Tg, E, cap), x.dtype)
    combine = jnp.zeros((G, Tg, E, cap), jnp.float32)
    weight_sum = jnp.zeros((G, Tg, 1), jnp.float32)
    picked = []
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                 # (G, Tg)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)   # (G, Tg, E)
        w = jnp.sum(gates * onehot, axis=-1, keepdims=True)  # (G, Tg, 1)
        pos = jnp.cumsum(onehot, axis=1) - onehot + count_so_far
        pos = jnp.sum(pos * onehot, axis=-1)                 # (G, Tg)
        keep = (pos < cap).astype(jnp.float32)[..., None]    # (G, Tg, 1)
        pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)  # (G, Tg, cap)
        d = (onehot * keep)[..., None] * pos_oh[:, :, None, :]
        dispatch = dispatch + d.astype(x.dtype)
        combine = combine + d * w[..., None]
        weight_sum = weight_sum + w * keep
        count_so_far = count_so_far + jnp.sum(onehot * keep, axis=1,
                                              keepdims=True)
        remaining = remaining * (1.0 - onehot)
        picked.append(onehot)

    combine = combine / jnp.maximum(weight_sum, 1e-9)[..., None]

    # aux load-balance loss (Switch-style, from the first choice)
    density = jnp.mean(picked[0], axis=(0, 1))
    router_prob = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(density * router_prob)

    h = jnp.einsum("gtec,gtd->gecd", dispatch, x)            # (G,E,cap,D)
    if ffn_type in ("silu", "geglu"):
        act = jax.nn.silu if ffn_type == "silu" else jax.nn.gelu
        hh = act(jnp.einsum("gecd,edf->gecf", h, p["experts_wi_gate"])) \
            * jnp.einsum("gecd,edf->gecf", h, p["experts_wi_up"])
    else:
        hh = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", h,
                                    p["experts_wi_up"]))
    out = jnp.einsum("gecf,efd->gecd", hh, p["experts_wo"])
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), out)
    return y, aux


MOE_GROUP_SIZE = 512


def moe_ffn(x: jax.Array, p: dict, *, top_k: int, ffn_type: str,
            capacity_factor: float) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (B, S, D), aux-loss scalar. Tokens are grouped into
    contiguous chunks of MOE_GROUP_SIZE per batch row; routing capacity is
    per-group. Dispatch is pure einsum (see _moe_dense_dispatch), so under
    pjit the batch/group dim stays sharded over 'data' — routing never
    leaves the client shard, matching the federated setting."""
    B, S, D = x.shape
    g = min(MOE_GROUP_SIZE, S)
    while S % g:
        g //= 2
    xg = x.reshape(B * (S // g), g, D)
    y, aux = _moe_dense_dispatch(xg, p, top_k=top_k, ffn_type=ffn_type,
                                 capacity_factor=capacity_factor)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def _rglru_gates(xc: jax.Array, p: dict):
    r = jax.nn.sigmoid(xc @ p["w_rec"])                     # recurrence gate
    i = jax.nn.sigmoid(xc @ p["w_inp"])                     # input gate
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r       # (B,S,D) in (-inf,0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xc)
    return a, gated


def _causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, width = w.shape[0]. x: (B,S,D), w: (W,D)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for t in range(W):
        out = out + xp[:, t:t + x.shape[1], :] * w[t]
    return out


def rglru_forward(x: jax.Array, p: dict, h0: Optional[jax.Array] = None):
    """Griffin recurrent block, full sequence via associative scan.

    x: (B,S,D). Returns (y, h_last). Linear diagonal recurrence
    h_t = a_t * h_{t-1} + b_t is computed with jax.lax.associative_scan —
    O(log S) depth, no (S x S) materialisation.
    """
    xin = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    xc = _causal_conv1d(xin, p["conv_w"])
    a, b = _rglru_gates(xc.astype(jnp.float32), p)
    if h0 is not None:
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_last = h[:, -1, :]
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    return y, h_last


def rglru_decode(x: jax.Array, p: dict, state: dict):
    """One step. x: (B,1,D). state: {'h': (B,D), 'conv': (B,W-1,D)}."""
    xin = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    W = p["conv_w"].shape[0]
    hist = jnp.concatenate([state["conv"], xin], axis=1)    # (B, W, D)
    xc = jnp.einsum("bwd,wd->bd", hist, p["conv_w"])[:, None, :]
    a, b = _rglru_gates(xc.astype(jnp.float32), p)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    y = (h[:, None, :].astype(x.dtype) * gate) @ p["w_out"]
    return y, {"h": h, "conv": hist[:, 1:, :]}


def rglru_init_state(batch: int, d: int, conv_width: int, dtype) -> dict:
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "conv": jnp.zeros((batch, conv_width - 1, d), dtype)}


# ---------------------------------------------------------------------------
# RWKV-6 time mix (chunked linear attention with data-dependent decay)
# ---------------------------------------------------------------------------

def _rwkv_projections(x: jax.Array, p: dict, x_prev: jax.Array):
    """Token-shift mixes + r/k/v/decay projections.

    x: (B,S,D); x_prev: (B,S,D) sequence shifted right by one.
    Returns r,k,v: (B,S,H,hd); log_w: (B,S,H,hd) (<= ~0, per-channel decay).
    """
    B, S, D = x.shape
    H, hd = p["u"].shape

    def mix(mu):
        return x + mu * (x_prev - x)

    r = (mix(p["mu_r"]) @ p["w_r"]).reshape(B, S, H, hd)
    k = (mix(p["mu_k"]) @ p["w_k"]).reshape(B, S, H, hd)
    v = (mix(p["mu_v"]) @ p["w_v"]).reshape(B, S, H, hd)
    xw = mix(p["mu_w"]).astype(jnp.float32)
    dd = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]       # (B,S,D)
    log_w = -jnp.exp(
        jnp.clip(p["w0"] + dd, -8.0, 8.0)).reshape(B, S, H, hd)
    return r, k, v, log_w


def rwkv_forward(x: jax.Array, p: dict, state: Optional[dict] = None,
                 chunk: int = 64):
    """RWKV-6 time-mix over a full sequence, chunked linear-attention form.

    Intra-chunk pairwise decays are exp(L_t - L_tau) with tau < t, which is
    always <= 1 — numerically stable without clamping tricks. Cross-chunk
    state S: (B,H,hd,hd) carried by lax.scan.
    """
    B, S, D = x.shape
    H, hd = p["u"].shape
    x_prev0 = jnp.zeros((B, 1, D), x.dtype) if state is None \
        else state["x_prev"][:, None, :]
    x_shift = jnp.concatenate([x_prev0, x[:, :-1, :]], axis=1)
    r, k, v, log_w = _rwkv_projections(x, p, x_shift)
    u = p["u"].astype(jnp.float32)

    nb = cdiv(S, chunk)
    pad = nb * chunk - S
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, z4) for t in (r, k, v))
        log_w = jnp.pad(log_w, z4)  # pad decays with 0 (=> w=1, harmless)

    def to_chunks(t):
        return t.reshape(B, nb, chunk, H, hd).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(to_chunks, (r.astype(jnp.float32),
                                      k.astype(jnp.float32),
                                      v.astype(jnp.float32), log_w))
    # cumulative log decay within each chunk (inclusive)
    L = jnp.cumsum(lwc, axis=3)                             # (nb,B,H,C,hd)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def body(S0, blk):
        rb, kb, vb, Lb, lwb = blk                           # (B,H,C,hd)
        # Query-side decays are EXCLUSIVE of the current step (o_t reads
        # S_{t-1}), matching rwkv_decode exactly: decay(tau -> t) =
        # prod_{j=tau+1}^{t-1} w_j = exp(Lq_t - L_tau), Lq = L - log_w.
        Lq = Lb - lwb
        diff = Lq[:, :, :, None, :] - Lb[:, :, None, :, :]  # (B,H,C,C,hd)
        att = jnp.einsum("bhtc,bhsc,bhtsc->bhts", rb, kb,
                         jnp.exp(jnp.where(causal[None, None, :, :, None],
                                           diff, NEG_INF)))
        att = jnp.where(causal[None, None], att, 0.0)
        o_intra = jnp.einsum("bhts,bhsd->bhtd", att, vb)
        # current-token bonus u
        o_diag = (rb * (u[None, :, None, :] * kb)).sum(-1, keepdims=True) * vb
        # cross-chunk: state as of chunk start, decayed to position t
        # (exclusive: o_t sees S0 decayed by w_{0..t-1} within this chunk)
        o_inter = jnp.einsum("bhtc,bhcd->bhtd", rb * jnp.exp(Lq), S0)
        # state update: S' = diag(exp(L_C)) S0 + sum_tau exp(L_C - L_tau) k v^T
        wC = jnp.exp(Lb[:, :, -1:, :])                      # (B,H,1,hd)
        kdec = kb * jnp.exp(Lb[:, :, -1:, :] - Lb)
        S_new = wC.transpose(0, 1, 3, 2) * S0 + \
            jnp.einsum("bhtc,bhtd->bhcd", kdec, vb)
        return S_new, o_intra + o_diag + o_inter

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32) if state is None \
        else state["S"].astype(jnp.float32)
    # remat the chunk body: without it, scan linearization stacks the
    # (nb,B,H,C,C,hd) pairwise-decay tensors (~17 GB/layer at 4k seq) as
    # backward residuals — the dominant HBM term for this family
    # (EXPERIMENTS.md §Perf iteration 4). Residuals drop to the body
    # inputs (O(S) total); backward recomputes exp(diff) per chunk.
    S_last, o = jax.lax.scan(jax.checkpoint(body), S0, (rc, kc, vc, L, lwc))
    # (nb, B, H, C, hd) -> (B, nb, C, H, hd) -> (B, S, H, hd)
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, nb * chunk, H, hd)[:, :S]
    y = (o.reshape(B, S, H * hd).astype(x.dtype)) @ p["w_o"]
    new_state = {"S": S_last, "x_prev": x[:, -1, :]}
    return y, new_state


def rwkv_decode(x: jax.Array, p: dict, state: dict):
    """One step. x: (B,1,D). state: {'S': (B,H,hd,hd), 'x_prev': (B,D)}."""
    B, _, D = x.shape
    H, hd = p["u"].shape
    r, k, v, log_w = _rwkv_projections(x, p, state["x_prev"][:, None, :])
    r, k, v = (t[:, 0].astype(jnp.float32) for t in (r, k, v))  # (B,H,hd)
    w = jnp.exp(log_w[:, 0].astype(jnp.float32))
    u = p["u"].astype(jnp.float32)
    S = state["S"].astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]                  # (B,H,hd,hd)
    o = jnp.einsum("bhc,bhcd->bhd", r, S + u[None, :, :, None] * kv)
    S_new = w[..., :, None] * S + kv
    y = (o.reshape(B, 1, H * hd).astype(x.dtype)) @ p["w_o"]
    return y, {"S": S_new, "x_prev": x[:, 0, :]}


def rwkv_init_state(batch: int, num_heads: int, head_dim: int, d: int,
                    dtype) -> dict:
    return {"S": jnp.zeros((batch, num_heads, head_dim, head_dim),
                           jnp.float32),
            "x_prev": jnp.zeros((batch, d), dtype)}
