from repro.models.model import (  # noqa: F401
    ACT_DTYPE,
    broadcast_cache,
    decode_step,
    encoder_forward,
    ensemble_decode_step,
    forward,
    init_cache,
    init_params,
    log_lik_fn,
    prefill_with_cache,
)
