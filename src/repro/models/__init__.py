from repro.models.model import (  # noqa: F401
    ACT_DTYPE,
    decode_step,
    encoder_forward,
    forward,
    init_cache,
    init_params,
    log_lik_fn,
    prefill_with_cache,
)
