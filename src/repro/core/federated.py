"""Algorithm 1 (FSGLD) simulator: client-side Update + server-side
Reassign_chain, with the paper's exact semantics (i.i.d. Categorical(f)
reassignment, T_local in-shard updates per round).

Shard data is stacked along a leading S axis so shard selection stays
jit-traceable. Multiple chains run via vmap (the parallel regime Ahn et al.
describe); `reassign='permutation'` switches to the collision-free SPMD
variant (DESIGN.md Sec 4.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig
from repro.core.sampler import LogLikFn, ShardScheme, make_step_fn
from repro.core.surrogate import (Gaussian, SurrogateBank, fit_gaussian,
                                  make_bank)

PyTree = Any


def _minibatch(key, shard_data: PyTree, shard_id, n_s: int, m: int) -> PyTree:
    """Sample m indices with replacement from shard ``shard_id`` (matching
    the with-replacement assumption in the Theorem 1/2 proofs)."""
    data_s = jax.tree.map(lambda d: d[shard_id], shard_data)
    idx = jax.random.randint(key, (m,), 0, n_s)
    return jax.tree.map(lambda d: d[idx], data_s)


@dataclasses.dataclass
class FederatedSampler:
    """The ``run_vmap`` bit-exactness ORACLE: the legacy single-host vmap
    executor the mesh chain engine is regression-tested against
    (tests/test_mesh_engine.py, tests/test_parity_matrix.py). This is an
    internal testing fixture — production code constructs the sampler
    through ``repro.api.FSGLD``, which routes every workload through the
    engine. (The old ``run``-delegation shim and its DeprecationWarning
    were removed after two majors; see the README migration table.)

    shard_data: pytree with leaves (S, N_s, ...) — equally-sized shards.
    ``dynamics='sghmc'`` swaps the Langevin step for the federated SGHMC
    integrator (core/sghmc.py; ``sghmc`` carries friction/temperature) —
    chain state becomes the (theta, momentum) pair, the trace stays
    theta-only. Combined with ``use_kernel`` this is the ``run_vmap``
    oracle for the engine's fused SGHMC executors.
    """
    log_lik_fn: LogLikFn
    cfg: SamplerConfig
    shard_data: PyTree
    minibatch: int
    bank: Optional[SurrogateBank] = None
    use_kernel: bool = False
    dynamics: str = "langevin"
    sghmc: Any = None  # Optional[SGHMCConfig]; None -> defaults

    def __post_init__(self):
        leaf = jax.tree.leaves(self.shard_data)[0]
        s, n = leaf.shape[0], leaf.shape[1]
        assert s == self.cfg.num_shards, (s, self.cfg.num_shards)
        self.scheme = ShardScheme(sizes=(n,) * s, probs=self.cfg.probs())
        if self.dynamics == "sghmc":
            from repro.core.sghmc import SGHMCConfig, make_sghmc_step
            if self.sghmc is None:
                self.sghmc = SGHMCConfig()
            self.step_fn = make_sghmc_step(
                self.log_lik_fn, self.cfg, self.scheme, self.bank,
                self.sghmc, use_kernel=self.use_kernel)
        elif self.dynamics == "langevin":
            self.step_fn = make_step_fn(
                self.log_lik_fn, self.cfg, self.scheme, self.bank,
                use_kernel=self.use_kernel)
        else:
            raise ValueError(self.dynamics)
        # built once: re-wrapping vmap per run() call would retrace every
        # time (jit caches on callable identity)
        self._vround = jax.jit(jax.vmap(self._round,
                                        in_axes=(0, 0, 0, None)))

    # -- client-side Update(T, theta_0, s) --------------------------------
    def _round(self, state, key, shard_id, bank_rt=None):
        n_s = self.scheme.sizes[0]

        def body(carry, k):
            state = carry
            k_batch, k_step = jax.random.split(k)
            if self.cfg.method == "sgld":  # centralized: pool all shards
                pooled = jax.tree.map(
                    lambda d: d.reshape((-1,) + d.shape[2:]),
                    self.shard_data)
                idx = jax.random.randint(k_batch, (self.minibatch,), 0,
                                         self.scheme.total)
                batch = jax.tree.map(lambda d: d[idx], pooled)
            else:
                batch = _minibatch(k_batch, self.shard_data, shard_id, n_s,
                                   self.minibatch)
            state = self.step_fn(state, k_step, batch, shard_id,
                                 self.minibatch, bank_rt=bank_rt)
            return state, (state[0] if self.dynamics == "sghmc" else state)

        keys = jax.random.split(key, self.cfg.local_updates)
        state, trace = jax.lax.scan(body, state, keys)
        return state, trace

    # -- server-side loop ---------------------------------------------------
    def run_vmap(self, key: jax.Array, theta0: PyTree, num_rounds: int,
                 *, n_chains: int = 1, reassign: str = "categorical",
                 collect_every: int = 1,
                 refresh_every: Optional[int] = None):
        """LEGACY single-host vmap executor (pre-mesh runtime). Kept as the
        bit-exactness oracle for the shard_map engine; prefer ``run``."""
        if refresh_every and self.dynamics == "sghmc":
            raise NotImplementedError(
                "adaptive refresh is not wired for sghmc dynamics")
        probs = jnp.asarray(self.cfg.probs())
        S = self.cfg.num_shards
        if self.dynamics == "sghmc":
            from repro.core.sghmc import init_momentum
            theta0 = (theta0, init_momentum(theta0))
        chains = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (n_chains,) + t.shape).copy(),
            theta0)
        bank_rt = self.bank
        vround = self._vround
        out = []
        for r in range(num_rounds):
            key, k_assign, k_run = jax.random.split(key, 3)
            if self.cfg.method == "sgld":
                sids = jnp.zeros((n_chains,), jnp.int32)
            elif reassign == "categorical":   # paper Algorithm 1
                sids = jax.random.categorical(
                    k_assign, jnp.log(probs)[None].repeat(n_chains, 0))
            elif reassign == "permutation":   # SPMD variant (DESIGN 4.1)
                perm = jax.random.permutation(k_assign, S)
                if n_chains > S:
                    # block-cyclic client visiting: chain c sits at
                    # client perm[c % S] (matches the engine's tiled
                    # slice bitwise)
                    perm = jnp.tile(perm, -(-n_chains // S))
                sids = perm[:n_chains]
            else:
                raise ValueError(reassign)
            if (refresh_every and self.cfg.method == "fsgld" and r > 0
                    and r % refresh_every == 0):
                # adaptive refresh (paper Conclusion's future work): re-fit
                # the surrogates around the current chain position — the
                # surrogate gradient is exact at the refresh point.
                center = jax.tree.map(lambda t: t.mean(0), chains)
                bank_rt = refresh_bank(self.log_lik_fn, self.shard_data,
                                       center)
            chains, trace = vround(chains,
                                   jax.random.split(k_run, n_chains), sids,
                                   bank_rt)
            take = jax.tree.map(lambda t: t[:, ::collect_every], trace)
            out.append(take)
        # list of (chains, T/collect, ...) -> (chains, rounds*T/collect, ...)
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, 1), *out)


# ---------------------------------------------------------------------------
# surrogate fitting: local SGLD per shard, once, before FSGLD (paper Sec 3.1)
# ---------------------------------------------------------------------------

def sample_local_likelihood(log_lik_fn: LogLikFn, shard_data: PyTree,
                            theta0: PyTree, key: jax.Array, *,
                            minibatch: int, step_size: float,
                            num_steps: int, burn_in: int, thin: int = 10,
                            prior_precision: float = 0.0) -> PyTree:
    """Run SGLD independently per shard against p_s ∝ p(x_s|theta)
    (optionally tempered by a weak prior for stability). Vmapped over the
    shard axis — this is the 'computed independently in parallel on the
    client side' phase. Returns samples with leaves (S, n_kept, ...)."""
    leaf = jax.tree.leaves(shard_data)[0]
    S, n_s = leaf.shape[0], leaf.shape[1]

    def one_shard(data_s, k):
        def body(theta, kk):
            k1, k2 = jax.random.split(kk)
            idx = jax.random.randint(k1, (minibatch,), 0, n_s)
            batch = jax.tree.map(lambda d: d[idx], data_s)
            g = jax.grad(log_lik_fn)(theta, batch)
            drift = jax.tree.map(
                lambda t, gg: -prior_precision * t
                + (n_s / minibatch) * gg.astype(t.dtype), theta, g)
            noise_keys = jax.random.split(k2, len(jax.tree.leaves(theta)))
            leaves, treedef = jax.tree.flatten(theta)
            dleaves = jax.tree.leaves(drift)
            new = [t + (step_size / 2) * d
                   + jnp.sqrt(step_size)
                   * jax.random.normal(nk, t.shape, t.dtype)
                   for t, d, nk in zip(leaves, dleaves, noise_keys)]
            theta = jax.tree.unflatten(treedef, new)
            return theta, theta

        keys = jax.random.split(k, num_steps)
        _, trace = jax.lax.scan(body, theta0, keys)
        return jax.tree.map(lambda t: t[burn_in::thin], trace)

    return jax.jit(jax.vmap(one_shard))(shard_data,
                                        jax.random.split(key, S))


def fit_bank_fisher(log_lik_fn: LogLikFn, shard_data: PyTree,
                    means: jax.Array, jitter: float = 1e-3,
                    batch: int = 256,
                    tie_precisions: bool = False) -> SurrogateBank:
    """Laplace-style surrogates (paper App. F.2): q_s = N(mu_s, Lambda_s^-1)
    with mu_s e.g. the local SGLD sample mean and Lambda_s the DIAGONAL
    EMPIRICAL FISHER of the local likelihood at mu_s:

        Lambda_s = sum_{x_i in shard s} grad log p(x_i|mu_s)^2 + jitter

    Unlike sample-covariance fits, the Fisher is correctly scaled with N_s
    by construction, so the conducive anti-restoring term (Lambda_s/f_s)
    matches the data restoring force it must cancel — under-mixed local
    chains cannot blow it up (see fit_bank_from_samples docstring)."""
    leaf = jax.tree.leaves(shard_data)[0]
    S, n_s = leaf.shape[0], leaf.shape[1]

    def one_shard(data_s, mu):
        def g2(i):
            item = jax.tree.map(
                lambda d: jax.lax.dynamic_slice_in_dim(d, i, 1), data_s)
            g = jax.grad(log_lik_fn)(mu, item)
            return g * g
        return jax.lax.map(g2, jnp.arange(n_s), batch_size=batch).sum(0)

    precs = jax.jit(jax.vmap(one_shard))(shard_data, means) + jitter
    if tie_precisions:
        # Beyond-paper stability device: share the per-dim MEAN Fisher
        # across shards. With identical Lambda the conducive gradient
        # g_s = S * Lambda * (mu_bar - mu_s) is CONSTANT in theta — it
        # cancels the first-order (mode-offset) heterogeneity exactly,
        # is zero-mean (Lemma 1), and adds no quadratic force, so it can
        # never destabilise the chain the way mismatched curvatures can
        # on non-convex (ReLU) posteriors. See EXPERIMENTS.md §Repro.
        precs = jnp.broadcast_to(precs.mean(0, keepdims=True),
                                 precs.shape)
    return make_bank(means, precs, "diag")


def refresh_bank(log_lik_fn: LogLikFn, shard_data: PyTree,
                 theta: jax.Array, jitter: float = 1e-3,
                 batch: int = 256) -> SurrogateBank:
    """Adaptive surrogate refresh at the current chain position theta
    (the paper Conclusion's future work, implemented):

        Lambda_s = CENTERED diag empirical Fisher at theta
                 = sum_i (g_i - g_bar)^2        (g_i per-point scores)
        mu_s     = theta + Lambda_s^{-1} grad log p(x_s | theta)

    One Newton-like step makes grad log q_s(theta) == grad log p(x_s|theta)
    EXACTLY at the refresh point (gradient matching, cf. Remark 3). The
    CENTERED Fisher matters: the raw second moment is inflated by the
    squared mean score away from the local mode (E[g^2] = Var g + (E g)^2),
    which over-sharpens Lambda_s and re-creates the anti-restoring-force
    instability; the score variance estimates the curvature at any theta
    (exact for the Gaussian-mean model: N_s * I). Costs one gradient +
    Fisher pass per client per refresh.
    """
    leaf = jax.tree.leaves(shard_data)[0]
    S, n_s = leaf.shape[0], leaf.shape[1]

    def one_shard(data_s):
        def gpair(i):
            item = jax.tree.map(
                lambda d: jax.lax.dynamic_slice_in_dim(d, i, 1), data_s)
            g = jax.grad(log_lik_fn)(theta, item)
            return g, g * g
        g, g2 = jax.lax.map(gpair, jnp.arange(n_s), batch_size=batch)
        gsum = g.sum(0)
        centered = g2.sum(0) - gsum * gsum / n_s
        return gsum, centered

    b, fisher = jax.jit(jax.vmap(one_shard))(shard_data)
    precs = jnp.maximum(fisher, 0.0) + jitter
    mus = theta[None] + b / precs
    return make_bank(mus, precs, "diag")


def fit_bank_linear(log_lik_fn: LogLikFn, shard_data: PyTree,
                    theta_ref: PyTree, batch: int = 256) -> SurrogateBank:
    """Linear (control-variate) surrogates — beyond-paper:

        log q_s(theta) = b_s . theta,   b_s = grad log p(x_s | theta_ref)

    The conducive gradient becomes the CONSTANT g_s = sum_s' b_s' - S b_s:
    exactly zero-mean (Lemma 1 needs only Lipschitz log q), bounded (no
    quadratic force => unconditionally stable on non-convex posteriors
    where Gaussian surrogates can diverge), and it cancels the first-order
    shard heterogeneity exactly at theta_ref — the SCAFFOLD control-variate
    idea transplanted into the FSGLD framework. One full-shard gradient
    pass per client, computed once and communicated once."""
    leaf = jax.tree.leaves(shard_data)[0]
    S, n_s = leaf.shape[0], leaf.shape[1]

    def one_shard(data_s):
        def g(i):
            item = jax.tree.map(
                lambda d: jax.lax.dynamic_slice_in_dim(d, i * batch,
                                                       batch), data_s)
            return jax.grad(log_lik_fn)(theta_ref, item)
        nb = n_s // batch
        out = jax.lax.map(g, jnp.arange(nb))
        total = jax.tree.map(lambda x: x.sum(0), out)
        rem = n_s - nb * batch
        if rem:
            tail = jax.tree.map(lambda d: d[nb * batch:], data_s)
            gt = jax.grad(log_lik_fn)(theta_ref, tail)
            total = jax.tree.map(jnp.add, total, gt)
        return total

    bs = jax.jit(jax.vmap(one_shard))(shard_data)   # leaves (S, ...)
    zeros = jax.tree.map(lambda b: jnp.zeros_like(b), bs)
    return make_bank(bs, zeros, "linear")


def fit_bank_from_samples(samples_flat: jax.Array, kind: str,
                          jitter: float = 1e-6,
                          max_prec: Optional[float] = None) -> SurrogateBank:
    """samples_flat: (S, n, P) flat-vector samples -> SurrogateBank.

    ``max_prec`` clips per-dimension precisions. Under-mixed local chains
    underestimate likelihood variance and so OVERestimate precision; a
    too-sharp q_s makes the conducive term h*Lambda_s/f_s exceed the
    Langevin stability limit and the chain diverges. Clipping keeps the
    estimator unbiased (Lemma 1 holds for ANY Lipschitz q — only the
    variance-reduction quality degrades). A safe choice is
    max_prec ~ 0.5 * f_min / step_size.
    """
    mus, precs = jax.vmap(lambda s: fit_gaussian(s, kind, jitter))(
        samples_flat)
    if max_prec is not None:
        precs = jnp.minimum(precs, max_prec)
    return make_bank(mus, precs, kind)
