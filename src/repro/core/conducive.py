"""Conducive gradients (paper Sec 3, Eq. 5-7) — the core contribution.

    g_s(theta) = grad log q(theta) - (1/f_s) grad log q_s(theta)

Zero-mean under shard selection s ~ Categorical(f) (Lemma 1):
    E_s[g_s] = grad log q - sum_s f_s (1/f_s) grad log q_s = 0.

Remark 1's alpha knob scales the exploration term. alpha=0 recovers DSGLD.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.core.surrogate import Gaussian, SurrogateBank

PyTree = Any


def conducive_gradient(theta: PyTree, q_global: Gaussian, q_s: Gaussian,
                       f_s, alpha: float = 1.0) -> PyTree:
    """g_s(theta), computed from the two resident surrogates only."""
    g_glob = q_global.grad_log(theta)
    g_loc = q_s.grad_log(theta)
    return jax.tree.map(
        lambda a, b: alpha * (a - b / f_s), g_glob, g_loc)


def conducive_gradient_from_bank(theta: PyTree, bank: SurrogateBank, s,
                                 f_s, alpha: float = 1.0) -> PyTree:
    return conducive_gradient(theta, bank.global_, bank.shard(s), f_s, alpha)
