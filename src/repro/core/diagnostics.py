"""MCMC convergence diagnostics: split-R-hat and effective sample size.

Standard substrate for a sampling framework — used by the examples to
report chain quality and by tests to assert mixing. Conventions follow
Gelman et al. (BDA3) / Vehtari et al. (2021): chains (C, N, ...) with
C >= 1; statistics are computed per scalar dimension and reduced with max
(R-hat) / min (ESS) for the headline number.

Fault discipline: a non-finite trace makes every moment here NaN, and a
NaN R-hat reads exactly like a converged one in a `< 1.01` assertion —
so ``rhat``/``ess``/``summarize`` REFUSE non-finite traces loudly.
Runs with quarantined chains pass ``mask`` (``RunHealth.healthy`` from
the engine) to exclude them before the check; the statistics are then
computed over the healthy chains only.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _select(chains: jax.Array, mask, who: str) -> jax.Array:
    """Apply the per-chain health mask, then refuse non-finite traces."""
    if mask is not None:
        mask = np.asarray(mask, bool)
        if mask.shape != (chains.shape[0],):
            raise ValueError(
                f"health mask shape {mask.shape} != (n_chains,) = "
                f"({chains.shape[0]},)")
        if not mask.any():
            raise ValueError(
                f"{who}: health mask excludes every chain — no healthy "
                "chains to diagnose")
        chains = chains[np.flatnonzero(mask)]
    if not bool(jnp.all(jnp.isfinite(chains))):
        raise ValueError(
            f"{who}: trace contains non-finite values — a NaN here would "
            "silently poison the statistic. Run with a recovery policy "
            "and pass mask=RunHealth.healthy to exclude diverged chains.")
    return chains


def _split_chains(x: jax.Array) -> jax.Array:
    """(C, N, ...) -> (2C, N//2, ...) split-half chains.

    Odd N: the FIRST sample (the one closest to burn-in) is dropped so
    both halves are contiguous equal-length blocks — documented
    truncation instead of silently losing a sample from the middle of
    the chain (the old ``x[:, n:2n]`` slice).
    """
    N = x.shape[1]
    if N % 2:
        x = x[:, 1:]
        N -= 1
    n = N // 2
    return jnp.concatenate([x[:, :n], x[:, n:]], axis=0)


def rhat(chains: jax.Array, *, mask: Optional[jax.Array] = None
         ) -> jax.Array:
    """Split-R-hat per dimension. chains: (C, N, ...) -> (...).

    Needs N >= 4: split halves must hold >= 2 samples each for the
    ddof=1 within-chain variance to exist (shorter traces would return
    NaN silently — refuse loudly instead). Non-finite traces are refused
    too; ``mask`` (per-chain bool, ``RunHealth.healthy``) excludes
    quarantined chains first."""
    chains = _select(chains, mask, "rhat")
    if chains.shape[1] < 4:
        raise ValueError(
            f"rhat needs >= 4 samples per chain (got N={chains.shape[1]}): "
            "split halves must each hold >= 2 samples")
    x = _split_chains(chains.astype(jnp.float64)
                      if jax.config.read("jax_enable_x64")
                      else chains.astype(jnp.float32))
    C, N = x.shape[:2]
    mean_c = x.mean(axis=1)                      # (C, ...)
    var_c = x.var(axis=1, ddof=1)                # (C, ...)
    W = var_c.mean(axis=0)
    B = N * mean_c.var(axis=0, ddof=1)
    var_hat = (N - 1) / N * W + B / N
    return jnp.sqrt(var_hat / jnp.maximum(W, 1e-30))


def ess(chains: jax.Array, max_lag: int = 200, *,
        mask: Optional[jax.Array] = None) -> jax.Array:
    """Bulk effective sample size per dimension via the initial-positive
    autocorrelation-sum estimator. chains: (C, N, ...) -> (...).

    ``max_lag`` is clamped to N//2 - 1 for short traces: the biased-FFT
    autocovariance at lags beyond half the trace averages over fewer
    than N/2 products and is pure noise — summing it would let a short
    trace report an arbitrarily wrong tau (the old N-1 clamp did exactly
    that). Floor of 1 keeps N <= 4 traces defined (tau from lag 1).
    Non-finite traces are refused; ``mask`` excludes unhealthy chains."""
    chains = _select(chains, mask, "ess")
    x = chains.astype(jnp.float32)
    C, N = x.shape[:2]
    xc = x - x.mean(axis=1, keepdims=True)
    var = x.var(axis=1).mean(axis=0)             # (...)
    max_lag = min(max_lag, max(N // 2 - 1, 1))

    # FFT autocovariance (dynamic-slice-free, vectorised over dims)
    nfft = 2 * N
    f = jnp.fft.rfft(xc, n=nfft, axis=1)
    acov = jnp.fft.irfft(f * jnp.conj(f), n=nfft, axis=1)[:, :N]
    acov = acov / N                              # (C, N, ...)
    rhos = acov[:, 1:max_lag + 1].mean(axis=0) \
        / jnp.maximum(var, 1e-30)                # (max_lag, ...)
    # truncate at first negative autocorrelation (Geyer initial positive)
    positive = jnp.cumprod(rhos > 0, axis=0).astype(rhos.dtype)
    tau = 1.0 + 2.0 * jnp.sum(rhos * positive, axis=0)
    return C * N / jnp.maximum(tau, 1.0)


def summarize(chains: jax.Array, *,
              mask: Optional[jax.Array] = None) -> dict:
    """Headline diagnostics for a (C, N, D) trace. ``mask`` (per-chain
    bool, e.g. ``RunHealth.healthy``) restricts the statistics to the
    healthy chains and reports how many were excluded."""
    r = rhat(chains, mask=mask)
    e = ess(chains, mask=mask)
    out = {"max_rhat": float(jnp.max(r)), "min_ess": float(jnp.min(e)),
           "mean_ess": float(jnp.mean(e))}
    if mask is not None:
        m = np.asarray(mask, bool)
        out["n_healthy"] = int(m.sum())
        out["n_excluded"] = int((~m).sum())
    return out
