"""Federated stochastic-gradient Hamiltonian Monte Carlo with conducive
gradients (beyond-paper: the paper notes conducive gradients are a generic
variance-reduction device for SG-MCMC; SGHMC [Chen et al. 2014] is the
natural second member of the family).

Update (naive-Euler SGHMC with friction C = alpha_f / h):

    r'     = (1 - alpha_f) r + h * drift(theta) + N(0, 2*alpha_f*T*h... )
    theta' = theta + r'

where ``drift`` is EXACTLY the same estimator stack as FSGLD
(prior + scaled minibatch gradient + conducive term), so Lemma 1's
unbiasedness carries over unchanged — conducive gradients compose with any
SG-MCMC drift.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig
from repro.core.sampler import (LogLikFn, ShardScheme, make_drift_fn,
                                tree_randn_like)
from repro.core.surrogate import SurrogateBank

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGHMCConfig:
    friction: float = 0.1   # alpha_f = C * h
    temperature: float = 1.0


def make_sghmc_step(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                    scheme: ShardScheme,
                    bank: Optional[SurrogateBank] = None,
                    hmc: SGHMCConfig = SGHMCConfig(),
                    use_kernel: bool = False):
    """Returns step((theta, r), key, batch, shard_id, m) -> (theta', r').

    cfg.method selects the drift ('sgld'/'dsgld' -> plain, 'fsgld' ->
    + conducive term); momenta r live in the same pytree structure.
    ``use_kernel=True`` routes the update through the fused Pallas SGHMC
    integrator (kernels/ops.py, ``dynamics='sghmc'``) — same drift, one
    HBM pass, in-kernel hash noise with the same per-leaf seed derivation
    as the Langevin kernel path."""
    if use_kernel:
        from repro.core.sampler import kernel_step_operands
        from repro.kernels import ops as kops
        resolve = kernel_step_operands(cfg, scheme, bank)

        def step(state, key, batch, shard_id, m, step_size=None,
                 bank_rt=None, sp_rt=None):
            theta, r = state
            h = cfg.step_size if step_size is None else step_size
            gll = jax.grad(log_lik_fn)(theta, batch)
            scale, f_s, q_g, q_s = resolve(shard_id, m, bank_rt, sp_rt)
            return kops.fused_update_tree(
                theta, gll, key, h=h, scale=scale, f_s=f_s,
                prior_prec=cfg.prior_precision, alpha=cfg.alpha,
                temperature=hmc.temperature, q_global=q_g, q_shard=q_s,
                surrogate_kind=(bank.kind if bank is not None else None),
                momentum=r, friction=hmc.friction, dynamics="sghmc")

        return step

    drift_fn = make_drift_fn(log_lik_fn, cfg, scheme, bank)
    a = hmc.friction
    noise_sig = jnp.sqrt(2.0 * a * hmc.temperature)

    def step(state, key, batch, shard_id, m, step_size=None, bank_rt=None,
             sp_rt=None):
        theta, r = state
        h = cfg.step_size if step_size is None else step_size
        d = drift_fn(theta, batch, shard_id, m, bank_rt, sp_rt)
        xi = tree_randn_like(key, theta)
        r = jax.tree.map(
            lambda rr, dd, nn: ((1.0 - a) * rr + h * dd.astype(rr.dtype)
                                + (noise_sig * jnp.sqrt(h))
                                * nn.astype(rr.dtype)),
            r, d, xi)
        theta = jax.tree.map(lambda t, rr: t + rr, theta, r)
        return theta, r

    return step


def init_momentum(theta: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, theta)


@dataclasses.dataclass
class FederatedSGHMC:
    """Algorithm-1-style runtime for federated SGHMC: T_local in-client
    steps, i.i.d. categorical reassignment, momenta carried with the chain
    (they are part of the chain state the paper would 'mail')."""
    log_lik_fn: LogLikFn
    cfg: SamplerConfig
    shard_data: PyTree
    minibatch: int
    bank: Optional[SurrogateBank] = None
    hmc: SGHMCConfig = dataclasses.field(default_factory=SGHMCConfig)

    def __post_init__(self):
        leaf = jax.tree.leaves(self.shard_data)[0]
        s, n = leaf.shape[0], leaf.shape[1]
        assert s == self.cfg.num_shards
        self.scheme = ShardScheme(sizes=(n,) * s, probs=self.cfg.probs())
        self.step_fn = make_sghmc_step(self.log_lik_fn, self.cfg,
                                       self.scheme, self.bank, self.hmc)

    def _round(self, state, key, shard_id):
        n_s = self.scheme.sizes[0]

        def body(carry, k):
            state = carry
            k1, k2 = jax.random.split(k)
            data_s = jax.tree.map(lambda d: d[shard_id], self.shard_data)
            idx = jax.random.randint(k1, (self.minibatch,), 0, n_s)
            batch = jax.tree.map(lambda d: d[idx], data_s)
            state = self.step_fn(state, k2, batch, shard_id,
                                 self.minibatch)
            return state, state[0]

        keys = jax.random.split(key, self.cfg.local_updates)
        return jax.lax.scan(body, state, keys)

    def run(self, key, theta0: PyTree, num_rounds: int,
            collect_every: int = 1):
        probs = jnp.asarray(self.cfg.probs())
        state = (theta0, init_momentum(theta0))
        rnd = jax.jit(self._round)
        out = []
        for _ in range(num_rounds):
            key, k1, k2 = jax.random.split(key, 3)
            s = jax.random.categorical(k1, jnp.log(probs))
            state, trace = rnd(state, k2, s)
            out.append(jax.tree.map(lambda t: t[::collect_every], trace))
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *out)
