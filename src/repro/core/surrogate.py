"""Exponential-family surrogates q_s(theta) ~= p(x_s | theta)  (paper Sec 3.1).

Three precision structures (DESIGN.md Sec 4.2):

  'full'   — mean (P,), precision (P, P).     paper-scale models.
  'diag'   — mean (P,), precision (P,).       MLP / metric-learning scale.
  'scalar' — pytree means + ONE precision scalar per tensor. billion-scale.

All three are Gaussians, hence closed under products: the global surrogate
q = prod_s q_s has precision sum(Lambda_s) and natural parameter
sum(Lambda_s mu_s). Consequently

    grad log q(theta) = sum_s grad log q_s(theta)

and a conducive-gradient evaluation costs one fused elementwise pass — the
paper's "additional prior evaluation" claim holds at any scale.

A ``SurrogateBank`` stacks S shard surrogates along a leading axis so shard
selection stays jit-traceable (dynamic indexing, no python branching).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _tree_index(tree: PyTree, s) -> PyTree:
    return jax.tree.map(lambda a: a[s], tree)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Gaussian:
    """One Gaussian surrogate. ``mean``/``prec`` are either flat vectors
    ('full'/'diag') or pytrees ('scalar': per-leaf means + scalar precisions).
    """
    mean: PyTree
    prec: PyTree
    kind: str = dataclasses.field(metadata=dict(static=True), default="diag")

    def grad_log(self, theta: PyTree) -> PyTree:
        """grad log q(theta) = -Lambda (theta - mu); 'linear' kind:
        log q(theta) = b . theta with b stored in ``mean`` => grad = b
        (a Lipschitz exponential-family member — Lemma 1 applies; the
        conducive term becomes a bounded control-variate constant,
        SCAFFOLD-style; see DESIGN.md Sec 4.2 and EXPERIMENTS.md)."""
        if self.kind == "linear":
            return self.mean
        if self.kind == "full":
            return -(self.prec @ (theta - self.mean))
        if self.kind == "diag":
            return -self.prec * (theta - self.mean)
        if self.kind == "scalar":
            return jax.tree.map(
                lambda th, mu, lam: -lam * (th - mu.astype(th.dtype)),
                theta, self.mean, self.prec)
        raise ValueError(self.kind)

    def log_density(self, theta: PyTree) -> jax.Array:
        """Unnormalised log q(theta) (for diagnostics)."""
        if self.kind == "linear":
            terms = jax.tree.map(lambda b, t: jnp.sum(b * t), self.mean,
                                 theta)
            return jax.tree.reduce(jnp.add, terms)
        if self.kind == "full":
            d = theta - self.mean
            return -0.5 * d @ (self.prec @ d)
        if self.kind == "diag":
            d = theta - self.mean
            return -0.5 * jnp.sum(self.prec * d * d)
        if self.kind == "scalar":
            terms = jax.tree.map(
                lambda th, mu, lam:
                -0.5 * lam * jnp.sum((th - mu.astype(th.dtype)) ** 2),
                theta, self.mean, self.prec)
            return jax.tree.reduce(jnp.add, terms)
        raise ValueError(self.kind)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SurrogateBank:
    """S stacked shard surrogates + the precomputed global product.

    means/precs carry a leading shard axis. ``global_`` is the product
    Gaussian (computed once, communicated once — paper Sec 3.1).
    """
    means: PyTree
    precs: PyTree
    global_: Gaussian
    kind: str = dataclasses.field(metadata=dict(static=True), default="diag")

    @property
    def num_shards(self) -> int:
        leaf = jax.tree.leaves(self.means)[0]
        return leaf.shape[0]

    def shard(self, s) -> Gaussian:
        return Gaussian(_tree_index(self.means, s),
                        _tree_index(self.precs, s), self.kind)

    def astype(self, dtype) -> "SurrogateBank":
        """Bank with means STORED at ``dtype`` (e.g. bf16 at billion-param
        scale — the large-model runtime's surrogate memory format).
        Precisions stay fp32: they are tiny (scalar per tensor / one vector)
        and enter the update as multipliers, where bf16 rounding would bias
        the conducive term rather than just blur the anchor point. All
        gradient paths upcast means at use (``Gaussian.grad_log``), so a
        bf16 bank is a drop-in for every executor."""
        cast = lambda t: jax.tree.map(  # noqa: E731
            lambda l: l.astype(dtype), t)
        return SurrogateBank(
            cast(self.means), self.precs,
            Gaussian(cast(self.global_.mean), self.global_.prec, self.kind),
            self.kind)


def make_bank(means: PyTree, precs: PyTree, kind: str,
              store_dtype=None) -> SurrogateBank:
    """Build a bank from stacked per-shard means/precisions and precompute
    the product-Gaussian global surrogate. ``store_dtype`` stores the means
    (only) at a reduced dtype — see ``SurrogateBank.astype``. The global
    product is computed in the input dtype BEFORE the cast."""
    if kind == "linear":
        # product of linear members: b_g = sum_s b_s (grad of log prod)
        mean_g = jax.tree.map(lambda b: b.sum(0), means)
        prec_g = jax.tree.map(lambda b: jnp.zeros(b.shape[1:], b.dtype),
                              means)
    elif kind == "full":
        prec_g = precs.sum(0)                       # (P, P)
        nat = jnp.einsum("spq,sq->p", precs, means)
        mean_g = jnp.linalg.solve(prec_g, nat)
    elif kind == "diag":
        prec_g = precs.sum(0)
        mean_g = (precs * means).sum(0) / jnp.maximum(prec_g, 1e-12)
    elif kind == "scalar":
        prec_g = jax.tree.map(lambda lam: lam.sum(0), precs)
        mean_g = jax.tree.map(
            lambda mu, lam, lg: (
                (lam.reshape((-1,) + (1,) * (mu.ndim - 1)) * mu).sum(0)
                / jnp.maximum(lg, 1e-12)).astype(mu.dtype),
            means, precs, prec_g)
    else:
        raise ValueError(kind)
    bank = SurrogateBank(means, precs, Gaussian(mean_g, prec_g, kind), kind)
    return bank if store_dtype is None else bank.astype(store_dtype)


# ---------------------------------------------------------------------------
# fitting surrogates from local SGLD samples (paper Sec 3.1 / Sec 5)
# ---------------------------------------------------------------------------

def fit_gaussian(samples: jax.Array, kind: str, jitter: float = 1e-6,
                 likelihood_only: bool = True, prior_prec: float = 0.0
                 ) -> tuple[jax.Array, jax.Array]:
    """Fit one Gaussian to (n_samples, P) draws from p_s ∝ p(x_s|theta)
    (possibly times a prior).

    The paper fits q_s to the *local likelihood*; when the local sampler
    targeted prior*likelihood, subtracting the prior precision
    (``prior_prec``) de-biases the fit (natural-parameter subtraction). Used
    with ``likelihood_only=False`` + ``prior_prec>0`` when local chains ran
    against the full local posterior.
    """
    mu = samples.mean(0)
    if kind == "full":
        cov = jnp.cov(samples, rowvar=False)
        cov = jnp.atleast_2d(cov) + jitter * jnp.eye(samples.shape[1])
        prec = jnp.linalg.inv(cov)
        if not likelihood_only and prior_prec > 0:
            prec_l = prec - prior_prec * jnp.eye(samples.shape[1])
            nat = prec @ mu  # prior has zero mean: natural params subtract
            prec = prec_l
            mu = jnp.linalg.solve(prec_l + jitter * jnp.eye(samples.shape[1]),
                                  nat)
        return mu, prec
    if kind == "diag":
        var = samples.var(0) + jitter
        prec = 1.0 / var
        if not likelihood_only and prior_prec > 0:
            prec_l = jnp.maximum(prec - prior_prec, jitter)
            mu = (prec * mu) / prec_l
            prec = prec_l
        return mu, prec
    raise ValueError(kind)


def fit_scalar_tree(sample_tree: PyTree, jitter: float = 1e-6
                    ) -> tuple[PyTree, PyTree]:
    """Fit per-tensor isotropic Gaussians: ``sample_tree`` leaves are
    (n_samples, *tensor_shape). Returns (means pytree, scalar precisions)."""
    means = jax.tree.map(lambda s: s.mean(0), sample_tree)
    precs = jax.tree.map(
        lambda s: 1.0 / (s.var(0).mean() + jitter), sample_tree)
    return means, precs


def analytic_gaussian_likelihood_surrogate(xs: jax.Array, obs_var: float = 1.0
                                           ) -> tuple[jax.Array, jax.Array]:
    """Exact likelihood surrogate for the paper's Sec 5.1 model
    N(x | mu, I): p(x_s|mu) ∝ N(mu | xbar_s, I/N_s)  =>  mean xbar_s,
    precision (N_s/obs_var) I (diag)."""
    n = xs.shape[0]
    mu = xs.mean(0)
    prec = jnp.full_like(mu, n / obs_var)
    return mu, prec
