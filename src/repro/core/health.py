"""In-scan chain health: divergence detection + recovery policies.

The FA-LD line (Deng et al. 2021; Plassier et al. 2022) analyzes exactly
the failure axes a federated sampler meets in the wild — heterogeneity-
driven divergence, clients that return garbage, chains that walk off the
posterior — yet a single NaN in one chain's update silently poisons its
whole trace, and downstream ``ess``/``rhat`` with it. This module makes
chain health a first-class, *declarative* part of the run:

  * :class:`Recovery` — the policy spec the engine lowers INTO its
    scanned round body (``core/engine.py``): a finite-state check on
    theta (and momentum, for SGHMC) plus an optional log-posterior-
    explosion detector, evaluated per chain per round with no extra
    host dispatches — the jaxpr gate (one scan, one pallas_call, no
    pad) holds with health tracking enabled.

      - ``policy='quarantine'`` freezes a diverged chain at its last
        healthy state: its trace repeats the frozen position from the
        faulty round on, its updates keep being computed but are
        discarded (the straggler machinery's masking), and it never
        contaminates any other chain — all other chains' traces are
        bitwise identical to a fault-free run.
      - ``policy='respawn'`` re-seeds the diverged chain from a healthy
        chain's state (the first healthy real chain in the same mesh
        data block — deterministic given the seed) and lets it keep
        sampling; the health word counts how many times each chain was
        respawned.

  * :class:`RunHealth` — the per-chain health report surfaced in the
    run result: the raw health word plus the derived ``healthy`` mask
    ``core/diagnostics.py`` accepts to exclude quarantined chains from
    ess/rhat instead of erroring on their frozen (or non-finite)
    traces.

The detector itself is cheap: the finite check is an elementwise
``isfinite`` reduction over the chain's own state, and the log-posterior
probe (enabled by ``divergence_threshold``) is ONE extra likelihood
evaluation per chain per ROUND (not per step) on a minibatch drawn from
a key folded out of the round key — so enabling it never perturbs the
sampling RNG stream, and a fault-free run with health tracking on is
bitwise identical to one with it off.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

POLICIES = ("quarantine", "respawn")

# fold_in salt deriving the health-probe key from the round key: the probe
# stream is parallel to (never consumed from) the sampling stream.
HEALTH_PROBE_SALT = 0x48EA17


@dataclasses.dataclass(frozen=True)
class Recovery:
    """Declarative fault-recovery policy for the chain engine.

    policy:
      'quarantine' — a diverged chain is frozen at its last healthy
                     state for the rest of the run (masked out of its
                     trace's advancement; surfaced as unhealthy in
                     :class:`RunHealth` so diagnostics exclude it).
      'respawn'    — a diverged chain is re-seeded from the first
                     healthy real chain in its mesh data block and
                     keeps sampling (deterministic given the seed); if
                     the whole block diverged it freezes instead.

    divergence_threshold: when set, a chain also counts as diverged
      once its probed unnormalized log-posterior drops more than this
      many nats below its reference level (the log-posterior-explosion
      detector); None = finite-state checks only. The reference is a
      quantile over the chain's last ``window`` probes, NOT a running
      max: a max reference is inflated by the single luckiest probe of
      the whole run (minibatch log-posterior noise), which forces the
      threshold to be set far above the noise spread and lets a slowly
      diverging chain fall a long way before tripping. The windowed
      quantile tracks the chain's recent healthy plateau, so a tight
      threshold (a few times the probe IQR) trips a slow divergence as
      soon as it drops below the recent level.
    window: how many recent probes the reference quantile is taken
      over. The window starts empty (-inf padded); a chain only trips
      once enough probes accumulated for the quantile to be finite, so
      warm-up rounds never false-trip.
    quantile: the reference quantile in [0, 1] (nearest-rank over the
      window; 0.5 = median).
    check_momentum: include SGHMC momenta in the finite-state check
      (ignored for Langevin dynamics).

    Hashable — the engine caches one executor per (config, recovery).
    """
    policy: str = "quarantine"
    divergence_threshold: Optional[float] = None
    check_momentum: bool = True
    window: int = 8
    quantile: float = 0.5

    def __post_init__(self):
        assert self.policy in POLICIES, self.policy
        if self.divergence_threshold is not None:
            assert self.divergence_threshold > 0, self.divergence_threshold
        assert self.window >= 1, self.window
        assert 0.0 <= self.quantile <= 1.0, self.quantile

    @property
    def use_detector(self) -> bool:
        return self.divergence_threshold is not None


@dataclasses.dataclass
class RunHealth:
    """Per-chain health report of one engine run.

    ``word`` is an (n_chains,) int32 whose meaning depends on the
    policy: under 'quarantine', 0 = healthy and k > 0 = quarantined
    after round k-1 (the first faulty round, 1-based so 0 stays the
    healthy sentinel); under 'respawn' it counts how many times the
    chain was respawned (every chain is live at the end either way).
    ``lp_ref`` is the final windowed-quantile log-posterior reference
    per chain when the divergence detector ran (-inf while a chain's
    probe window is still warming up), else None.
    """
    word: np.ndarray
    policy: str = "quarantine"
    lp_ref: Optional[np.ndarray] = None

    @property
    def healthy(self) -> np.ndarray:
        """(n_chains,) bool — chains whose traces are trustworthy end to
        end: never quarantined (and, under respawn, never respawned —
        a respawned chain's early trace belongs to its donor's basin)."""
        return np.asarray(self.word) == 0

    @property
    def n_healthy(self) -> int:
        return int(self.healthy.sum())

    @property
    def n_chains(self) -> int:
        return int(np.asarray(self.word).shape[0])

    def __repr__(self):
        return (f"RunHealth(policy={self.policy!r}, "
                f"healthy={self.n_healthy}/{self.n_chains})")
