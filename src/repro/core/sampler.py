"""SGLD / DSGLD / FSGLD update rules (paper Eqs. 1-5, Algorithm 1).

Functional core shared by the paper-scale simulator (core/federated.py) and
the billion-parameter SPMD runtime (launch/train.py). A *step* is

    theta' = theta + (h/2) * drift(theta, minibatch, s) + sqrt(h*tau) * xi

with drift:
    SGLD   : grad log p(theta) + (N/m)          grad log p(x^(m)|theta)
    DSGLD  : grad log p(theta) + (N_s/(f_s m))  grad log p(x_s^(m)|theta)
    FSGLD  : DSGLD + alpha * g_s(theta)                       [conducive]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig
from repro.core.conducive import conducive_gradient
from repro.core.surrogate import Gaussian, SurrogateBank

PyTree = Any
LogLikFn = Callable[[PyTree, PyTree], jax.Array]  # (theta, batch) -> scalar


def tree_randn_like(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef,
        [jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)])


def langevin_update(theta: PyTree, drift: PyTree, h, key: jax.Array,
                    temperature: float = 1.0) -> PyTree:
    """theta + h/2 drift + N(0, h*tau I). Pure-jnp reference path; the fused
    Pallas kernel (repro.kernels.ops.fused_fsgld_update) implements the same
    contract in one HBM pass."""
    noise = tree_randn_like(key, theta)
    sig = jnp.sqrt(h * temperature)
    return jax.tree.map(
        lambda t, d, n: (t + (h / 2) * d.astype(t.dtype)
                         + (sig * n).astype(t.dtype)),
        theta, drift, noise)


def prior_grad(theta: PyTree, prior_precision: float) -> PyTree:
    """grad log N(theta | 0, lambda^-1 I) = -lambda * theta."""
    return jax.tree.map(lambda t: -prior_precision * t, theta)


@dataclasses.dataclass(frozen=True)
class ShardScheme:
    """Static shard metadata: sizes N_s and selection probs f_s.

    Shard sizes may be NON-uniform: stacked shard data is then padded along
    the per-shard sample axis to ``max_size`` and the pad rows are dead —
    ``valid_mask``/``sizes_array`` let samplers draw minibatch indices only
    from the live prefix of each shard (see core/engine.py).
    """
    sizes: tuple
    probs: tuple

    @property
    def num_shards(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        return int(sum(self.sizes))

    @property
    def max_size(self) -> int:
        return int(max(self.sizes))

    @property
    def uniform(self) -> bool:
        return len(set(self.sizes)) == 1

    def as_arrays(self):
        return (jnp.asarray(self.sizes, jnp.float32),
                jnp.asarray(self.probs, jnp.float32))

    def sizes_array(self) -> jnp.ndarray:
        """(S,) int32 true shard sizes (pre-padding)."""
        return jnp.asarray(self.sizes, jnp.int32)

    def starts_array(self) -> jnp.ndarray:
        """(S,) int32 exclusive-prefix-sum of sizes: global offset of each
        shard in the virtual ragged concatenation (pooled SGLD sampling)."""
        sizes = jnp.asarray(self.sizes, jnp.int32)
        return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(sizes)[:-1]])

    def valid_mask(self) -> jnp.ndarray:
        """(S, max_size) bool — True on live rows, False on padding."""
        cols = jnp.arange(self.max_size)[None, :]
        return cols < self.sizes_array()[:, None]


def chain_scales(cfg: SamplerConfig, scheme: ShardScheme, sids: jax.Array,
                 minibatch: int) -> tuple[jax.Array, jax.Array]:
    """Per-chain estimator factors for a chain block resident at clients
    ``sids``: returns (scale, f_s), each (C,) fp32. DSGLD/FSGLD unbias by
    N_s/(f_s m) (paper Eq. 4); centralized SGLD scales by N/m and has no
    shard factor. Shared by the chain-batched and packed round bodies."""
    C = sids.shape[0]
    if cfg.method == "sgld":
        return (jnp.full((C,), scheme.total / minibatch, jnp.float32),
                jnp.ones((C,), jnp.float32))
    sizes_f, probs_f = scheme.as_arrays()
    f_s = probs_f[sids]
    return sizes_f[sids] / (f_s * minibatch), f_s


def make_drift_fn(
    log_lik_fn: LogLikFn,
    cfg: SamplerConfig,
    scheme: ShardScheme,
    bank: Optional[SurrogateBank] = None,
) -> Callable:
    """Returns drift(theta, batch, shard_id, m) -> pytree.

    ``shard_id`` may be a traced int32 scalar (dynamic shard selection);
    ``m`` is the static minibatch size.
    """
    sizes, probs = scheme.as_arrays()
    if cfg.method == "fsgld" and bank is None:
        raise ValueError("FSGLD needs a SurrogateBank")

    def drift(theta, batch, shard_id, m, bank_rt: Optional[SurrogateBank]
              = None):
        """bank_rt: runtime surrogate override — lets the adaptive-refresh
        scheduler swap surrogates without retracing (banks are pytrees)."""
        b = bank_rt if bank_rt is not None else bank
        gll = jax.grad(log_lik_fn)(theta, batch)
        if cfg.method == "sgld":
            scale = scheme.total / m
            f_s = 1.0
        else:
            f_s = probs[shard_id]
            scale = sizes[shard_id] / (f_s * m)
        d = jax.tree.map(
            lambda p, g: p + scale * g.astype(p.dtype),
            prior_grad(theta, cfg.prior_precision), gll)
        if cfg.method == "fsgld":
            g_s = conducive_gradient(theta, b.global_,
                                     b.shard(shard_id), f_s, cfg.alpha)
            d = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), d, g_s)
        return d

    return drift


def kernel_step_operands(cfg: SamplerConfig, scheme: ShardScheme,
                         bank: Optional[SurrogateBank]) -> Callable:
    """Shared per-step operand resolution for the fused-kernel step paths
    (the Langevin step below and the SGHMC step in core/sghmc.py — same
    estimator stack, different integrator): returns
    resolve(shard_id, m, bank_rt) -> (scale, f_s, q_global, q_shard) with
    the DSGLD/FSGLD unbiasing factors (paper Eq. 4) and the resident
    surrogate pair (None for SGLD/DSGLD)."""
    sizes, probs = scheme.as_arrays()

    def resolve(shard_id, m, bank_rt=None):
        b = bank_rt if bank_rt is not None else bank
        if cfg.method == "sgld":
            scale = jnp.float32(scheme.total / m)
            f_s = jnp.float32(1.0)
        else:
            f_s = probs[shard_id]
            scale = sizes[shard_id] / (f_s * m)
        if cfg.method == "fsgld":
            q_g, q_s = b.global_, b.shard(shard_id)
        else:
            q_g = q_s = None
        return scale, f_s, q_g, q_s

    return resolve


def make_step_fn(
    log_lik_fn: LogLikFn,
    cfg: SamplerConfig,
    scheme: ShardScheme,
    bank: Optional[SurrogateBank] = None,
    use_kernel: bool = False,
) -> Callable:
    """Returns step(theta, key, batch, shard_id, m, step_size=None) -> theta'.

    ``use_kernel=True`` routes the parameter update through the fused Pallas
    kernel (kernels/ops.py) — same semantics, one HBM pass.
    """
    drift_fn = make_drift_fn(log_lik_fn, cfg, scheme, bank)

    if not use_kernel:
        def step(theta, key, batch, shard_id, m, step_size=None,
                 bank_rt=None):
            h = cfg.step_size if step_size is None else step_size
            d = drift_fn(theta, batch, shard_id, m, bank_rt)
            return langevin_update(theta, d, h, key, cfg.temperature)
        return step

    from repro.kernels import ops as kops
    resolve = kernel_step_operands(cfg, scheme, bank)

    def step(theta, key, batch, shard_id, m, step_size=None, bank_rt=None):
        h = cfg.step_size if step_size is None else step_size
        gll = jax.grad(log_lik_fn)(theta, batch)
        scale, f_s, q_g, q_s = resolve(shard_id, m, bank_rt)
        return kops.fused_update_tree(
            theta, gll, key, h=h, scale=scale, f_s=f_s,
            prior_prec=cfg.prior_precision, alpha=cfg.alpha,
            temperature=cfg.temperature, q_global=q_g, q_shard=q_s,
            surrogate_kind=(bank.kind if bank is not None else None))

    return step
