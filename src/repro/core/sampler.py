"""SGLD / DSGLD / FSGLD update rules (paper Eqs. 1-5, Algorithm 1).

Functional core shared by the paper-scale simulator (core/federated.py) and
the billion-parameter SPMD runtime (launch/train.py). A *step* is

    theta' = theta + (h/2) * drift(theta, minibatch, s) + sqrt(h*tau) * xi

with drift:
    SGLD   : grad log p(theta) + (N/m)          grad log p(x^(m)|theta)
    DSGLD  : grad log p(theta) + (N_s/(f_s m))  grad log p(x_s^(m)|theta)
    FSGLD  : DSGLD + alpha * g_s(theta)                       [conducive]
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SamplerConfig
from repro.core.conducive import conducive_gradient
from repro.core.surrogate import Gaussian, SurrogateBank

PyTree = Any
LogLikFn = Callable[[PyTree, PyTree], jax.Array]  # (theta, batch) -> scalar


def tree_randn_like(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef,
        [jax.random.normal(k, l.shape, l.dtype) for k, l in zip(keys, leaves)])


def langevin_update(theta: PyTree, drift: PyTree, h, key: jax.Array,
                    temperature: float = 1.0) -> PyTree:
    """theta + h/2 drift + N(0, h*tau I). Pure-jnp reference path; the fused
    Pallas kernel (repro.kernels.ops.fused_fsgld_update) implements the same
    contract in one HBM pass."""
    noise = tree_randn_like(key, theta)
    sig = jnp.sqrt(h * temperature)
    return jax.tree.map(
        lambda t, d, n: (t + (h / 2) * d.astype(t.dtype)
                         + (sig * n).astype(t.dtype)),
        theta, drift, noise)


def prior_grad(theta: PyTree, prior_precision: float) -> PyTree:
    """grad log N(theta | 0, lambda^-1 I) = -lambda * theta."""
    return jax.tree.map(lambda t: -prior_precision * t, theta)


@dataclasses.dataclass(frozen=True)
class ShardScheme:
    """Static shard metadata: sizes N_s and selection probs f_s.

    Shard sizes may be NON-uniform: stacked shard data is then padded along
    the per-shard sample axis to ``max_size`` and the pad rows are dead —
    ``valid_mask``/``sizes_array`` let samplers draw minibatch indices only
    from the live prefix of each shard (see core/engine.py).

    At streamed-client scale (~10^6 clients) ``sizes`` may be a numpy
    array instead of a tuple, and ``probs`` may be None for the uniform
    f_s = 1/S case — a million-element python tuple costs tens of MB and
    seconds to build; ``None`` lowers to the SAME fp32 values the tuple
    path produces (``1.0/S`` cast once), so small-S runs are bitwise
    unaffected by which spelling constructed the scheme.
    """
    sizes: Any            # tuple | np.ndarray of int
    probs: Any            # tuple | np.ndarray | None (None => uniform 1/S)

    @property
    def num_shards(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        return int(np.asarray(self.sizes, np.int64).sum())

    @property
    def max_size(self) -> int:
        return int(np.asarray(self.sizes).max())

    @property
    def uniform(self) -> bool:
        a = np.asarray(self.sizes)
        return int(a.min()) == int(a.max())

    def probs_array(self) -> np.ndarray:
        """(S,) float32 selection probs on the HOST (numpy) — the
        streamed planner and resident-subset gathers read this without
        touching the device."""
        if self.probs is None:
            return np.full((self.num_shards,), 1.0 / self.num_shards,
                           np.float32)
        return np.asarray(self.probs, np.float32)

    def as_arrays(self):
        return (jnp.asarray(np.asarray(self.sizes, np.float32)),
                jnp.asarray(self.probs_array()))

    def sizes_array(self) -> jnp.ndarray:
        """(S,) int32 true shard sizes (pre-padding)."""
        return jnp.asarray(np.asarray(self.sizes, np.int32))

    def starts_array(self) -> jnp.ndarray:
        """(S,) int32 exclusive-prefix-sum of sizes: global offset of each
        shard in the virtual ragged concatenation (pooled SGLD sampling)."""
        sizes = self.sizes_array()
        return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(sizes)[:-1]])

    def valid_mask(self) -> jnp.ndarray:
        """(S, max_size) bool — True on live rows, False on padding."""
        cols = jnp.arange(self.max_size)[None, :]
        return cols < self.sizes_array()[:, None]


def chain_scales(cfg: SamplerConfig, scheme: ShardScheme, sids: jax.Array,
                 minibatch: int, sp_rt=None) -> tuple[jax.Array, jax.Array]:
    """Per-chain estimator factors for a chain block resident at clients
    ``sids``: returns (scale, f_s), each (C,) fp32. DSGLD/FSGLD unbias by
    N_s/(f_s m) (paper Eq. 4); centralized SGLD scales by N/m and has no
    shard factor. Shared by the chain-batched and packed round bodies.

    ``sp_rt`` is the streamed-client runtime override — a
    ``(sizes_i32, sizes_f32, probs_f32)`` triple of (K,) arrays holding
    the RESIDENT subset's metadata, indexed by resident-local sids. The
    rows are host-gathers of the full (S,) arrays, so a streamed lookup
    returns the exact fp32 value the resident path reads (see
    core/engine.py's streamed executor)."""
    C = sids.shape[0]
    if cfg.method == "sgld":
        return (jnp.full((C,), scheme.total / minibatch, jnp.float32),
                jnp.ones((C,), jnp.float32))
    if sp_rt is not None:
        sizes_f, probs_f = sp_rt[1], sp_rt[2]
    else:
        sizes_f, probs_f = scheme.as_arrays()
    f_s = probs_f[sids]
    return sizes_f[sids] / (f_s * minibatch), f_s


def make_drift_fn(
    log_lik_fn: LogLikFn,
    cfg: SamplerConfig,
    scheme: ShardScheme,
    bank: Optional[SurrogateBank] = None,
) -> Callable:
    """Returns drift(theta, batch, shard_id, m) -> pytree.

    ``shard_id`` may be a traced int32 scalar (dynamic shard selection);
    ``m`` is the static minibatch size.
    """
    sizes, probs = scheme.as_arrays()
    if cfg.method == "fsgld" and bank is None:
        raise ValueError("FSGLD needs a SurrogateBank")

    def drift(theta, batch, shard_id, m, bank_rt: Optional[SurrogateBank]
              = None, sp_rt=None):
        """bank_rt: runtime surrogate override — lets the adaptive-refresh
        scheduler swap surrogates without retracing (banks are pytrees).
        sp_rt: resident-subset (sizes_i32, sizes_f, probs_f) override for
        the streamed-client path; shard_id is then resident-LOCAL."""
        b = bank_rt if bank_rt is not None else bank
        sz, pr = (sizes, probs) if sp_rt is None else (sp_rt[1], sp_rt[2])
        gll = jax.grad(log_lik_fn)(theta, batch)
        if cfg.method == "sgld":
            scale = scheme.total / m
            f_s = 1.0
        else:
            f_s = pr[shard_id]
            scale = sz[shard_id] / (f_s * m)
        d = jax.tree.map(
            lambda p, g: p + scale * g.astype(p.dtype),
            prior_grad(theta, cfg.prior_precision), gll)
        if cfg.method == "fsgld":
            g_s = conducive_gradient(theta, b.global_,
                                     b.shard(shard_id), f_s, cfg.alpha)
            d = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), d, g_s)
        return d

    return drift


def kernel_step_operands(cfg: SamplerConfig, scheme: ShardScheme,
                         bank: Optional[SurrogateBank]) -> Callable:
    """Shared per-step operand resolution for the fused-kernel step paths
    (the Langevin step below and the SGHMC step in core/sghmc.py — same
    estimator stack, different integrator): returns
    resolve(shard_id, m, bank_rt) -> (scale, f_s, q_global, q_shard) with
    the DSGLD/FSGLD unbiasing factors (paper Eq. 4) and the resident
    surrogate pair (None for SGLD/DSGLD)."""
    sizes, probs = scheme.as_arrays()

    def resolve(shard_id, m, bank_rt=None, sp_rt=None):
        b = bank_rt if bank_rt is not None else bank
        sz, pr = (sizes, probs) if sp_rt is None else (sp_rt[1], sp_rt[2])
        if cfg.method == "sgld":
            scale = jnp.float32(scheme.total / m)
            f_s = jnp.float32(1.0)
        else:
            f_s = pr[shard_id]
            scale = sz[shard_id] / (f_s * m)
        if cfg.method == "fsgld":
            q_g, q_s = b.global_, b.shard(shard_id)
        else:
            q_g = q_s = None
        return scale, f_s, q_g, q_s

    return resolve


def make_step_fn(
    log_lik_fn: LogLikFn,
    cfg: SamplerConfig,
    scheme: ShardScheme,
    bank: Optional[SurrogateBank] = None,
    use_kernel: bool = False,
) -> Callable:
    """Returns step(theta, key, batch, shard_id, m, step_size=None) -> theta'.

    ``use_kernel=True`` routes the parameter update through the fused Pallas
    kernel (kernels/ops.py) — same semantics, one HBM pass.
    """
    drift_fn = make_drift_fn(log_lik_fn, cfg, scheme, bank)

    if not use_kernel:
        def step(theta, key, batch, shard_id, m, step_size=None,
                 bank_rt=None, sp_rt=None):
            h = cfg.step_size if step_size is None else step_size
            d = drift_fn(theta, batch, shard_id, m, bank_rt, sp_rt)
            return langevin_update(theta, d, h, key, cfg.temperature)
        return step

    from repro.kernels import ops as kops
    resolve = kernel_step_operands(cfg, scheme, bank)

    def step(theta, key, batch, shard_id, m, step_size=None, bank_rt=None,
             sp_rt=None):
        h = cfg.step_size if step_size is None else step_size
        gll = jax.grad(log_lik_fn)(theta, batch)
        scale, f_s, q_g, q_s = resolve(shard_id, m, bank_rt, sp_rt)
        return kops.fused_update_tree(
            theta, gll, key, h=h, scale=scale, f_s=f_s,
            prior_prec=cfg.prior_precision, alpha=cfg.alpha,
            temperature=cfg.temperature, q_global=q_g, q_shard=q_s,
            surrogate_kind=(bank.kind if bank is not None else None))

    return step
