"""Mesh-parallel FSGLD chain runtime (the production multi-chain engine).

The paper's parallel regime (Ahn et al.-style parallel chains; the FA-LD
follow-ups in PAPERS.md) needs MANY posterior chains resident on MANY
clients at once. The simulator in ``core/federated.py`` ran chains with a
single-host ``vmap``; this module replaces that execution path with a
``shard_map`` executor over the (``data``, ``model``) mesh from
``launch/mesh.py``:

  * ``data``  — the CHAIN axis. Chains are sharded over it; each data group
    runs its chain block locally (vmapped inside the block, so the 1x1 host
    mesh is bit-identical to the legacy vmap path).
  * ``model`` — SHARD-parallel surrogate work. The bank refresh / Fisher
    fitting pass splits the client-shard axis S over ``model`` and
    all-gathers the fitted naturals (``refresh_bank_mesh``).

Chain->client reassignment:

  * ``categorical`` — the paper's Algorithm 1: i.i.d. s ~ Categorical(f)
    per chain (chains may collide on a client).
  * ``permutation`` — the collision-free SPMD variant (DESIGN.md Sec 4.1):
    every device derives the SAME random permutation from the replicated
    round key inside the shard_map block and slices its own chain block by
    ``axis_index('data')`` — device-side, no host round-trip, and
    bit-identical to the legacy host-side ``permutation(key, S)[:C]``.

Non-uniform clients: shard data leaves are (S, max_n, ...) padded along the
sample axis; ``ShardScheme.sizes`` carries the true N_s and minibatch
indices are drawn in [0, N_s) only, so pad rows are never touched (tests
fill them with NaN to prove it).

The fused Pallas kernel path (``use_kernel=True``) routes the whole chain
block through the PACKED single-launch executor (PR 2): the entire
parameter pytree of the block lives in one chain-major
``(C * rows_total, 128)`` buffer (``kernels.ops.PackedChains``), packed
ONCE per run, and every step issues exactly ONE ``pallas_call`` covering
all leaves of all chains via a static segment table. ``packed=False``
falls back to the PR 1 per-leaf chain-batched entry
(``kernels.ops.fused_update_chains_tree`` — one ``pallas_call`` per leaf
per step).

``run`` itself is a single jitted ``lax.scan`` over communication rounds
(per mode/shape, cached): reassignment (categorical + SPMD permutation;
block-cyclic client visiting when n_chains > S), round key-splitting, and
thinned trace collection all happen inside the scan, chain state is
donated instead of copied, and the trace comes back preallocated as
``(C, R * T/collect_every, ...)`` — no host dispatch and no trailing
concatenate in the hot loop.

Federation scenarios (``repro.fed``, PR 5): ``run(...,
federation=spec)`` lowers the scenario's communication schedule (delayed
rounds, partial participation, stragglers) and round-boundary payload
compression (top-k / rand-k / qsgd with error feedback) INTO the scanned
round body — the carry gains the resident client assignment and the
compression's (server-view, error-feedback) state, still one scan and
one dispatch. The engine-identity spec lowers to None and shares the
oracle executor bit-for-bit.

Fault tolerance (PR 7): ``run(..., recovery=Recovery(...))`` lowers an
in-scan per-chain HEALTH word into the same round bodies — a finite-state
check on theta (and SGHMC momentum) plus an optional log-posterior
divergence detector probed with a ``fold_in``-derived key, so enabling
health never perturbs the sampling stream. Diverged chains are
quarantined (frozen, masked out of federation exchange and traces) or
respawned from the block's first healthy chain — both per-chain
``where`` masks, so the surviving chains' trajectories stay bitwise
identical to a fault-free run. ``chaos=`` accepts a static fault plan
(``repro.testing.ChaosSpec``, duck-typed — the engine never imports the
test harness) that NaN-poisons chosen chains' post-round state or their
compressed payloads at chosen absolute rounds. ``snapshot_every=``
atomically checkpoints the FULL scan carry (chain state, RNG key,
federation carry, health words, trace-so-far) between segments through
``repro.checkpoint.snapshot``; ``resume=True`` continues from the newest
valid snapshot with traces bitwise identical to an uninterrupted run —
the executor takes the absolute starting round and the federation carry
as inputs, so segmentation never resets in-scan state.

Rival samplers (PR 8): ``aggregation='fald'`` lowers FA-LD (federated
averaging Langevin dynamics, Deng et al. 2021) into the SAME scanned
round body — at every communication round the participating chains'
states are averaged in flat fp32 space (a masked ``psum`` over the
``data`` axis, so multi-device blocks agree), and each client's injected
noise is amplified by ``sqrt(n_chains)`` (temperature × C) so the
AVERAGED iterate targets the correct posterior temperature. ELF-style
bidirectional compression (``Compression(direction='dual'|'bidir')``)
compresses the server→client broadcast as a delta against the shared
reference with its OWN error-feedback residual riding the carry next to
the primal one — primal-only runs keep today's carry and ops bitwise.
Both lower into the one-scan/one-pallas_call/no-pad round body; the
pure-JAX FA-LD oracle lives in ``repro.rivals.fald``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SamplerConfig
from repro.core.health import HEALTH_PROBE_SALT, RunHealth
from repro.core.sampler import (LogLikFn, ShardScheme, chain_scales,
                                make_step_fn)
from repro.core.surrogate import SurrogateBank, make_bank
from repro.kernels import ops as kops
from repro.obs import trace as obs_trace
from repro.obs.telemetry import TELEMETRY_PROBE_SALT, MetricsFrame
from repro.sharding.rules import (chain_spec, fed_carry_spec,
                                  stream_window_spec)

PyTree = Any


# ---------------------------------------------------------------------------
# padding non-uniform clients
# ---------------------------------------------------------------------------

def pad_shards(per_shard: list, fill: float = jnp.nan):
    """Stack a list of per-client pytrees (each with leading axis N_s) into
    padded (S, max_n, ...) leaves + the true sizes tuple.

    Float leaves pad with NaN by default: any estimator that touches a
    pad row poisons the chain immediately instead of silently biasing it.
    Integer leaves (token ids) cannot carry NaN — jnp.pad would silently
    coerce it to 0, a VALID id — so they get the dtype's minimum as an
    extreme out-of-range sentinel instead.
    """
    sizes = tuple(int(jax.tree.leaves(t)[0].shape[0]) for t in per_shard)
    max_n = max(sizes)

    def pad_one(leaf):
        pad = [(0, max_n - leaf.shape[0])] + [(0, 0)] * (leaf.ndim - 1)
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            value = fill
        else:
            value = jnp.iinfo(leaf.dtype).min
        return jnp.pad(leaf, pad, constant_values=value)

    stacked = jax.tree.map(
        lambda *leaves: jnp.stack([pad_one(l) for l in leaves]), *per_shard)
    return stacked, sizes


# ---------------------------------------------------------------------------
# per-chain round bodies
# ---------------------------------------------------------------------------

def _make_batch_sampler(cfg: SamplerConfig, scheme: ShardScheme,
                        minibatch: int):
    """Returns sample(k_batch, shard_id, shard_data) -> minibatch pytree.

    DSGLD/FSGLD draw m indices with replacement from the LIVE prefix
    [0, N_s) of the resident shard. Centralized SGLD draws from the virtual
    ragged concatenation of all shards: a global index u in [0, N) maps to
    (shard, offset) via the size prefix sums — for uniform shards this
    selects exactly the elements of the legacy pooled-reshape path.

    ``sizes_rt`` overrides the closed-over (S,) size table with the
    streamed path's RESIDENT (K,) int32 rows (``shard_id`` is then
    resident-local); the rows are host-gathers of the same table, so the
    randint bound — and hence the draw — is bitwise unchanged.
    """
    sizes = scheme.sizes_array()
    total = scheme.total
    m = minibatch
    if cfg.method == "sgld":
        starts = scheme.starts_array()
        ends = jnp.cumsum(sizes)

    def sample(k_batch, shard_id, shard_data, sizes_rt=None):
        if cfg.method == "sgld":
            u = jax.random.randint(k_batch, (m,), 0, total)
            sh = jnp.searchsorted(ends, u, side="right").astype(jnp.int32)
            off = u - starts[sh]
            return jax.tree.map(lambda d: d[sh, off], shard_data)
        sz = sizes if sizes_rt is None else sizes_rt
        idx = jax.random.randint(k_batch, (m,), 0, sz[shard_id])
        return jax.tree.map(lambda d: d[shard_id][idx], shard_data)

    return sample


def make_round_fn(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                  scheme: ShardScheme, step_fn, minibatch: int,
                  collect: bool = True, collect_state=None):
    """Client-side Update(T, theta_0, s) for ONE chain — the same math as
    the legacy ``FederatedSampler._round`` generalised to ragged shards.
    Returns round(state, key, shard_id, shard_data, bank_rt).

    ``state`` is whatever pytree ``step_fn`` carries: the parameter pytree
    for Langevin dynamics, the (theta, momentum) pair for SGHMC.
    ``collect_state`` projects the carried state to the traced sample
    (identity by default; SGHMC traces theta only)."""
    sample = _make_batch_sampler(cfg, scheme, minibatch)
    if collect_state is None:
        collect_state = lambda s: s  # noqa: E731

    def round_fn(state, key, shard_id, shard_data, bank_rt=None,
                 sp_rt=None):
        sizes_rt = None if sp_rt is None else sp_rt[0]

        def body(carry, k):
            state = carry
            k_batch, k_step = jax.random.split(k)
            batch = sample(k_batch, shard_id, shard_data, sizes_rt)
            state = step_fn(state, k_step, batch, shard_id, minibatch,
                            bank_rt=bank_rt, sp_rt=sp_rt)
            return state, collect_state(state) if collect else None

        keys = jax.random.split(key, cfg.local_updates)
        state, trace = jax.lax.scan(body, state, keys)
        return state, trace

    return round_fn


def make_masked_grad_vmap(grad_fn, *, per: int, n_chains: int, d_size: int):
    """Per-block gradient pass that SKIPS pad-chain work.

    Odd chain counts pad the block to ``n_total = d_size * per`` resident
    chains; the pad chains live at the global tail, so each data group i
    holds ``real_i = clip(n_chains - i*per, 0, per)`` real chains. With no
    padding this is a plain ``vmap(grad_fn)``. Otherwise the round body
    switches on ``axis_index('data')`` into a branch that vmaps the
    gradient over ONLY the group's real chains and concatenates zeros for
    the pad slots — the branches are per-device programs inside shard_map,
    so only the taken one executes and the pad chains' gradient FLOPs are
    genuinely skipped (asserted on the branch jaxprs in
    tests/test_packed_executor.py), not computed-and-discarded. The pad
    chains' elementwise kernel-update rows remain (they are ~pad/C of the
    cheap update cost; the gradient pass is the expensive part).
    """
    n_pad = d_size * per - n_chains
    if n_pad == 0:
        return lambda thetas, batches: jax.vmap(grad_fn)(thetas, batches)

    def branch(real):
        def go(args):
            thetas, batches = args
            if real == 0:
                return jax.tree.map(jnp.zeros_like, thetas)
            head = jax.vmap(grad_fn)(
                jax.tree.map(lambda t: jax.lax.slice_in_dim(t, 0, real),
                             thetas),
                jax.tree.map(lambda t: jax.lax.slice_in_dim(t, 0, real),
                             batches))
            if real == per:
                return head
            # concatenate, not `pad`: scan bodies carry a no-pad-jaxpr
            # guarantee (see _executor.pad_tail)
            return jax.tree.map(
                lambda g: jnp.concatenate(
                    [g, jnp.zeros((per - real,) + g.shape[1:], g.dtype)]),
                head)

        return go

    branches = [branch(min(max(n_chains - i * per, 0), per))
                for i in range(d_size)]

    def masked(thetas, batches):
        return jax.lax.switch(jax.lax.axis_index("data"), branches,
                              (thetas, batches))

    return masked


def make_chain_round_fn(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                        scheme: ShardScheme, minibatch: int,
                        bank_kind: Optional[str], collect: bool = True,
                        dynamics: str = "langevin", sghmc=None,
                        grad_vmap=None):
    """CHAIN-BATCHED round for the fused-kernel path: gradients are vmapped
    over the local chain block, then the whole block goes through ONE
    chain-batched Pallas update per leaf per step.

    Returns round(state, keys, sids, shard_data, bank) operating on
    (C_blk, ...)-stacked chain states — the parameter pytree for Langevin
    dynamics, the (thetas, momenta) pair for SGHMC (``sghmc``: the
    SGHMCConfig supplying friction/temperature). ``grad_vmap`` overrides
    the block gradient pass (pad-chain masking, ``make_masked_grad_vmap``).
    """
    sample = _make_batch_sampler(cfg, scheme, minibatch)
    if grad_vmap is None:
        grad_fn = jax.grad(log_lik_fn)
        grad_vmap = lambda th, b: jax.vmap(grad_fn)(th, b)  # noqa: E731
    # only FSGLD carries the conducive correction — mirror the gating in
    # make_step_fn's kernel path, else a resident bank would silently add
    # the surrogate term to DSGLD/SGLD updates.
    use_surrogate = cfg.method == "fsgld"
    if not use_surrogate:
        bank_kind = None
    hmc = dynamics == "sghmc"
    dyn_kw = (dict(dynamics="sghmc", friction=sghmc.friction,
                   temperature=sghmc.temperature) if hmc
              else dict(temperature=cfg.temperature))

    def round_fn(state, keys, sids, shard_data, bank=None, sp_rt=None):
        if not use_surrogate:
            bank = None
        scale, f_s = chain_scales(cfg, scheme, sids, minibatch, sp_rt)
        sizes_rt = None if sp_rt is None else sp_rt[0]

        def body(carry, ks):
            thetas, r = carry if hmc else (carry, None)
            kk = jax.vmap(jax.random.split)(ks)       # (C, 2, 2)
            k_batch, k_step = kk[:, 0], kk[:, 1]
            batches = jax.vmap(
                lambda k, s: sample(k, s, shard_data, sizes_rt))(
                k_batch, sids)
            glls = grad_vmap(thetas, batches)
            out = kops.fused_update_chains_tree(
                thetas, glls, k_step, h=cfg.step_size, scale=scale,
                f_s=f_s, prior_prec=cfg.prior_precision, alpha=cfg.alpha,
                bank=bank, sids=sids, surrogate_kind=bank_kind,
                momentum=r, **dyn_kw)
            thetas = out[0] if hmc else out
            carry = out if hmc else thetas
            return carry, thetas if collect else None

        keys_t = jax.vmap(lambda k: jax.random.split(
            k, cfg.local_updates))(keys)              # (C, T, 2)
        state, trace = jax.lax.scan(body, state,
                                    jnp.swapaxes(keys_t, 0, 1))
        if collect and trace is not None:
            # (T, C, ...) -> (C, T, ...) to match the vmap-of-scan layout
            trace = jax.tree.map(lambda t: jnp.swapaxes(t, 0, 1), trace)
        return state, trace

    return round_fn


def _perm_sids_slice(k_assign: jax.Array, num_shards: int, start,
                     per: int, n_total: Optional[int] = None) -> jax.Array:
    """Collision-free reassignment, SPMD: every device derives the SAME
    permutation of [0, S) from the replicated round key and slices its own
    chain block. Equals the host-side ``permutation(k, S)[:C]`` bitwise.
    Shared by the scanned round body and ``_permute_sids``.

    ``n_total > num_shards`` switches to BLOCK-CYCLIC client visiting:
    the round's permutation is tiled so chain c sits at client
    ``perm[c % S]`` — every client hosts floor/ceil(C/S) chains and the
    only collisions are the cyclic wrap (host-side equivalent:
    ``tile(permutation(k, S), ceil(C/S))[:C]``)."""
    perm = jax.random.permutation(k_assign, num_shards)
    if n_total is not None and n_total > num_shards:
        perm = jnp.concatenate([perm] * (-(-n_total // num_shards)))
    return jax.lax.dynamic_slice_in_dim(perm, start, per)


def pack_bank(layout: kops.PackedChains, bank: Optional[SurrogateBank]):
    """SurrogateBank -> packed operands for the single-launch round body.

    Shared (global) surrogate operands are packed ONCE here — per-round
    work is only the ``[sids]`` row gather in the round body. Per-shard
    stacks keep a leading S axis: (S, rows_total, 128).
    """
    if bank is None:
        return None
    if bank.kind == "diag":
        return {
            "mu_g": layout.pack_shared(bank.global_.mean),
            "lam_g": layout.pack_shared(bank.global_.prec),
            "means": layout.pack(bank.means).reshape(
                -1, layout.rows_total, kops.LANE),
            "precs": layout.pack(bank.precs).reshape(
                -1, layout.rows_total, kops.LANE),
        }
    if bank.kind == "scalar":
        return {
            "mu_g": layout.pack_shared(bank.global_.mean),
            "means": layout.pack(bank.means).reshape(
                -1, layout.rows_total, kops.LANE),
            # per-leaf scalar precisions ride in the (C, L, 8) scalar rows
            "lam_g_leaf": jnp.stack([
                jnp.asarray(p, jnp.float32)
                for p in jax.tree.leaves(bank.global_.prec)]),
            "lam_s_leaf": jnp.stack([
                jnp.asarray(p, jnp.float32)
                for p in jax.tree.leaves(bank.precs)], axis=1),
        }
    raise ValueError(bank.kind)


def make_packed_round_fn(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                         scheme: ShardScheme, minibatch: int,
                         bank_kind: Optional[str],
                         layout: kops.PackedChains, collect: bool = True,
                         dynamics: str = "langevin", sghmc=None,
                         grad_vmap=None):
    """SINGLE-LAUNCH round for the packed executor: the chain block's whole
    parameter pytree lives in one chain-major packed buffer and every step
    issues exactly one ``pallas_call`` (kernels.ops.packed_step).

    State is ``(packed, thetas)`` — or ``(packed, momenta_packed, thetas)``
    for ``dynamics='sghmc'``, the momenta riding a SECOND chain-major
    buffer over the same segment table: the packed buffers are
    authoritative; the unpacked pytree mirror feeds the gradient pass and
    trace collection, so the scan body contains NO pad/ravel work — leaf
    gradients are written into the packed gradient buffer by static
    update-slices, and the only per-round (not per-step) work is gathering
    the resident-client surrogate rows and prebuilding the scalar rows.
    Non-fp32 leaves quantize back to their storage dtype after every step
    (``layout.quantize``, identity for all-fp32 trees), replaying the
    per-leaf kernel's dtype round-trip. RNG streams (batch draws,
    per-(chain, leaf) noise seeds) are derived exactly as the per-leaf
    chain-batched round derives them, so results are bit-identical to it —
    and therefore to the ``run_vmap`` oracle.
    """
    sample = _make_batch_sampler(cfg, scheme, minibatch)
    if grad_vmap is None:
        grad_fn = jax.grad(log_lik_fn)
        grad_vmap = lambda th, b: jax.vmap(grad_fn)(th, b)  # noqa: E731
    use_surrogate = cfg.method == "fsgld"
    if not use_surrogate:
        bank_kind = None
    L = layout.num_leaves
    hmc = dynamics == "sghmc"

    def round_fn(state, keys, sids, shard_data, pbank=None, sp_rt=None):
        if not use_surrogate:
            pbank = None
        scale, f_s = chain_scales(cfg, scheme, sids, minibatch, sp_rt)
        sizes_rt = None if sp_rt is None else sp_rt[0]
        mu_g = mu_s = lam_gp = lam_sp = None
        lam_g_leaf = lam_s_leaf = None
        if bank_kind is None:
            variant = "plain"
        elif bank_kind == "diag":
            variant = "diag"
            mu_g, lam_gp = pbank["mu_g"], pbank["lam_g"]
            mu_s = pbank["means"][sids].reshape(-1, kops.LANE)
            lam_sp = pbank["precs"][sids].reshape(-1, kops.LANE)
        elif bank_kind == "scalar":
            variant = "scalar"
            mu_g = pbank["mu_g"]
            mu_s = pbank["means"][sids].reshape(-1, kops.LANE)
            lam_g_leaf = pbank["lam_g_leaf"]
            lam_s_leaf = pbank["lam_s_leaf"][sids]
        else:
            raise ValueError(bank_kind)
        scalars = kops.packed_scalar_rows(
            layout, h=cfg.step_size, scale=scale, f_s=f_s,
            prior_prec=cfg.prior_precision, alpha=cfg.alpha,
            temperature=(sghmc.temperature if hmc else cfg.temperature),
            lam_g_leaf=lam_g_leaf, lam_s_leaf=lam_s_leaf,
            friction=(sghmc.friction if hmc else 0.0))

        def body(carry, ks):
            if hmc:
                th_p, r_p, thetas = carry
            else:
                (th_p, thetas), r_p = carry, None
            kk = jax.vmap(jax.random.split)(ks)       # (C, 2, 2)
            k_batch, k_step = kk[:, 0], kk[:, 1]
            batches = jax.vmap(
                lambda k, s: sample(k, s, shard_data, sizes_rt))(
                k_batch, sids)
            glls = grad_vmap(thetas, batches)
            g_p = layout.pack(glls)
            seeds = kops.chain_leaf_seeds(k_step, L)
            out = kops.packed_step(
                layout, th_p, g_p, seeds, scalars, variant=variant,
                mu_g=mu_g, mu_s=mu_s, lam_g=lam_gp, lam_s=lam_sp,
                r_p=r_p, dynamics=dynamics)
            th_p = layout.quantize(out[0] if hmc else out)
            thetas = layout.unpack(th_p)
            if hmc:
                carry = (th_p, layout.quantize(out[1]), thetas)
            else:
                carry = (th_p, thetas)
            return carry, thetas if collect else None

        keys_t = jax.vmap(lambda k: jax.random.split(
            k, cfg.local_updates))(keys)              # (C, T, 2)
        state, trace = jax.lax.scan(body, state,
                                    jnp.swapaxes(keys_t, 0, 1))
        if collect and trace is not None:
            trace = jax.tree.map(lambda t: jnp.swapaxes(t, 0, 1), trace)
        return state, trace

    return round_fn


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshChainEngine:
    """shard_map-based multi-chain FSGLD runtime.

    shard_data: pytree with leaves (S, max_n, ...) — shards padded to the
    longest client; ``sizes`` carries true per-client counts (None =>
    uniform, no padding). ``mesh`` must expose ('data', 'model') axes;
    n_chains must divide by the data-axis size.

    ``use_kernel=True`` + ``packed`` (default: auto) selects the
    single-launch packed executor — one ``pallas_call`` per step for the
    whole chain block, for ANY mix of floating parameter-leaf dtypes
    (non-fp32 leaves quantize back to their storage dtype each step,
    replaying the per-leaf kernel's round-trip bit-exactly).
    ``packed=False`` keeps the PR 1 per-leaf chain-batched kernel path;
    auto falls back to it only for non-float leaves.

    ``dynamics='sghmc'`` swaps the per-step update for federated SGHMC
    (core/sghmc.py) over (theta, momentum) chain state — same estimator
    stack, reassignment, and collective path; the trace carries theta
    only. SGHMC composes with every executor: the reference vmap path
    runs the pure-jnp integrator, the fused-kernel paths route through
    the SGHMC variant of the Pallas kernels (the packed layout carries
    the momenta in a second chain-major buffer over the same segment
    table).

    ``n_chains`` no longer needs to divide the mesh data axis: odd chain
    counts are padded with dummy chains up to the next multiple (the pad
    chains run on the last data group(s) and are sliced out of every
    output). The REAL chains' RNG streams are derived from the true
    ``n_chains``, so a padded run stays bit-identical to the
    ``run_vmap`` oracle with the same chain count.

    ``aggregation='fald'`` turns the engine into FA-LD: participating
    chains' states are server-averaged at every communication round
    (inside the scan, a masked psum over the ``data`` axis) and each
    chain's injected noise is scaled so the AVERAGE has the configured
    temperature (per-client temperature × n_chains — FA-LD's
    ``sqrt(N/p_c)`` noise with uniform weights). Composes with every
    executor, Federation schedule/compression (including dual/bidir),
    health/recovery, and snapshots; Langevin dynamics only. The rounds
    always take the federated round body (even with no Federation spec),
    so FA-LD runs share one RNG stream layout with scheduled runs and
    the ``repro.rivals.fald`` oracle mirrors it bitwise.
    """
    log_lik_fn: LogLikFn
    cfg: SamplerConfig
    shard_data: PyTree
    minibatch: int
    bank: Optional[SurrogateBank] = None
    use_kernel: bool = False
    mesh: Any = None
    sizes: Optional[tuple] = None
    packed: Optional[bool] = None
    dynamics: str = "langevin"
    sghmc: Any = None  # Optional[SGHMCConfig]; None -> defaults
    aggregation: str = "none"  # 'none' | 'fald' (server-averaged rounds)
    stream_hook: Any = None  # callable(window_idx, StreamWindow) | None;
    # fires after each streamed window's dispatch (bench memory sampling)

    def __post_init__(self):
        if self.mesh is None:
            from repro.launch.mesh import make_host_mesh
            self.mesh = make_host_mesh()
        from repro.fed.partition import is_client_source
        self._source = (self.shard_data
                        if is_client_source(self.shard_data) else None)
        self._resident_cache = None
        if self._source is not None:
            # lazy per-client source: only the clients a run actually
            # touches are ever materialized (the streamed path gathers
            # resident windows; the resident path materializes all S
            # on first use — small-S only, by construction).
            s = int(self._source.num_clients)
            assert s == self.cfg.num_shards, (s, self.cfg.num_shards)
            assert self.sizes is None, \
                "a ClientSource carries its own sizes"
            sizes = np.asarray(self._source.sizes, np.int64)
            assert sizes.shape == (s,), sizes.shape
            assert int(sizes.max()) == int(self._source.max_size)
        else:
            leaf = jax.tree.leaves(self.shard_data)[0]
            s, max_n = leaf.shape[0], leaf.shape[1]
            assert s == self.cfg.num_shards, (s, self.cfg.num_shards)
            sizes = ((max_n,) * s if self.sizes is None
                     else tuple(int(n) for n in self.sizes))
            assert len(sizes) == s and max(sizes) == max_n, (sizes, max_n)
        self.scheme = ShardScheme(sizes=sizes, probs=self.cfg.probs())
        if self.aggregation not in ("none", "fald"):
            raise ValueError(
                f"unknown aggregation {self.aggregation!r}; "
                f"available: none, fald")
        if self.aggregation == "fald" and self.dynamics != "langevin":
            raise NotImplementedError(
                "aggregation='fald' is a Langevin-dynamics algorithm "
                "(FA-LD averages overdamped clients); it does not "
                f"compose with dynamics={self.dynamics!r}")
        if self.dynamics == "sghmc":
            from repro.core.sghmc import SGHMCConfig, make_sghmc_step
            if self.sghmc is None:
                self.sghmc = SGHMCConfig()
            # the pure-jnp integrator backs the reference vmap executor;
            # the kernel executors route through the fused SGHMC kernels
            self.step_fn = make_sghmc_step(
                self.log_lik_fn, self.cfg, self.scheme, self.bank,
                self.sghmc)
        elif self.dynamics == "langevin":
            self.step_fn = make_step_fn(self.log_lik_fn, self.cfg,
                                        self.scheme, self.bank,
                                        use_kernel=False)
        else:
            raise ValueError(self.dynamics)
        self._executors = {}

    # -- executors ---------------------------------------------------------

    def _chain_spec(self):
        return chain_spec()

    # -- client-axis materialization ---------------------------------------

    def _data(self):
        """The FULL (S, max_n, ...) shard stack for resident-path runs.
        Materialized (and cached) from a lazy ClientSource on first use —
        the streamed path never calls this."""
        if self._source is None:
            return self.shard_data
        if self._resident_cache is None:
            ids = np.arange(self.cfg.num_shards)
            self._resident_cache = jax.tree.map(
                jnp.asarray, self._source.rows(ids))
        return self._resident_cache

    def _client_rows(self, ids):
        """(K, max_n, ...) rows for one resident window. From a
        ClientSource this builds ONLY the requested clients; from a
        materialized stack it gathers rows of identical values — either
        way a streamed lookup reads the exact bytes the resident path
        reads, which is what makes streamed runs bitwise identical."""
        if self._source is not None:
            return jax.tree.map(jnp.asarray, self._source.rows(ids))
        idx = jnp.asarray(np.asarray(ids, np.int32))
        return jax.tree.map(lambda d: d[idx], self.shard_data)

    def _layout_for(self, theta0: PyTree) -> Optional[kops.PackedChains]:
        """Resolve the packed layout for this run, or None for the
        per-leaf paths. Mixed floating dtypes pack (non-fp32 leaves
        quantize back each step); non-float leaves cannot ride the fp32
        buffer — auto falls back to the per-leaf path, explicit
        packed=True refuses."""
        if not self.use_kernel:
            if self.packed:
                raise ValueError("packed=True requires use_kernel=True")
            return None
        if self.packed is False:
            return None
        floating = all(jnp.issubdtype(l.dtype, jnp.floating)
                       for l in jax.tree.leaves(theta0))
        if not floating:
            if self.packed is None:
                return None
            raise ValueError("packed executor requires floating-point "
                             "parameter leaves (state rides an fp32 "
                             "buffer with per-leaf quantize-back)")
        return kops.make_packed_layout(theta0)

    def _executor(self, *, num_rounds: int, n_chains: int,
                  n_total: Optional[int] = None, reassign: str,
                  collect: bool, collect_every: int,
                  layout: Optional[kops.PackedChains], federation=None,
                  recovery=None, chaos=None,
                  stream: Optional[int] = None, telemetry=None):
        """jit(shard_map(scan-over-rounds)) executor: ONE dispatch runs
        ``num_rounds`` communication rounds — reassignment, round key
        splitting, local updates, and thinned trace collection all live
        inside the scan. Chain state is donated, the trace comes back as
        a preallocated (C, num_rounds * ceil(T/collect_every), ...) block,
        and the final round key is returned so chunked callers (adaptive
        refresh, snapshot segments) continue the same stream. Cached per
        configuration.

        Signature: ``execute(key, chains, shard_data, bank_rt, r0,
        fed_carry, health) -> (chains, traces, key, fed_carry, health)``.
        ``r0`` is the absolute index of the first round this dispatch
        runs (traced — resegmenting a run never retraces); ``fed_carry``
        is ``(sids, (ref, err[, derr]) | None)`` for a lowered
        federation scenario or FA-LD aggregation (``derr`` rides along
        for dual/bidir compression) and None otherwise; ``health`` is
        ``(word, lp_window)`` when a recovery policy is active and None
        otherwise. Threading
        both through the executor I/O is what makes segment boundaries
        (snapshots, resume) invisible to the scanned state.

        ``n_chains`` is the REAL chain count (the RNG fan-out width — it
        must match the oracle's); ``n_total`` >= n_chains is the padded
        count actually resident on the mesh (a data-axis multiple). Pad
        chains get sid 0 (categorical; their permutation slot otherwise)
        and a zero key; their trajectories are computed and discarded by
        ``run``'s output slice.

        ``federation`` (a ``repro.fed.Federation``, or None) lowers the
        scenario's communication schedule and payload compression INTO
        the scanned round body: the carry gains the resident sids (kept
        across delayed/non-participating rounds) and, with compression,
        the per-chain (server-view, error-feedback) flat state — still
        one scan, one dispatch, no retrace per scenario. An
        engine-identity spec lowers to None and shares the oracle
        executor bit-for-bit.

        ``recovery`` (``repro.core.health.Recovery``, or None) lowers the
        per-chain health check + quarantine/respawn masking into the
        round bodies; ``chaos`` (duck-typed ``repro.testing.ChaosSpec``)
        lowers the static fault plan. Both are per-chain ``where`` masks:
        a fault-free run with them enabled is bitwise identical to one
        without, and a faulted chain never touches its neighbours.

        ``telemetry`` (``repro.obs.Telemetry``, or None) lowers per-round
        per-chain metric rows into the same round bodies as EXTRA scan
        outputs; the executor then returns a sixth value — a dict of
        (C, num_rounds) fp32 metric arrays. Probe metrics draw their
        minibatch from ``fold_in(k_run, TELEMETRY_PROBE_SALT)`` (the
        health-detector isolation pattern), so telemetry never perturbs
        the sampling stream: a telemetry-on run's chains and trace are
        bitwise identical to a telemetry-off run's."""
        if n_total is None:
            n_total = n_chains
        fed = (federation if federation is not None
               and not federation.engine_identity else None)
        chaos = chaos if chaos is not None and chaos.active else None
        rec = recovery
        tel = telemetry
        cache_key = (num_rounds, n_chains, n_total, reassign, collect,
                     collect_every, layout, fed, rec, chaos, stream, tel)
        if cache_key in self._executors:
            return self._executors[cache_key]

        cfg = self.cfg
        S = cfg.num_shards
        per = n_total // self.mesh.shape["data"]
        n_pad = n_total - n_chains
        if reassign == "categorical" and cfg.method != "sgld":
            # built lazily: at streamed-client scale probs() is None
            # (implicit uniform) and categorical reassignment is refused
            # before ever reaching an executor
            log_probs = jnp.log(jnp.asarray(self.scheme.probs_array()))
        bank_kind = self.bank.kind if self.bank is not None else None

        # FA-LD noise calibration: averaging C clients shrinks the
        # injected-noise variance by C, so each client samples at
        # temperature * C and the AVERAGED iterate targets cfg.temperature
        # (Deng et al. 2021's sqrt(N/p_c) client noise, uniform weights).
        agg = self.aggregation == "fald"
        cfg_dyn = (dataclasses.replace(
            cfg, temperature=cfg.temperature * n_chains) if agg else cfg)

        grad_vmap = make_masked_grad_vmap(
            jax.grad(self.log_lik_fn), per=per, n_chains=n_chains,
            d_size=self.mesh.shape["data"]) if n_pad else None
        if layout is not None:
            round_fn = make_packed_round_fn(
                self.log_lik_fn, cfg_dyn, self.scheme, self.minibatch,
                bank_kind, layout, collect=collect, dynamics=self.dynamics,
                sghmc=self.sghmc, grad_vmap=grad_vmap)
        elif self.use_kernel:
            round_fn = make_chain_round_fn(
                self.log_lik_fn, cfg_dyn, self.scheme, self.minibatch,
                bank_kind, collect=collect, dynamics=self.dynamics,
                sghmc=self.sghmc, grad_vmap=grad_vmap)
        else:
            step_fn = self.step_fn if not agg else make_step_fn(
                self.log_lik_fn, cfg_dyn, self.scheme, self.bank,
                use_kernel=False)
            one_chain = make_round_fn(
                self.log_lik_fn, cfg_dyn, self.scheme, step_fn,
                self.minibatch, collect=collect,
                collect_state=((lambda s: s[0])
                               if self.dynamics == "sghmc" else None))

            def round_fn(thetas, keys, sids, shard_data, bank_rt,
                         sp_rt=None):
                return jax.vmap(
                    one_chain, in_axes=(0, 0, 0, None, None, None))(
                    thetas, keys, sids, shard_data, bank_rt, sp_rt)

        def pad_tail(arr):
            """Extend a (n_chains, ...) per-chain operand to n_total rows
            with zeros for the dummy pad chains (concatenate, not `pad`:
            the scan bodies carry a no-pad-primitive jaxpr guarantee)."""
            if n_pad == 0:
                return arr
            tail = jnp.zeros((n_pad,) + arr.shape[1:], arr.dtype)
            return jnp.concatenate([arr, tail])

        hmc = self.dynamics == "sghmc"

        # federation lowering: the schedule/compression hooks operate on
        # the canonical per-chain (theta, momentum) view of whatever state
        # form the executor carries, and write back through set_view
        # (repacking the packed buffers — lossless: the pallas update is
        # elementwise, so buffer pad lanes never feed real lanes).
        if layout is not None:
            def get_view(st):
                if hmc:
                    return st[2], layout.unpack(st[1])
                return st[1], None

            def set_view(st, th, r):
                if hmc:
                    return (layout.pack(th), layout.pack(r), th)
                return (layout.pack(th), th)
        else:
            def get_view(st):
                return (st[0], st[1]) if hmc else (st, None)

            def set_view(st, th, r):
                return (th, r) if hmc else th

        # FA-LD takes the federated round body even with no Federation
        # spec (identity schedule, exact exchange): the averaging is a
        # communication-round feature, and sharing the fed body keeps ONE
        # RNG stream layout for the rivals/fald oracle to mirror.
        use_fed = fed is not None or agg
        if use_fed:
            from repro.fed import schedule as fsched
            from repro.fed.compress import (Compression, make_compressor,
                                            make_flattener)
            if fed is not None:
                sched, comp = fed.schedule, fed.compression
            else:
                sched, comp = fsched.CommSchedule(), Compression()
            use_part = sched.participation < 1.0
            use_strag = sched.straggler_prob > 0.0
            use_comp = not comp.identity
            # ELF leg selection: primal compresses client->server uploads
            # (today's path), dual compresses the server->client
            # broadcast with its own EF residual riding the carry.
            use_primal, use_dual = comp.use_primal, comp.use_dual
            use_exch = use_comp or agg

        # the identity fast path keeps its round-index-free scan (xs=None)
        # — same jaxpr as ever; any of these features needs the absolute
        # round index threaded through the scan instead.
        use_r = use_fed or chaos is not None or rec is not None
        if rec is not None and rec.use_detector:
            probe_sample = _make_batch_sampler(cfg, self.scheme,
                                               self.minibatch)
        log_lik = self.log_lik_fn

        # telemetry lowering: every metric is either closed-form over
        # values the round body already carries, or a PROBE evaluation on
        # a fold_in-salted key — nothing consumes the sampling stream,
        # and none of it needs the absolute round index (use_r unchanged:
        # the identity fast path keeps its xs=None scan with telemetry on)
        if tel is not None:
            scheme = self.scheme
            minibatch = self.minibatch
            if tel.probe:
                tel_sample = _make_batch_sampler(cfg, scheme, minibatch)
            h = cfg_dyn.step_size
            if self.dynamics == "sghmc":
                # naive-Euler SGHMC noise term: sqrt(2 a tau) sqrt(h) xi
                # (core/sghmc.py)
                tel_noise = float(np.sqrt(
                    2.0 * self.sghmc.friction * self.sghmc.temperature
                    * h))
            else:
                tel_noise = float(np.sqrt(h * cfg_dyn.temperature))

        def block(key, chains, shard_data, bank_rt, r0, fedc, hw0,
                  stream_ids=None, sp_rt=None):
            # streamed client axis: shard_data/bank_rt hold only the
            # RESIDENT window's K client rows; ``stream_ids`` is the
            # sorted (K,) global-id vector and ``sp_rt`` the resident
            # (sizes_i32, sizes_f32, probs_f32) metadata rows. Carried
            # sids stay GLOBAL (so fed carries compare bitwise across
            # window boundaries); each round remaps them to
            # resident-local once, by a compare-and-sum rank — NOT
            # searchsorted, which lowers with an inner scan and would
            # break the one-scan jaxpr guarantee.
            if stream is not None:
                def to_local(s):
                    loc = jnp.sum(stream_ids[None, :] < s[:, None],
                                  axis=1)
                    # pad chains may hold ids outside the window (their
                    # trajectories are discarded); clamp keeps their
                    # gathers in range without a pad primitive
                    return jnp.minimum(loc, stream - 1).astype(jnp.int32)
            else:
                to_local = lambda s: s  # noqa: E731
            if layout is not None:
                rt_bank = pack_bank(
                    layout, bank_rt if cfg.method == "fsgld" else None)
                if hmc:
                    th_c, r_c = chains
                    # the momenta ride a SECOND chain-major buffer over
                    # the SAME segment table (their own seed stream is
                    # the per-step noise draw routed by seed BlockSpecs)
                    state = (layout.pack(th_c), layout.pack(r_c), th_c)
                else:
                    state = (layout.pack(chains), chains)
            else:
                rt_bank = bank_rt
                state = chains
            blk = jax.lax.axis_index("data") * per

            # ---- telemetry metric rows --------------------------------
            if tel is not None:
                th_tpl = chains[0] if hmc else chains
                # flat parameter count — the wire-byte estimates' dim
                tel_dim = sum(int(np.prod(l.shape[1:]))
                              for l in jax.tree.leaves(th_tpl))
                tel_sizes_rt = None if sp_rt is None else sp_rt[0]

            def tel_sq(tree):
                """Per-chain sum of squares over all leaves, (per,) f32."""
                s = None
                for l in jax.tree.leaves(tree):
                    v = jnp.sum(jnp.square(
                        l.astype(jnp.float32)).reshape((per, -1)), axis=1)
                    s = v if s is None else s + v
                return s

            def tel_metrics(k_run, state, pre_th, sids, exch_f, nbytes,
                            hw):
                """One round's metric rows, each (per,) fp32 — computed
                AFTER the round's masking (straggler/health), so frozen
                chains show zero drift and quarantined ones their word.
                ``sids`` are resident-local; ``exch_f``/``nbytes`` come
                from the caller (fed bodies gate them on the exchange
                mask, the identity body exchanges every round)."""
                th, _ = get_view(state)
                th_sq = tel_sq(th)
                m = {"theta_norm": jnp.sqrt(th_sq),
                     "drift_norm": jnp.sqrt(tel_sq(jax.tree.map(
                         lambda a, b: a.astype(jnp.float32)
                         - b.astype(jnp.float32), th, pre_th))),
                     "noise_scale": jnp.full((per,), tel_noise,
                                             jnp.float32)}
                if bank_rt is not None and cfg.method == "fsgld":
                    _, f_s = chain_scales(cfg, scheme, sids, minibatch,
                                          sp_rt)
                    from repro.core.conducive import \
                        conducive_gradient_from_bank
                    g_c = jax.vmap(
                        lambda t, s, f: conducive_gradient_from_bank(
                            t, bank_rt, s, f, cfg.alpha))(th, sids, f_s)
                    m["conducive_norm"] = jnp.sqrt(tel_sq(g_c))
                else:
                    m["conducive_norm"] = jnp.zeros((per,), jnp.float32)
                m["participation"] = exch_f
                m["bytes_per_round"] = nbytes
                m["health_word"] = (hw[0].astype(jnp.float32)
                                    if rec is not None
                                    else jnp.zeros((per,), jnp.float32))
                if tel.probe:
                    kp = jax.lax.dynamic_slice_in_dim(
                        pad_tail(jax.random.split(jax.random.fold_in(
                            k_run, TELEMETRY_PROBE_SALT), n_chains)),
                        blk, per)

                    def probe_one(t, k, s):
                        batch = tel_sample(k, s, shard_data, tel_sizes_rt)
                        return jax.value_and_grad(log_lik)(t, batch)

                    lp, g_p = jax.vmap(probe_one)(th, kp, sids)
                    m["grad_norm"] = jnp.sqrt(tel_sq(g_p))
                    m["log_post"] = lp.astype(jnp.float32) \
                        - 0.5 * cfg.prior_precision * th_sq
                return {n: m[n] for n in tel.names}

            def propose_sids(k_assign):
                """This round's chain->client draw — the same derivation
                on the identity and scheduled paths (schedules only gate
                whether a chain TAKES its draw)."""
                if cfg.method == "sgld":
                    return jnp.zeros((per,), jnp.int32)
                if reassign == "categorical":     # paper Algorithm 1
                    return jax.lax.dynamic_slice_in_dim(
                        pad_tail(jax.random.categorical(
                            k_assign,
                            log_probs[None].repeat(n_chains, 0))),
                        blk, per)
                # SPMD variant (DESIGN 4.1); block-cyclic when C > S
                return _perm_sids_slice(k_assign, S, blk, per, n_total)

            # ---- fault lowering (chaos + health) -----------------------
            gid = blk + jnp.arange(per)          # global chain ids
            is_real = gid < n_chains

            def poison_state(r, state):
                """chaos: NaN the chosen chains' post-round theta at the
                chosen absolute rounds — per-chain, so every other chain
                is bitwise untouched."""
                if chaos is None or not chaos.poisons_state:
                    return state
                m = jnp.isin(r, jnp.asarray(chaos.nan_rounds)) & \
                    jnp.isin(gid, jnp.asarray(chaos.nan_chains))
                th, mom = get_view(state)
                th = jax.tree.map(
                    lambda l: jnp.where(
                        m.reshape((per,) + (1,) * (l.ndim - 1)),
                        jnp.nan, l)
                    if jnp.issubdtype(l.dtype, jnp.inexact) else l, th)
                return set_view(state, th, mom)

            def finite_chains(tree):
                ok = None
                for l in jax.tree.leaves(tree):
                    f = jnp.all(jnp.isfinite(l.reshape((per, -1))), axis=1)
                    ok = f if ok is None else ok & f
                return ok

            def check_health(r, k_run, sids, pre_th, pre_mom, state,
                             trace, hw):
                """Per-chain health word update + recovery masking, run
                once per ROUND after the local updates (no extra
                launches). Every write is a per-chain where(): a chain
                that never trips keeps bit-identical state/trace, and a
                tripped chain never reaches into its neighbours.

                The divergence reference is a nearest-rank QUANTILE over
                the chain's last ``rec.window`` probes (the ring rides
                the health carry, -inf padded), not a running max: the
                quantile is robust to single lucky probes, so the
                threshold can sit a few probe-IQRs under the recent
                healthy plateau and a SLOW divergence trips early. While
                the window is -inf dominated (warm-up, post-respawn) the
                reference is -inf and nothing trips — so a fault-free
                run stays bitwise identical with health on or off."""
                word, lp_win = hw
                th, mom = get_view(state)
                bad_new = ~finite_chains(th)
                if hmc and rec.check_momentum:
                    bad_new = bad_new | ~finite_chains(mom)
                lp = None
                if rec.use_detector:
                    # probe key from fold_in: the detector consumes
                    # NOTHING from the sampling stream, so enabling it
                    # cannot perturb the chains it watches
                    kp = jax.lax.dynamic_slice_in_dim(
                        pad_tail(jax.random.split(jax.random.fold_in(
                            k_run, HEALTH_PROBE_SALT), n_chains)),
                        blk, per)
                    sq = None
                    for l in jax.tree.leaves(th):
                        s = jnp.sum(jnp.square(
                            l.astype(jnp.float32)).reshape((per, -1)), 1)
                        sq = s if sq is None else sq + s
                    lp = jax.vmap(
                        lambda t, k, s: log_lik(
                            t, probe_sample(k, s, shard_data)))(
                        th, kp, sids)
                    lp = lp.astype(jnp.float32) \
                        - 0.5 * cfg.prior_precision * sq
                    # nearest-rank quantile, NOT jnp.quantile: lerp
                    # between -inf (warm-up padding) and a finite probe
                    # would be NaN
                    q_idx = min(rec.window - 1,
                                int(rec.quantile * (rec.window - 1)))
                    lp_ref = jnp.sort(lp_win, axis=1)[:, q_idx]
                    bad_new = bad_new | ~jnp.isfinite(lp) | \
                        (lp < lp_ref - rec.divergence_threshold)
                    pushed = jnp.concatenate(
                        [lp_win[:, 1:], lp[:, None]], axis=1)
                if rec.policy == "quarantine":
                    bad = (word != 0) | bad_new
                    word = jnp.where((word == 0) & bad_new,
                                     r + 1, word)

                    def fix(new, old):
                        return jnp.where(
                            bad.reshape((per,) + (1,) * (new.ndim - 1)),
                            old, new)

                    if lp is not None:
                        # quarantined chains' windows freeze with them
                        lp_win = jnp.where(
                            (bad | ~jnp.isfinite(lp))[:, None],
                            lp_win, pushed)
                    repl = bad
                else:                                       # respawn
                    word = word + bad_new.astype(word.dtype)
                    healthy = (~bad_new) & is_real
                    donor = jnp.argmax(healthy)
                    any_h = jnp.any(healthy)

                    def fix(new, old):
                        # re-seed from the block's first healthy real
                        # chain; freeze in place when the whole block
                        # diverged at once
                        cand = jnp.where(any_h, new[donor][None], old)
                        return jnp.where(
                            bad_new.reshape(
                                (per,) + (1,) * (new.ndim - 1)),
                            cand, new)

                    if lp is not None:
                        # respawned chains restart an empty window (their
                        # donor's plateau is not theirs)
                        lp_win = jnp.where(
                            ((~bad_new) & jnp.isfinite(lp))[:, None],
                            pushed, lp_win)
                        lp_win = jnp.where(bad_new[:, None], -jnp.inf,
                                           lp_win)
                    repl = bad_new
                th = jax.tree.map(fix, th, pre_th)
                mom = jax.tree.map(fix, mom, pre_mom) if hmc else None
                if collect:
                    trace = jax.tree.map(
                        lambda t, f: jnp.where(
                            repl.reshape((per, 1) + (1,) * (t.ndim - 2)),
                            f[:, None], t),
                        trace, th)
                return set_view(state, th, mom), trace, (word, lp_win)

            def round_body(carry, r):
                key, state, hw = carry
                key, k_assign, k_run = jax.random.split(key, 3)
                sids = propose_sids(k_assign)
                run_sids = to_local(sids)
                if rec is not None or tel is not None:
                    pre_th, pre_mom = get_view(state)
                keys_blk = jax.lax.dynamic_slice_in_dim(
                    pad_tail(jax.random.split(k_run, n_chains)), blk, per)
                state, trace = round_fn(state, keys_blk, run_sids,
                                        shard_data, rt_bank, sp_rt)
                state = poison_state(r, state)
                if rec is not None:
                    state, trace, hw = check_health(
                        r, k_run, run_sids, pre_th, pre_mom, state, trace,
                        hw)
                y = (jax.tree.map(lambda t: t[:, ::collect_every], trace)
                     if collect else None)
                if tel is not None:
                    # the identity path exchanges (reassigns) every
                    # round: participation 1, exact wire bytes both legs
                    y = (y, tel_metrics(
                        k_run, state, pre_th, run_sids,
                        jnp.ones((per,), jnp.float32),
                        jnp.full((per,), 8.0 * tel_dim, jnp.float32), hw))
                return (key, state, hw), y

            def fed_round_body(carry, r):
                key, state, sids, cst, hw = carry
                key, k_assign, k_run, k_fed = jax.random.split(key, 4)
                new_sids = propose_sids(k_assign).astype(jnp.int32)
                comm = fsched.comm_mask(sched, r)
                if use_part:
                    exch = comm & jax.lax.dynamic_slice_in_dim(
                        pad_tail(fsched.participation_mask(
                            sched, jax.random.fold_in(k_fed, 0), r,
                            n_chains)), blk, per)
                else:
                    exch = jnp.broadcast_to(comm, (per,))
                if rec is not None and rec.policy == "quarantine":
                    # quarantined chains are masked OUT of the exchange:
                    # they neither reassign nor push/pull the server view
                    # (their ref/err rows freeze with them)
                    exch = exch & (hw[0] == 0)
                sids = jnp.where(exch, new_sids, sids)
                if use_exch:
                    # exchange at the round boundary: primal leg
                    # (compressed client->server upload), optional FA-LD
                    # server averaging over the participating chains,
                    # optional dual leg (compressed server->client
                    # broadcast) — the exchanging chains continue from
                    # the server's view; everyone else's state is
                    # untouched — bitwise: non-exchanging chains' leaves
                    # are never written (no fp32 flatten round-trip), and
                    # the whole pipeline (flatten, top_k/quantize,
                    # average, repack) runs under a lax.cond so delayed
                    # schedules skip it entirely on non-communication
                    # rounds (comm is a replicated scalar of r, so the
                    # cond is SPMD-safe).
                    def do_exchange(op):
                        state, cst_in = op
                        th, mom = get_view(state)
                        flat = flatten(th)
                        poison = None
                        if chaos is not None and chaos.poisons_payload:
                            # corrupted wire payload: the delta the server
                            # applies goes NaN for the chosen chains at
                            # the chosen rounds — their server view (and
                            # the state they continue from) diverges
                            poison = jnp.isin(r, jnp.asarray(
                                chaos.payload_nan_rounds)) & jnp.isin(
                                gid, jnp.asarray(chaos.payload_nan_chains))
                        if use_primal:
                            ref, err = cst_in[0], cst_in[1]
                            upd = flat - ref + err
                            dhat = compress(
                                upd, jax.random.fold_in(k_fed, 1))
                            if poison is not None:
                                dhat = jnp.where(poison[:, None],
                                                 jnp.nan, dhat)
                            # m_flat: the server's per-chain model after
                            # the upload leg
                            m_flat = ref + dhat
                            err_new = (upd - dhat if comp.error_feedback
                                       else jnp.zeros_like(upd))
                        else:
                            ref = cst_in[0] if cst_in is not None else None
                            m_flat = flat
                            if poison is not None:
                                m_flat = jnp.where(poison[:, None],
                                                   jnp.nan, m_flat)
                        if agg:
                            # FA-LD server step: average the exchanging
                            # REAL chains' models (masked psum over the
                            # chain axis — every data group sees the same
                            # average; pad chains never contribute).
                            w = exch & is_real
                            cnt = jax.lax.psum(
                                jnp.sum(w.astype(jnp.float32)), "data")
                            tot = jax.lax.psum(jnp.sum(
                                jnp.where(w[:, None], m_flat, 0.0),
                                axis=0), "data")
                            avg = tot / jnp.maximum(cnt, 1.0)
                            m_flat = jnp.where(w[:, None], avg[None],
                                               m_flat)
                        if use_dual:
                            # dual leg: the broadcast is a compressed
                            # delta against the SHARED reference (what
                            # both sides last agreed on), with its own
                            # error-feedback residual
                            derr = cst_in[2]
                            dupd = m_flat - ref + derr
                            dd = compress(
                                dupd, jax.random.fold_in(k_fed, 3))
                            v_new = ref + dd
                            derr_new = (dupd - dd if comp.error_feedback
                                        else jnp.zeros_like(dupd))
                        else:
                            # exact broadcast: the client receives the
                            # server model itself (NOT ref + (m - ref):
                            # the fp round-trip would break bitwise
                            # parity of primal-only runs)
                            v_new = m_flat
                        cst_out = cst_in
                        if use_comp:
                            mm = exch[:, None]
                            ref_o = jnp.where(mm, v_new, cst_in[0])
                            err_o = (jnp.where(mm, err_new, cst_in[1])
                                     if use_primal else cst_in[1])
                            if use_dual:
                                cst_out = (ref_o, err_o,
                                           jnp.where(mm, derr_new,
                                                     cst_in[2]))
                            else:
                                cst_out = (ref_o, err_o)
                        th_srv = unflatten(v_new)  # the clients' new view
                        th = jax.tree.map(
                            lambda srv, old: jnp.where(
                                exch.reshape((per,)
                                             + (1,) * (old.ndim - 1)),
                                srv, old),
                            th_srv, th)
                        return set_view(state, th, mom), cst_out

                    state, cst = jax.lax.cond(
                        comm, do_exchange, lambda op: op, (state, cst))
                run_sids = to_local(sids)
                if use_strag or rec is not None or tel is not None:
                    pre_th, pre_mom = get_view(state)
                keys_blk = jax.lax.dynamic_slice_in_dim(
                    pad_tail(jax.random.split(k_run, n_chains)), blk, per)
                state, trace = round_fn(state, keys_blk, run_sids,
                                        shard_data, rt_bank, sp_rt)
                if use_strag:
                    # dropped updates: straggler chains' state does not
                    # advance and their trace repeats the frozen position
                    strag = jax.lax.dynamic_slice_in_dim(
                        pad_tail(fsched.straggler_mask(
                            sched, jax.random.fold_in(k_fed, 2),
                            n_chains)), blk, per)

                    def keep(new, old):
                        mm = strag.reshape((per,) + (1,) * (new.ndim - 1))
                        return jnp.where(mm, old, new)

                    th, mom = get_view(state)
                    th = jax.tree.map(keep, th, pre_th)
                    mom = (jax.tree.map(keep, mom, pre_mom) if hmc
                           else None)
                    state = set_view(state, th, mom)
                    if collect:
                        trace = jax.tree.map(
                            lambda t, p: jnp.where(
                                strag.reshape((per,) + (1,) * (t.ndim - 1)),
                                p[:, None], t),
                            trace, pre_th)
                state = poison_state(r, state)
                if rec is not None:
                    state, trace, hw = check_health(
                        r, k_run, run_sids, pre_th, pre_mom, state, trace,
                        hw)
                y = (jax.tree.map(lambda t: t[:, ::collect_every], trace)
                     if collect else None)
                if tel is not None:
                    # exch already folds in the comm schedule, the
                    # participation draw, and quarantine masking — the
                    # chains that actually moved bytes this round
                    exch_f = exch.astype(jnp.float32)
                    y = (y, tel_metrics(
                        k_run, state, pre_th, run_sids, exch_f,
                        exch_f * float(comp.bytes_per_round(tel_dim)),
                        hw))
                return (key, state, sids, cst, hw), y

            rounds = (r0 + jnp.arange(num_rounds)) if use_r else None
            if not use_fed:
                (key, state, hw0), traces = jax.lax.scan(
                    round_body, (key, state, hw0), rounds,
                    length=num_rounds)
            else:
                th0, _ = get_view(state)
                flatten, unflatten, dim = make_flattener(th0)
                if use_comp:
                    compress = make_compressor(comp, dim)
                (key, state, f_sids, f_cst, hw0), traces = jax.lax.scan(
                    fed_round_body,
                    (key, state, fedc[0], fedc[1], hw0), rounds)
                fedc = (f_sids, f_cst)
            tmet = None
            if tel is not None:
                # scan stacked each (per,) metric row to (R, per);
                # chain-major (per, R) matches the trace's output layout
                traces, tmet = traces
                tmet = {k: jnp.swapaxes(v, 0, 1)
                        for k, v in tmet.items()}
            if layout is not None:
                chains_out = ((state[2], layout.unpack(state[1])) if hmc
                              else state[1])
            else:
                chains_out = state
            if collect:
                # (R, C_blk, T/ce, ...) -> (C_blk, R * T/ce, ...): same
                # round-major order the legacy host-side concatenate built.
                traces = jax.tree.map(
                    lambda t: jnp.swapaxes(t, 0, 1).reshape(
                        (t.shape[1], num_rounds * t.shape[2])
                        + t.shape[3:]),
                    traces)
            if tel is not None:
                return chains_out, traces, key, fedc, hw0, tmet
            return chains_out, traces, key, fedc, hw0

        cspec = self._chain_spec()
        fc_spec = fed_carry_spec() if use_fed else None
        h_spec = cspec if rec is not None else None
        in_specs = (P(), cspec, P(), P(), P(), fc_spec, h_spec)
        if stream is not None:
            # resident window ids + metadata rows: replicated, like the
            # shard stack they index into
            w_spec = stream_window_spec()
            in_specs = in_specs + (w_spec, (w_spec,) * 3)
        out_specs = (cspec, cspec if collect else None, P(), fc_spec,
                     h_spec)
        if tel is not None:
            # metric rows are chain-major (C, R): sharded like the trace
            out_specs = out_specs + (cspec,)
        mapped = shard_map(
            block, mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False)
        fn = jax.jit(mapped, donate_argnums=(1,))
        self._executors[cache_key] = fn
        return fn

    def _permute_sids(self, k_assign: jax.Array, n_chains: int):
        """Host-callable wrapper around ``_perm_sids_slice`` (the same
        helper the scanned round body uses) for one whole reassignment:
        returns the (n_chains,) collision-free sids for this round
        (block-cyclic when n_chains > S)."""
        S = self.cfg.num_shards
        per = n_chains // self.mesh.shape["data"]

        def block(k):
            return _perm_sids_slice(
                k[0], S, jax.lax.axis_index("data") * per, per,
                n_total=n_chains)

        return shard_map(
            block, mesh=self.mesh, in_specs=(P(),),
            out_specs=P("data"), check_rep=False)(k_assign[None])

    # -- server-side loop --------------------------------------------------

    def run(self, key: jax.Array, theta0: PyTree, num_rounds: int, *,
            n_chains: int = 1, reassign: str = "categorical",
            collect_every: int = 1, refresh_every: Optional[int] = None,
            collect: bool = True, stacked: bool = False,
            federation=None, recovery=None, chaos=None,
            snapshot_every: Optional[int] = None,
            snapshot_path: Optional[str] = None, resume: bool = False,
            stream=None, telemetry=None):
        """Same contract (and same RNG stream) as the legacy
        ``FederatedSampler.run``: returns stacked samples with leading axes
        (n_chains, num_rounds * T_local / collect_every, ...), or the final
        chain states when ``collect=False`` (large-model mode — the trace
        of a billion-parameter posterior does not fit anywhere).

        All rounds execute as ONE jitted scan (one host dispatch per run;
        with ``refresh_every``, one per refresh segment — the refresh
        itself is a host-side surrogate re-fit between segments).

        ``stacked=True`` treats ``theta0`` as per-chain states with a
        leading (n_chains, ...) axis instead of one state to broadcast —
        the entry point for round-at-a-time drivers that carry chain
        state across calls (the retired launch/steps.py federated round).

        ``dynamics='sghmc'`` engines accept the plain parameter pytree
        and pair it with zero momenta internally (the momenta are part of
        the mailed chain state); ``collect=False`` returns the
        (theta, momentum) pairs.

        ``federation`` (a ``repro.fed.Federation`` spec, or None) applies
        the scenario's communication schedule and round-boundary payload
        compression inside the scanned round body. Partitioning is NOT
        the engine's job — ``shard_data`` must already be split (the
        ``repro.api`` facade applies ``Federation.partition``). An
        engine-identity spec is bit-identical to ``federation=None``.

        ``reassign='permutation'`` supports n_chains > num_shards via
        BLOCK-CYCLIC client visiting: the round's permutation is tiled so
        chain c sits at client perm[c % S] — every client hosts
        floor/ceil(C/S) chains.

        Fault tolerance: ``recovery`` (a ``repro.core.health.Recovery``)
        turns on the in-scan health check and makes the call return
        ``(result, RunHealth)`` — the health word per REAL chain (0 =
        never faulted). ``chaos`` injects a static fault plan (testing).
        ``snapshot_every=k, snapshot_path=dir`` atomically checkpoints
        the full scan carry every k rounds; ``resume=True`` continues
        from the newest valid snapshot in ``snapshot_path`` (falling
        back to a fresh run when none exists) with traces bitwise
        identical to an uninterrupted run.

        ``telemetry`` (a ``repro.obs.Telemetry``) lowers per-round
        per-chain metric rows into the scanned round body and APPENDS a
        ``repro.obs.MetricsFrame`` to the return value — the result
        tuple is built in order (result[, health][, frame]).
        ``telemetry.log_every`` segments the run (bitwise losslessly,
        via the same carry threading snapshots use) and emits an
        ``engine.progress`` trace event per segment. The frame covers
        the rounds executed by THIS call (a resumed run's frame starts
        at its resume point). Telemetry-off runs are bitwise identical
        to telemetry-on runs — and to runs on code that predates the
        telemetry layer.
        """
        d_size = self.mesh.shape["data"]
        n_total = n_chains + (-n_chains) % d_size
        if self.cfg.method != "sgld" and reassign not in ("categorical",
                                                          "permutation"):
            raise ValueError(reassign)
        fed = (federation if federation is not None
               and not federation.engine_identity else None)
        chaos = chaos if chaos is not None and chaos.active else None
        if stream is not None:
            # streamed client axis: only the planner-replayable,
            # window-local features compose. Everything below needs
            # either all clients resident or an un-plannable RNG stream —
            # refuse loudly rather than stream wrong results.
            if self.cfg.method == "sgld":
                raise NotImplementedError(
                    "stream= does not compose with method='sgld': pooled "
                    "sampling draws from the virtual concatenation of ALL "
                    "clients and needs them resident")
            if reassign != "permutation":
                raise NotImplementedError(
                    f"stream= requires reassign='permutation' (got "
                    f"{reassign!r}): the resident-set planner replays the "
                    "collision-free permutation stream; categorical "
                    "draws are not plannable ahead of the scan")
            if refresh_every:
                raise NotImplementedError(
                    "stream= does not compose with refresh_every: the "
                    "surrogate re-fit is a pass over ALL clients' data")
            if snapshot_every or resume:
                raise NotImplementedError(
                    "stream= does not compose with snapshots/resume yet: "
                    "the window plan is not part of the snapshot payload")
            if recovery is not None or chaos is not None:
                raise NotImplementedError(
                    "stream= does not compose with recovery/chaos yet")
            if telemetry is not None:
                raise NotImplementedError(
                    "stream= does not compose with telemetry= yet: the "
                    "metric rows are not part of the window plan (the "
                    "host-side prefetch/overlap SPANS still fire — see "
                    "repro.obs.trace)")
            if stream.resident > self.cfg.num_shards:
                raise ValueError(
                    f"Stream(resident={stream.resident}) exceeds the "
                    f"client count ({self.cfg.num_shards}); resident is "
                    "the ON-DEVICE subset size and must be <= the number "
                    "of clients — lower resident, or raise the client "
                    "count")
        if fed is not None and refresh_every and self.cfg.method == "fsgld":
            raise NotImplementedError(
                "adaptive refresh does not compose with a non-identity "
                "communication schedule/compression yet: the carried "
                "sids / error-feedback state would reset at every "
                "refresh segment boundary")
        if (snapshot_every or resume) and not snapshot_path:
            raise ValueError(
                "snapshot_every/resume need a snapshot_path directory")
        if telemetry is not None and telemetry.log_every and \
                (snapshot_every or refresh_every):
            raise NotImplementedError(
                "Telemetry.log_every does not compose with "
                "snapshot_every/refresh_every: pick ONE segmentation "
                "driver (progress events already fire at snapshot/"
                "refresh segment boundaries)")
        if snapshot_path and refresh_every:
            raise NotImplementedError(
                "snapshots do not compose with adaptive refresh yet: the "
                "refreshed surrogate bank is not part of the snapshot "
                "payload")
        if self.dynamics == "sghmc":
            if refresh_every:
                raise NotImplementedError(
                    "adaptive refresh is not wired for sghmc dynamics")
            from repro.core.sghmc import init_momentum
            # zero momenta in theta0's structure — per-chain when stacked,
            # broadcast with theta0 otherwise (same expression either way)
            theta0 = (theta0, init_momentum(theta0))
        # the packed layout is built from the PARAMETER pytree alone: the
        # sghmc momenta share its structure (and hence its segment table)
        ex_theta = theta0[0] if self.dynamics == "sghmc" else theta0
        layout = self._layout_for(
            jax.tree.map(lambda t: t[0], ex_theta) if stacked else ex_theta)
        cshard = NamedSharding(self.mesh, self._chain_spec())
        if stacked:
            assert jax.tree.leaves(theta0)[0].shape[0] == n_chains, \
                (jax.tree.leaves(theta0)[0].shape, n_chains)
            # pad chains replicate chain 0's state (their updates are
            # computed and discarded — any finite state works). The
            # unpadded leaves are COPIED: the executor donates its chain
            # operand, and donating the caller's own arrays would delete
            # them under a round-at-a-time driver.
            chains = jax.tree.map(
                lambda t: jnp.concatenate(
                    [t, jnp.broadcast_to(t[:1], (n_total - n_chains,)
                                         + t.shape[1:])])
                if n_total > n_chains else t.copy(), theta0)
        else:
            chains = jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None], (n_total,) + t.shape).copy(), theta0)
        chains = jax.device_put(
            chains, jax.tree.map(lambda _: cshard, chains))
        bank_rt = self.bank
        take = (lambda t: t[:n_chains]) if n_total > n_chains \
            else (lambda t: t)

        # in-scan carries threaded through the executor I/O (so segment
        # boundaries — snapshots, resume — never reset them)
        hw = None
        if recovery is not None:
            # the divergence probe window rides the carry as a (C, W)
            # ring, -inf padded (= empty)
            hw = (jnp.zeros((n_total,), jnp.int32),
                  jnp.full((n_total, recovery.window), -jnp.inf,
                           jnp.float32))
        fedc = None
        # FA-LD routes through the federated round body even with no
        # Federation spec (see _executor) — it needs the fed carry
        use_fed = fed is not None or self.aggregation == "fald"
        if use_fed:
            comp0 = fed.compression if fed is not None else None
            cst0 = None
            if comp0 is not None and not comp0.identity:
                from repro.fed.compress import make_flattener
                th_part = chains[0] if self.dynamics == "sghmc" else chains
                flatten, _, _ = make_flattener(th_part)
                # copy: flatten() can alias the (donated) chains buffer
                ref0 = jnp.array(flatten(th_part), copy=True)
                cst0 = (ref0, jnp.zeros_like(ref0))
                if comp0.use_dual:
                    # dual-leg error feedback rides a third carry slot
                    cst0 = cst0 + (jnp.zeros_like(ref0),)
            fedc = (jnp.zeros((n_total,), jnp.int32), cst0)

        if stream is not None:
            return self._run_streamed(
                key, chains, num_rounds, stream=stream,
                n_chains=n_chains, n_total=n_total, reassign=reassign,
                collect_every=collect_every, collect=collect,
                layout=layout, federation=fed, fedc=fedc, take=take)

        typed_key = hasattr(jax.dtypes, "prng_key") and jnp.issubdtype(
            key.dtype, jax.dtypes.prng_key)

        def snap_payload(trace_now):
            """The FULL scan carry, real-chain rows only (mesh padding is
            reconstructed on load): everything a resumed run needs to be
            bitwise identical to an uninterrupted one."""
            p = {"chains": jax.tree.map(take, chains),
                 "key": jax.random.key_data(key) if typed_key else key}
            if fedc is not None:
                p["sids"] = fedc[0][:n_chains]
                if fedc[1] is not None:
                    p["ref"] = fedc[1][0][:n_chains]
                    p["err"] = fedc[1][1][:n_chains]
                    if len(fedc[1]) == 3:
                        p["derr"] = fedc[1][2][:n_chains]
            if hw is not None:
                p["word"] = hw[0][:n_chains]
                p["lp_ref"] = hw[1][:n_chains]
            if collect:
                p["trace"] = trace_now
            return p

        def repad(t, fill=None):
            t = jnp.asarray(t)
            if n_total == n_chains:
                return t
            tail = (jnp.broadcast_to(t[:1], (n_total - n_chains,)
                                     + t.shape[1:])
                    if fill is None else
                    jnp.full((n_total - n_chains,) + t.shape[1:], fill,
                             t.dtype))
            return jnp.concatenate([t, tail])

        out = []
        r_start = 0
        if resume:
            from repro.checkpoint.snapshot import latest_snapshot
            th_like = (jax.tree.map(take, chains)[0]
                       if self.dynamics == "sghmc"
                       else jax.tree.map(take, chains))
            payload, r_start = latest_snapshot(snapshot_path,
                                               snap_payload(th_like))
            if payload is None:
                r_start = 0       # nothing to resume: fresh run
            else:
                chains = jax.tree.map(repad, payload["chains"])
                chains = jax.device_put(
                    chains, jax.tree.map(lambda _: cshard, chains))
                k = jnp.asarray(payload["key"])
                key = jax.random.wrap_key_data(k) if typed_key else k
                if fedc is not None:
                    cst0 = None
                    if fedc[1] is not None:
                        cst0 = (repad(payload["ref"]),
                                repad(payload["err"]))
                        if len(fedc[1]) == 3:
                            cst0 = cst0 + (repad(payload["derr"]),)
                    fedc = (repad(jnp.asarray(payload["sids"],
                                              jnp.int32), fill=0), cst0)
                if hw is not None:
                    hw = (repad(jnp.asarray(payload["word"], jnp.int32),
                                fill=0),
                          repad(jnp.asarray(payload["lp_ref"],
                                            jnp.float32), fill=-jnp.inf))
                if collect:
                    out = [jax.tree.map(jnp.asarray, payload["trace"])]

        refresh_mode = bool(refresh_every) and self.cfg.method == "fsgld"
        tel_seg = (telemetry.log_every if telemetry is not None
                   else None)
        seg_len = (snapshot_every if snapshot_every
                   else (refresh_every if refresh_mode
                         else (tel_seg or num_rounds)))
        tel_rows = []
        r0 = r_start
        while r0 < num_rounds:
            if refresh_mode and r0 > 0:
                # refresh boundary (r0 is a refresh_every multiple)
                if self.bank is None or self.bank.kind != "diag":
                    # refresh_bank(_mesh) fits DIAG banks over flat-vector
                    # params (same limit as the legacy path); swapping the
                    # bank kind under a specialized round fn would corrupt
                    # the kernel path silently — refuse loudly instead.
                    raise NotImplementedError(
                        "adaptive refresh supports flat-parameter 'diag' "
                        f"banks only (got {getattr(self.bank, 'kind', None)!r})")
                center = jax.tree.map(
                    lambda t: t[:n_chains].mean(0), chains)
                with obs_trace.span("engine.refresh", round=int(r0)):
                    bank_rt = self.refresh(center)
            seg = min(seg_len, num_rounds - r0)
            execute = self._executor(
                num_rounds=seg, n_chains=n_chains, n_total=n_total,
                reassign=reassign, collect=collect,
                collect_every=collect_every, layout=layout,
                federation=fed, recovery=recovery, chaos=chaos,
                telemetry=telemetry)
            t_seg = time.monotonic()
            with obs_trace.span("engine.segment", r0=int(r0),
                                rounds=int(seg)):
                outs = execute(
                    key, chains, self._data(), bank_rt,
                    jnp.asarray(r0, jnp.int32), fedc, hw)
            if telemetry is not None:
                chains, trace, key, fedc, hw, mrow = outs
                # the device_get syncs the segment — one host sync per
                # segment boundary, where snapshot writers sync anyway
                row = {k: np.asarray(jax.device_get(v))[:n_chains]
                       for k, v in mrow.items()}
                tel_rows.append(row)
            else:
                chains, trace, key, fedc, hw = outs
            if collect:
                out.append(trace)
            r0 += seg
            if telemetry is not None and obs_trace.enabled():
                dt = time.monotonic() - t_seg
                steps = seg * self.cfg.local_updates * n_chains
                obs_trace.event(
                    "engine.progress", round=int(r0),
                    rounds=int(num_rounds), seconds=round(dt, 6),
                    steps_per_s=round(steps / max(dt, 1e-9), 3),
                    **{k: round(float(v.mean()), 6)
                       for k, v in row.items()})
            if snapshot_every:
                from repro.checkpoint.snapshot import save_snapshot
                trace_now = None
                if collect:
                    sl = [jax.tree.map(take, t) for t in out]
                    trace_now = (sl[0] if len(sl) == 1 else jax.tree.map(
                        lambda *xs: jnp.concatenate(xs, 1), *sl))
                save_snapshot(snapshot_path, snap_payload(trace_now),
                              rounds_done=r0)
        if not collect:
            res = jax.tree.map(take, chains)
        else:
            out = [jax.tree.map(take, t) for t in out]
            res = (out[0] if len(out) == 1 else
                   jax.tree.map(lambda *xs: jnp.concatenate(xs, 1), *out))
        frame = None
        if telemetry is not None:
            # per-segment (C, seg) rows -> one round-major (R, C) frame
            frame = MetricsFrame(
                {k: np.concatenate([r[k] for r in tel_rows],
                                   axis=1).T.astype(np.float32)
                 for k in tel_rows[0]}) if tel_rows else MetricsFrame(
                {n: np.zeros((0, n_chains), np.float32)
                 for n in telemetry.names})
        if recovery is None:
            return res if frame is None else (res, frame)
        lp_ref = None
        if recovery.use_detector:
            # surface the reduced per-chain reference (the same
            # nearest-rank quantile the in-scan detector compares
            # against), not the raw probe ring
            q_idx = min(recovery.window - 1,
                        int(recovery.quantile * (recovery.window - 1)))
            lp_ref = jax.device_get(
                jnp.sort(hw[1][:n_chains], axis=1)[:, q_idx])
        health = RunHealth(
            word=jax.device_get(hw[0])[:n_chains],
            policy=recovery.policy,
            lp_ref=lp_ref)
        return (res, health) if frame is None else (res, health, frame)

    # -- streamed client axis ---------------------------------------------

    def _run_streamed(self, key, chains, num_rounds, *, stream, n_chains,
                      n_total, reassign, collect_every, collect, layout,
                      federation, fedc, take):
        """Streamed-window loop: plan the resident sets from the RNG
        chain, then for each fixed-length window dispatch the scan
        segment (async) and — while the device runs it — build and stage
        the NEXT window's resident buffers (double-buffered host
        prefetch; ``Stream(prefetch=False)`` serializes for A/B timing).

        Fault-free streamed runs are bitwise identical to the resident
        path: the carry (key, chain states, fed carry) threads through
        the same executor I/O that already makes snapshot segmentation
        invisible, and every resident-window lookup — shard rows, sizes,
        probs, surrogate rows — is a gather of the exact values the
        resident path reads."""
        from repro.fed import schedule as fsched
        S = self.cfg.num_shards
        use_fed = federation is not None or self.aggregation == "fald"
        sids_rn = fsched.replay_sids(
            key, num_rounds=num_rounds, n_chains=n_chains, num_shards=S,
            federated=use_fed,
            sched=(federation.schedule if federation is not None
                   else None),
            reassign=reassign)
        windows = fsched.plan_stream(sids_rn, resident=stream.resident,
                                     window=stream.window)
        sizes_np = np.asarray(np.asarray(self.scheme.sizes), np.int64)
        probs_np = self.scheme.probs_array()
        bank = self.bank

        def stage(win):
            """Host-build one window's device operands. Every transfer
            below is async (jax dispatches device_put/gathers without
            blocking), so calling this right after a segment dispatch
            overlaps the staging with the running scan."""
            ids = win.resident_ids          # (K,) sorted int32, padded
            data = self._client_rows(ids)
            # int->f32 via the SAME conversions the resident arrays take
            # (ShardScheme.as_arrays / sizes_array), so each (K,) row is
            # bitwise the resident table's row
            sp = (jnp.asarray(sizes_np[ids].astype(np.int32)),
                  jnp.asarray(sizes_np[ids].astype(np.float32)),
                  jnp.asarray(probs_np[ids]))
            bnk = None
            if bank is not None:
                idx = jnp.asarray(ids)
                row = lambda a: jnp.asarray(a)[idx]  # noqa: E731
                # resident-row bank: per-shard rows gathered, the global
                # product Gaussian carried through UNTOUCHED (it is a
                # sum over all S shards, computed once at fit time)
                bnk = SurrogateBank(jax.tree.map(row, bank.means),
                                    jax.tree.map(row, bank.precs),
                                    bank.global_, bank.kind)
            return data, bnk, jnp.asarray(ids), sp

        hw = None
        out = []
        t_run = time.monotonic()

        def timed_stage(idx):
            """Stage window ``idx`` under a span; returns (operands,
            host seconds spent staging) — after the FIRST window every
            stage call runs while the device executes the previous
            window's scan, so its span duration IS the prefetch work
            hidden behind compute (``Stream(prefetch=False)`` serializes
            and the same spans become the A/B reference)."""
            t0 = time.monotonic()
            with obs_trace.span("stream.stage", window=idx):
                s = stage(windows[idx])
            return s, time.monotonic() - t0

        staged, first_stage_s = timed_stage(0)
        stage_s = first_stage_s
        for i, win in enumerate(windows):
            execute = self._executor(
                num_rounds=win.length, n_chains=n_chains,
                n_total=n_total, reassign=reassign, collect=collect,
                collect_every=collect_every, layout=layout,
                federation=federation, stream=stream.resident)
            data_k, bank_k, ids_dev, sp_dev = staged
            with obs_trace.span("stream.dispatch", window=i,
                                r0=int(win.r0), rounds=int(win.length)):
                chains, trace, key, fedc, hw = execute(
                    key, chains, data_k, bank_k,
                    jnp.asarray(win.r0, jnp.int32), fedc, hw, ids_dev,
                    sp_dev)
            if i + 1 < len(windows):
                if not stream.prefetch:
                    jax.block_until_ready(chains)   # no overlap: A/B ref
                staged, ds = timed_stage(i + 1)
                stage_s += ds
            if collect:
                out.append(trace)
            if self.stream_hook is not None:
                self.stream_hook(i, win)
        if obs_trace.enabled():
            wall = time.monotonic() - t_run
            hidden = stage_s - first_stage_s  # post-dispatch stages only
            obs_trace.event(
                "stream.prefetch_overlap", windows=len(windows),
                prefetch=bool(stream.prefetch),
                stage_s=round(stage_s, 6), wall_s=round(wall, 6),
                overlap_frac=round(
                    (hidden / max(wall, 1e-9))
                    if stream.prefetch else 0.0, 6))
        if not collect:
            return jax.tree.map(take, chains)
        out = [jax.tree.map(take, t) for t in out]
        return (out[0] if len(out) == 1 else
                jax.tree.map(lambda *xs: jnp.concatenate(xs, 1), *out))

    # -- model-axis work: shard-parallel surrogate refresh ----------------

    def refresh(self, theta: PyTree) -> SurrogateBank:
        """Adaptive surrogate refresh at ``theta`` with the client-shard
        axis S split over the MODEL mesh axis (each model group runs the
        Fisher/gradient pass for its subset of clients, results gathered
        by the shard_map output spec). Same math as
        ``federated.refresh_bank``."""
        return refresh_bank_mesh(self.log_lik_fn, self._data(), theta,
                                 self.mesh, sizes=self.scheme.sizes)


def refresh_bank_mesh(log_lik_fn: LogLikFn, shard_data: PyTree,
                      theta: jax.Array, mesh, *, sizes=None,
                      jitter: float = 1e-3, batch: int = 256
                      ) -> SurrogateBank:
    """``federated.refresh_bank`` parallelised over the mesh 'model' axis:
    per-client score sums + centered Fishers are embarrassingly parallel
    over clients, so the S axis shards over 'model' (requires S % |model|
    == 0; the 1x1 host mesh degenerates to the serial pass). Ragged
    clients reduce over their live prefix only."""
    leaf = jax.tree.leaves(shard_data)[0]
    S, max_n = leaf.shape[0], leaf.shape[1]
    sizes = (max_n,) * S if sizes is None else tuple(sizes)
    n_arr = jnp.asarray(sizes, jnp.float32)
    m_size = mesh.shape["model"]
    assert S % m_size == 0, (S, m_size)

    def one_shard(data_s, n_s):
        # Per-example scores in BATCHED gradient passes: each lax.map step
        # vmaps grad over a whole chunk of examples (gathered by index)
        # instead of a dynamic_slice-of-1 per example. Index chunks pad up
        # to a multiple of `batch` with clamped gathers; masking stays a
        # where(), not live*g: pad rows may hold NaN by design and
        # 0 * NaN == NaN would poison the reduction.
        def gpair(i):
            item = jax.tree.map(lambda d: d[i][None], data_s)
            g = jax.grad(log_lik_fn)(theta, item)
            g = jnp.where(i < n_s, g, jnp.zeros_like(g))
            return g, g * g

        # tail indices >= max_n gather clamped rows but always fail the
        # i < n_s mask (n_s <= max_n), so they contribute exact zeros.
        nb = -(-max_n // batch)
        idx = jnp.arange(nb * batch)
        g, g2 = jax.lax.map(jax.vmap(gpair), idx.reshape(nb, batch))
        # flatten and trim to max_n before reducing: the reduction sees
        # the same (max_n, ...) operand as the serial refresh pass, so the
        # partial-sum grouping (and hence rounding) is unchanged
        gsum = g.reshape((-1,) + g.shape[2:])[:max_n].sum(0)
        centered = (g2.reshape((-1,) + g2.shape[2:])[:max_n].sum(0)
                    - gsum * gsum / n_s)
        return gsum, centered

    def block(data_blk, n_blk):
        return jax.vmap(one_shard)(data_blk, n_blk)

    b, fisher = jax.jit(shard_map(
        block, mesh=mesh,
        in_specs=(P("model"), P("model")),
        out_specs=(P("model"), P("model")),
        check_rep=False))(shard_data, n_arr)
    precs = jnp.maximum(fisher, 0.0) + jitter
    mus = theta[None] + b / precs
    return make_bank(mus, precs, "diag")
