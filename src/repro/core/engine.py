"""Mesh-parallel FSGLD chain runtime (the production multi-chain engine).

The paper's parallel regime (Ahn et al.-style parallel chains; the FA-LD
follow-ups in PAPERS.md) needs MANY posterior chains resident on MANY
clients at once. The simulator in ``core/federated.py`` ran chains with a
single-host ``vmap``; this module replaces that execution path with a
``shard_map`` executor over the (``data``, ``model``) mesh from
``launch/mesh.py``:

  * ``data``  — the CHAIN axis. Chains are sharded over it; each data group
    runs its chain block locally (vmapped inside the block, so the 1x1 host
    mesh is bit-identical to the legacy vmap path).
  * ``model`` — SHARD-parallel surrogate work. The bank refresh / Fisher
    fitting pass splits the client-shard axis S over ``model`` and
    all-gathers the fitted naturals (``refresh_bank_mesh``).

Chain->client reassignment:

  * ``categorical`` — the paper's Algorithm 1: i.i.d. s ~ Categorical(f)
    per chain (chains may collide on a client).
  * ``permutation`` — the collision-free SPMD variant (DESIGN.md Sec 4.1):
    every device derives the SAME random permutation from the replicated
    round key inside the shard_map block and slices its own chain block by
    ``axis_index('data')`` — device-side, no host round-trip, and
    bit-identical to the legacy host-side ``permutation(key, S)[:C]``.

Non-uniform clients: shard data leaves are (S, max_n, ...) padded along the
sample axis; ``ShardScheme.sizes`` carries the true N_s and minibatch
indices are drawn in [0, N_s) only, so pad rows are never touched (tests
fill them with NaN to prove it).

The fused Pallas kernel path (``use_kernel=True``) routes the whole chain
block through the CHAIN-BATCHED entry point
(``kernels.ops.fused_update_chains_tree``) — one ``pallas_call`` per leaf
per step for the entire block instead of a vmap over single-chain kernels,
keeping the hot elementwise update one HBM pass per chain-block.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SamplerConfig
from repro.core.sampler import LogLikFn, ShardScheme, make_step_fn
from repro.core.surrogate import SurrogateBank, make_bank
from repro.sharding.rules import chain_spec

PyTree = Any


# ---------------------------------------------------------------------------
# padding non-uniform clients
# ---------------------------------------------------------------------------

def pad_shards(per_shard: list, fill: float = jnp.nan):
    """Stack a list of per-client pytrees (each with leading axis N_s) into
    padded (S, max_n, ...) leaves + the true sizes tuple.

    Float leaves pad with NaN by default: any estimator that touches a
    pad row poisons the chain immediately instead of silently biasing it.
    Integer leaves (token ids) cannot carry NaN — jnp.pad would silently
    coerce it to 0, a VALID id — so they get the dtype's minimum as an
    extreme out-of-range sentinel instead.
    """
    sizes = tuple(int(jax.tree.leaves(t)[0].shape[0]) for t in per_shard)
    max_n = max(sizes)

    def pad_one(leaf):
        pad = [(0, max_n - leaf.shape[0])] + [(0, 0)] * (leaf.ndim - 1)
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            value = fill
        else:
            value = jnp.iinfo(leaf.dtype).min
        return jnp.pad(leaf, pad, constant_values=value)

    stacked = jax.tree.map(
        lambda *leaves: jnp.stack([pad_one(l) for l in leaves]), *per_shard)
    return stacked, sizes


# ---------------------------------------------------------------------------
# per-chain round bodies
# ---------------------------------------------------------------------------

def _make_batch_sampler(cfg: SamplerConfig, scheme: ShardScheme,
                        minibatch: int):
    """Returns sample(k_batch, shard_id, shard_data) -> minibatch pytree.

    DSGLD/FSGLD draw m indices with replacement from the LIVE prefix
    [0, N_s) of the resident shard. Centralized SGLD draws from the virtual
    ragged concatenation of all shards: a global index u in [0, N) maps to
    (shard, offset) via the size prefix sums — for uniform shards this
    selects exactly the elements of the legacy pooled-reshape path.
    """
    sizes = scheme.sizes_array()
    starts = scheme.starts_array()
    ends = jnp.cumsum(sizes)
    total = scheme.total
    m = minibatch

    def sample(k_batch, shard_id, shard_data):
        if cfg.method == "sgld":
            u = jax.random.randint(k_batch, (m,), 0, total)
            sh = jnp.searchsorted(ends, u, side="right").astype(jnp.int32)
            off = u - starts[sh]
            return jax.tree.map(lambda d: d[sh, off], shard_data)
        idx = jax.random.randint(k_batch, (m,), 0, sizes[shard_id])
        return jax.tree.map(lambda d: d[shard_id][idx], shard_data)

    return sample


def make_round_fn(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                  scheme: ShardScheme, step_fn, minibatch: int,
                  collect: bool = True):
    """Client-side Update(T, theta_0, s) for ONE chain — the same math as
    the legacy ``FederatedSampler._round`` generalised to ragged shards.
    Returns round(theta, key, shard_id, shard_data, bank_rt)."""
    sample = _make_batch_sampler(cfg, scheme, minibatch)

    def round_fn(theta, key, shard_id, shard_data, bank_rt=None):
        def body(carry, k):
            theta = carry
            k_batch, k_step = jax.random.split(k)
            batch = sample(k_batch, shard_id, shard_data)
            theta = step_fn(theta, k_step, batch, shard_id, minibatch,
                            bank_rt=bank_rt)
            return theta, theta if collect else None

        keys = jax.random.split(key, cfg.local_updates)
        theta, trace = jax.lax.scan(body, theta, keys)
        return theta, trace

    return round_fn


def make_chain_round_fn(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                        scheme: ShardScheme, minibatch: int,
                        bank_kind: Optional[str], collect: bool = True):
    """CHAIN-BATCHED round for the fused-kernel path: gradients are vmapped
    over the local chain block, then the whole block goes through ONE
    chain-batched Pallas update per leaf per step.

    Returns round(thetas, keys, sids, shard_data, bank) operating on
    (C_blk, ...)-stacked chain states.
    """
    from repro.kernels import ops as kops

    sample = _make_batch_sampler(cfg, scheme, minibatch)
    sizes_f, probs_f = scheme.as_arrays()
    grad_fn = jax.grad(log_lik_fn)
    # only FSGLD carries the conducive correction — mirror the gating in
    # make_step_fn's kernel path, else a resident bank would silently add
    # the surrogate term to DSGLD/SGLD updates.
    use_surrogate = cfg.method == "fsgld"
    if not use_surrogate:
        bank_kind = None

    def round_fn(thetas, keys, sids, shard_data, bank=None):
        if not use_surrogate:
            bank = None
        C = keys.shape[0]
        if cfg.method == "sgld":
            scale = jnp.full((C,), scheme.total / minibatch, jnp.float32)
            f_s = jnp.ones((C,), jnp.float32)
        else:
            f_s = probs_f[sids]
            scale = sizes_f[sids] / (f_s * minibatch)

        def body(carry, ks):
            thetas = carry
            kk = jax.vmap(jax.random.split)(ks)       # (C, 2, 2)
            k_batch, k_step = kk[:, 0], kk[:, 1]
            batches = jax.vmap(
                lambda k, s: sample(k, s, shard_data))(k_batch, sids)
            glls = jax.vmap(grad_fn)(thetas, batches)
            thetas = kops.fused_update_chains_tree(
                thetas, glls, k_step, h=cfg.step_size, scale=scale,
                f_s=f_s, prior_prec=cfg.prior_precision, alpha=cfg.alpha,
                temperature=cfg.temperature, bank=bank, sids=sids,
                surrogate_kind=bank_kind)
            return thetas, thetas if collect else None

        keys_t = jax.vmap(lambda k: jax.random.split(
            k, cfg.local_updates))(keys)              # (C, T, 2)
        thetas, trace = jax.lax.scan(body, thetas,
                                     jnp.swapaxes(keys_t, 0, 1))
        if collect and trace is not None:
            # (T, C, ...) -> (C, T, ...) to match the vmap-of-scan layout
            trace = jax.tree.map(lambda t: jnp.swapaxes(t, 0, 1), trace)
        return thetas, trace

    return round_fn


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MeshChainEngine:
    """shard_map-based multi-chain FSGLD runtime.

    shard_data: pytree with leaves (S, max_n, ...) — shards padded to the
    longest client; ``sizes`` carries true per-client counts (None =>
    uniform, no padding). ``mesh`` must expose ('data', 'model') axes;
    n_chains must divide by the data-axis size.
    """
    log_lik_fn: LogLikFn
    cfg: SamplerConfig
    shard_data: PyTree
    minibatch: int
    bank: Optional[SurrogateBank] = None
    use_kernel: bool = False
    mesh: Any = None
    sizes: Optional[tuple] = None

    def __post_init__(self):
        if self.mesh is None:
            from repro.launch.mesh import make_host_mesh
            self.mesh = make_host_mesh()
        leaf = jax.tree.leaves(self.shard_data)[0]
        s, max_n = leaf.shape[0], leaf.shape[1]
        assert s == self.cfg.num_shards, (s, self.cfg.num_shards)
        sizes = ((max_n,) * s if self.sizes is None
                 else tuple(int(n) for n in self.sizes))
        assert len(sizes) == s and max(sizes) == max_n, (sizes, max_n)
        self.scheme = ShardScheme(sizes=sizes, probs=self.cfg.probs())
        self.step_fn = make_step_fn(self.log_lik_fn, self.cfg, self.scheme,
                                    self.bank, use_kernel=False)
        self._vrounds = {}

    # -- executors ---------------------------------------------------------

    def _chain_spec(self):
        return chain_spec()

    def _vround(self, collect: bool):
        """jit(shard_map(...)) executor for one communication round, built
        lazily per collect mode and cached."""
        key = (collect, self.use_kernel)
        if key in self._vrounds:
            return self._vrounds[key]

        if self.use_kernel:
            chain_round = make_chain_round_fn(
                self.log_lik_fn, self.cfg, self.scheme, self.minibatch,
                self.bank.kind if self.bank is not None else None,
                collect=collect)

            def block(chains, keys, sids, shard_data, bank_rt):
                return chain_round(chains, keys, sids, shard_data, bank_rt)
        else:
            round_fn = make_round_fn(
                self.log_lik_fn, self.cfg, self.scheme, self.step_fn,
                self.minibatch, collect=collect)

            def block(chains, keys, sids, shard_data, bank_rt):
                return jax.vmap(round_fn,
                                in_axes=(0, 0, 0, None, None))(
                    chains, keys, sids, shard_data, bank_rt)

        cspec = self._chain_spec()
        out_specs = (cspec, cspec if collect else None)
        mapped = shard_map(
            block, mesh=self.mesh,
            in_specs=(cspec, cspec, cspec, P(), P()),
            out_specs=out_specs, check_rep=False)
        fn = jax.jit(mapped)
        self._vrounds[key] = fn
        return fn

    def _permute_sids(self, k_assign: jax.Array, n_chains: int):
        """Collision-free reassignment, computed SPMD: every data group
        derives the same permutation of [0, S) from the replicated round
        key and takes the slice owned by its chain block. Equals the
        host-side ``permutation(k, S)[:n_chains]`` bitwise."""
        S = self.cfg.num_shards
        assert n_chains <= S, (n_chains, S)
        per = n_chains // self.mesh.shape["data"]

        def block(k):
            i = jax.lax.axis_index("data")
            perm = jax.random.permutation(k[0], S)
            return jax.lax.dynamic_slice(perm, (i * per,), (per,))

        return shard_map(
            block, mesh=self.mesh, in_specs=(P(),),
            out_specs=P("data"), check_rep=False)(k_assign[None])

    # -- server-side loop --------------------------------------------------

    def run(self, key: jax.Array, theta0: PyTree, num_rounds: int, *,
            n_chains: int = 1, reassign: str = "categorical",
            collect_every: int = 1, refresh_every: Optional[int] = None,
            collect: bool = True):
        """Same contract (and same RNG stream) as the legacy
        ``FederatedSampler.run``: returns stacked samples with leading axes
        (n_chains, num_rounds * T_local / collect_every, ...), or the final
        chain states when ``collect=False`` (large-model mode — the trace
        of a billion-parameter posterior does not fit anywhere).
        """
        d_size = self.mesh.shape["data"]
        if n_chains % d_size:
            raise ValueError(
                f"n_chains={n_chains} must divide over the data axis "
                f"({d_size})")
        probs = jnp.asarray(self.cfg.probs())
        S = self.cfg.num_shards
        cshard = NamedSharding(self.mesh, self._chain_spec())
        chains = jax.device_put(
            jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None], (n_chains,) + t.shape).copy(), theta0),
            jax.tree.map(lambda _: cshard, theta0))
        bank_rt = self.bank
        vround = self._vround(collect)
        out = []
        for r in range(num_rounds):
            key, k_assign, k_run = jax.random.split(key, 3)
            if self.cfg.method == "sgld":
                sids = jnp.zeros((n_chains,), jnp.int32)
            elif reassign == "categorical":   # paper Algorithm 1
                sids = jax.random.categorical(
                    k_assign, jnp.log(probs)[None].repeat(n_chains, 0))
            elif reassign == "permutation":   # SPMD variant (DESIGN 4.1)
                sids = self._permute_sids(k_assign, n_chains)
            else:
                raise ValueError(reassign)
            if (refresh_every and self.cfg.method == "fsgld" and r > 0
                    and r % refresh_every == 0):
                if self.bank is None or self.bank.kind != "diag":
                    # refresh_bank(_mesh) fits DIAG banks over flat-vector
                    # params (same limit as the legacy path); swapping the
                    # bank kind under a specialized round fn would corrupt
                    # the kernel path silently — refuse loudly instead.
                    raise NotImplementedError(
                        "adaptive refresh supports flat-parameter 'diag' "
                        f"banks only (got {getattr(self.bank, 'kind', None)!r})")
                center = jax.tree.map(lambda t: t.mean(0), chains)
                bank_rt = self.refresh(center)
            chains, trace = vround(chains, jax.random.split(k_run, n_chains),
                                   sids, self.shard_data, bank_rt)
            if collect:
                out.append(jax.tree.map(lambda t: t[:, ::collect_every],
                                        trace))
        if not collect:
            return chains
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, 1), *out)

    # -- model-axis work: shard-parallel surrogate refresh ----------------

    def refresh(self, theta: PyTree) -> SurrogateBank:
        """Adaptive surrogate refresh at ``theta`` with the client-shard
        axis S split over the MODEL mesh axis (each model group runs the
        Fisher/gradient pass for its subset of clients, results gathered
        by the shard_map output spec). Same math as
        ``federated.refresh_bank``."""
        return refresh_bank_mesh(self.log_lik_fn, self.shard_data, theta,
                                 self.mesh, sizes=self.scheme.sizes)


def refresh_bank_mesh(log_lik_fn: LogLikFn, shard_data: PyTree,
                      theta: jax.Array, mesh, *, sizes=None,
                      jitter: float = 1e-3, batch: int = 256
                      ) -> SurrogateBank:
    """``federated.refresh_bank`` parallelised over the mesh 'model' axis:
    per-client score sums + centered Fishers are embarrassingly parallel
    over clients, so the S axis shards over 'model' (requires S % |model|
    == 0; the 1x1 host mesh degenerates to the serial pass). Ragged
    clients reduce over their live prefix only."""
    leaf = jax.tree.leaves(shard_data)[0]
    S, max_n = leaf.shape[0], leaf.shape[1]
    sizes = (max_n,) * S if sizes is None else tuple(sizes)
    n_arr = jnp.asarray(sizes, jnp.float32)
    m_size = mesh.shape["model"]
    assert S % m_size == 0, (S, m_size)

    def one_shard(data_s, n_s):
        def gpair(i):
            item = jax.tree.map(
                lambda d: jax.lax.dynamic_slice_in_dim(d, i, 1), data_s)
            g = jax.grad(log_lik_fn)(theta, item)
            # where(), not live*g: pad rows may hold NaN by design and
            # 0 * NaN == NaN would poison the reduction.
            g = jnp.where(i < n_s, g, jnp.zeros_like(g))
            return g, g * g

        g, g2 = jax.lax.map(gpair, jnp.arange(max_n), batch_size=batch)
        gsum = g.sum(0)
        centered = g2.sum(0) - gsum * gsum / n_s
        return gsum, centered

    def block(data_blk, n_blk):
        return jax.vmap(one_shard)(data_blk, n_blk)

    b, fisher = jax.jit(shard_map(
        block, mesh=mesh,
        in_specs=(P("model"), P("model")),
        out_specs=(P("model"), P("model")),
        check_rep=False))(shard_data, n_arr)
    precs = jnp.maximum(fisher, 0.0) + jitter
    mus = theta[None] + b / precs
    return make_bank(mus, precs, "diag")
