"""The paper's primary contribution: conducive gradients + FSGLD."""
from repro.core.conducive import (  # noqa: F401
    conducive_gradient,
    conducive_gradient_from_bank,
)
from repro.core.federated import (  # noqa: F401
    FederatedSampler,
    fit_bank_fisher,
    fit_bank_linear,
    refresh_bank,
    fit_bank_from_samples,
    sample_local_likelihood,
)
from repro.core.engine import (  # noqa: F401
    MeshChainEngine,
    make_chain_round_fn,
    make_round_fn,
    pad_shards,
    refresh_bank_mesh,
)
from repro.core.diagnostics import ess, rhat, summarize  # noqa: F401
from repro.core.sghmc import FederatedSGHMC, make_sghmc_step  # noqa: F401
from repro.core.sampler import (  # noqa: F401
    ShardScheme,
    langevin_update,
    make_drift_fn,
    make_step_fn,
    prior_grad,
    tree_randn_like,
)
from repro.core.surrogate import (  # noqa: F401
    Gaussian,
    SurrogateBank,
    analytic_gaussian_likelihood_surrogate,
    fit_gaussian,
    fit_scalar_tree,
    make_bank,
)
