"""The ensemble posterior server: K draws, one prefill, hot-swap banks.

``EnsembleServer`` is the long-running object behind
``repro.api.FSGLD.serve`` / ``repro.launch.serve``: it holds the stacked
(K, ...) posterior draws, answers requests with one shared prefill plus
a per-token decode fan-out (``repro.serve.ensemble``), and between
requests polls its draw-bank directory for fresh draws written by a
still-running sampler (``repro.launch.train --draw-bank``) — the
streaming chain→server path. ``refresh()`` hot-swaps the newest K draws
in WITHOUT restarting the server or touching an in-flight request.

Draw placement: the stacked draw axis rides the mesh 'data' axis
(``repro.sharding.rules.ensemble_shardings``) whenever a mesh is given
and K divides it; otherwise draws replicate (never crash on an uneven
ensemble).
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.models import (encoder_forward, ensemble_decode_step,
                          init_params)
from repro.obs import trace as obs_trace
from repro.models.model import ACT_DTYPE
from repro.serve.ensemble import ensemble_prefill, predictive_stats
from repro.sharding import rules

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One served request: the greedy BMA token stream plus per-token
    uncertainty (all (B, gen); see repro.serve.ensemble for the signal
    definitions). ``n_draws`` records the ensemble size that answered —
    after a hot-swap it may differ from the previous request's."""
    tokens: jax.Array
    mean_logprob: jax.Array
    entropy: jax.Array
    mutual_info: jax.Array
    token_var: jax.Array
    n_draws: int
    prefill_s: float
    decode_s: float


class EnsembleServer:
    """Serve K posterior draws as one Bayesian-model-averaged model.

    Exactly one draw source:
      * ``bank=`` a draw-bank directory (or legacy single-checkpoint
        dir) — the freshest ``n_draws`` are loaded, fingerprint-checked
        against this arch's parameter skeleton, and ``refresh()`` keeps
        tracking the directory;
      * ``draws=`` an already-stacked (K, ...) params pytree;
      * neither — ``n_draws`` fresh inits (shape smoke, no posterior).
    """

    def __init__(self, cfg, *, bank: Optional[str] = None,
                 draws: Optional[PyTree] = None,
                 n_draws: Optional[int] = None, mesh: Any = None,
                 seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.bank = bank
        self.metas: List[Optional[checkpoint.DrawMeta]] = []
        self._like = init_params(cfg, jax.random.PRNGKey(seed))
        self._seen_draws = 0
        if bank is not None:
            if draws is not None:
                raise ValueError("pass bank= or draws=, not both")
            self._want = n_draws
            self.draws = None
            if not self.refresh():
                raise ValueError(f"no draws in bank {bank!r}")
        elif draws is not None:
            self.draws = self._place(draws)
            self.metas = [None] * self.n_draws
        else:
            k = n_draws or 1
            keys = jax.random.split(jax.random.PRNGKey(seed), k)
            self.draws = self._place(jax.tree.map(
                lambda *ls: jnp.stack(ls),
                *[init_params(cfg, kk) for kk in keys]))
            self.metas = [None] * k

    # -- draw management ---------------------------------------------------

    @property
    def n_draws(self) -> int:
        return int(jax.tree.leaves(self.draws)[0].shape[0])

    def _place(self, draws: PyTree) -> PyTree:
        draws = jax.tree.map(jnp.asarray, draws)
        k = int(jax.tree.leaves(draws)[0].shape[0])
        if self.mesh is not None and k % self.mesh.shape[
                rules.ENSEMBLE_AXIS] == 0:
            shardings = rules.ensemble_shardings(draws, self.mesh)
            draws = jax.device_put(draws, shardings)
        return draws

    def refresh(self, *, retries: int = 2,
                backoff_s: float = 0.05) -> bool:
        """Poll the draw bank; when new complete draws appeared since the
        last load, hot-swap the freshest ``n_draws`` in. Returns True when
        the ensemble changed. No-op (False) for non-bank servers.

        Fault tolerance: transient read failures (``OSError``, torn-write
        ``CorruptCheckpointError``) are retried ``retries`` times with
        exponential backoff; refusals (arch/fingerprint mismatch, wholly
        corrupt bank) are not retried. Either way, once an ensemble is
        live a failed refresh keeps it serving (warn + False) — only the
        INITIAL load is allowed to raise."""
        if self.bank is None:
            return False
        avail = len(checkpoint.list_draws(self.bank))
        if avail == 0 and os.path.exists(
                os.path.join(self.bank, "manifest.json")):
            avail = 1  # legacy single-checkpoint fallback: one draw
        if avail == 0 or (avail == self._seen_draws
                          and self.draws is not None):
            return False
        k = self._want
        if k is not None and avail < k:
            k = avail  # sampler still filling the bank: serve what exists
        stacked = metas = None
        last_exc: Optional[Exception] = None
        with obs_trace.span("server.refresh", bank=self.bank, avail=avail):
            for attempt in range(retries + 1):
                try:
                    stacked, metas = checkpoint.load_bank(
                        self.bank, self._like, k=k,
                        expect_arch=self.cfg.name)
                    last_exc = None
                    break
                except (checkpoint.CorruptCheckpointError, OSError) as e:
                    last_exc = e
                    obs_trace.event(
                        "server.refresh_retry", attempt=attempt,
                        retries=retries, error=str(e),
                        backoff_s=(backoff_s * (2 ** attempt)
                                   if attempt < retries else 0.0))
                    if attempt < retries:
                        time.sleep(backoff_s * (2 ** attempt))
                except ValueError as e:  # refusal — retrying cannot help
                    last_exc = e
                    obs_trace.event("server.refresh_refused", error=str(e))
                    break
        if last_exc is not None:
            if self.draws is not None:
                warnings.warn(
                    f"draw-bank refresh failed ({last_exc}); keeping the "
                    f"previous {self.n_draws}-draw ensemble live")
                obs_trace.event(
                    "server.refresh_failed", error=str(last_exc),
                    kept_draws=self.n_draws)
                return False
            raise last_exc
        self.draws = self._place(stacked)
        self.metas = metas
        self._seen_draws = avail
        return True

    # -- serving -----------------------------------------------------------

    def _encoder_inputs(self, key, batch):
        cfg = self.cfg
        if cfg.family == "vlm":
            enc = jax.random.normal(
                key, (batch, cfg.num_patches, cfg.d_model), ACT_DTYPE)
            return enc, enc
        if cfg.family == "audio":
            enc_in = jax.random.normal(
                key, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
            anchor = jax.tree.map(lambda l: l[0], self.draws)
            return enc_in, encoder_forward(anchor, cfg, enc_in)
        return None, None

    def generate(self, prompt: Optional[jax.Array] = None, *,
                 key: Optional[jax.Array] = None, gen: int = 16,
                 batch: int = 4, prompt_len: int = 32) -> ServeResult:
        """Serve one request: greedy decode ``gen`` tokens from the
        ensemble predictive mean. ``prompt`` (B, S) int32, or None to
        draw a random prompt from ``key`` (shape smoke, matching the
        legacy driver). Token 0 comes from the shared anchor prefill;
        ensemble fan-out statistics start at token 1."""
        cfg = self.cfg
        if key is None:
            key = jax.random.PRNGKey(0)
        if prompt is None:
            prompt = jax.random.randint(
                key, (batch, prompt_len), 0, cfg.vocab_size, jnp.int32)
        B, S = prompt.shape
        total = S + gen
        enc_embeds, enc_out = self._encoder_inputs(key, B)

        with obs_trace.span("serve.prefill", batch=B, prompt_len=S,
                            n_draws=self.n_draws):
            t0 = time.time()
            logits0, caches = ensemble_prefill(
                self.draws, cfg, prompt, total, enc_embeds=enc_embeds)
            # token 0: the anchor's logits as a one-draw ensemble (the
            # shared prefill means there is no fan-out to aggregate yet)
            stats = [predictive_stats(logits0[None])]
            prefill_s = time.time() - t0

        if enc_out is not None:
            step = jax.jit(lambda d, c, t, p: ensemble_decode_step(
                d, cfg, c, t, p, enc_out=enc_out))
        else:
            step = jax.jit(lambda d, c, t, p: ensemble_decode_step(
                d, cfg, c, t, p))
        with obs_trace.span("serve.decode", batch=B, gen=gen,
                            n_draws=self.n_draws):
            t0 = time.time()
            tok = stats[0].token[:, None]
            for t in range(S, total - 1):
                pos = jnp.full((B,), t, jnp.int32)
                logits_k, caches = step(self.draws, caches, tok, pos)
                stats.append(predictive_stats(logits_k))
                tok = stats[-1].token[:, None]
            decode_s = time.time() - t0
        if obs_trace.enabled():
            obs_trace.event(
                "serve.request", batch=B, prompt_len=S, gen=gen,
                n_draws=self.n_draws,
                prefill_s=round(prefill_s, 6), decode_s=round(decode_s, 6),
                tokens_per_s=round(
                    B * max(gen - 1, 1) / max(decode_s, 1e-9), 3))

        col = lambda f: jnp.stack(  # noqa: E731
            [f(s) for s in stats], axis=1)
        return ServeResult(
            tokens=col(lambda s: s.token),
            mean_logprob=col(lambda s: s.mean_logprob),
            entropy=col(lambda s: s.entropy),
            mutual_info=col(lambda s: s.mutual_info),
            token_var=col(lambda s: s.token_var),
            n_draws=self.n_draws, prefill_s=prefill_s,
            decode_s=decode_s)
