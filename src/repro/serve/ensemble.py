"""Bayesian-model-averaging ensemble math for posterior serving.

The sampler's product is the POSTERIOR, not a point estimate; serving it
means serving K draws theta_1..theta_K as one model::

    p(y | x) ≈ (1/K) Σ_k p(y | x, theta_k)

Layout contract (one request):

  * prefill runs ONCE, on the anchor draw (k=0) — one forward pass fills
    one decode cache, which :func:`repro.models.broadcast_cache` fans
    out to a (K, ...) cache stack whose prompt region is shared across
    draws by construction;
  * decode fans out per token: ``ensemble_decode_step`` vmaps the
    single-token step over the draw axis with a SHARED token stream, and
    :func:`predictive_stats` folds the (K, B, V) logits into the
    predictive mean plus per-token uncertainty;
  * the next token is argmax of the predictive MEAN — the served
    sequence is one stream, ensemble-averaged per token.

With K=1 every aggregate is the identity (mean over one draw) and the
argmax is taken over a per-row monotone shift of the raw logits, so
single-draw ensemble serving is bit-identical to the plain
prefill+decode path (tests/test_serving.py pins this).

Uncertainty signals per generated token (all (B,) per step, fp32):

  * ``mean_logprob`` — log predictive-mean probability of the emitted
    token (the BMA confidence; feeds the NLL calibration gate);
  * ``entropy``      — predictive entropy H[p̄] (total uncertainty);
  * ``mutual_info``  — H[p̄] − mean_k H[p_k] (BALD): the epistemic part,
    i.e. the draws DISAGREEING. Exactly 0 at K=1 — uncertainty the
    single-draw path cannot see;
  * ``token_var``    — Var_k p_k(token): per-token draw variance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import broadcast_cache, prefill_with_cache

PyTree = Any


@dataclasses.dataclass(frozen=True)
class StepStats:
    """Predictive aggregate of one decode step (leaves (B,))."""
    token: jax.Array
    mean_logprob: jax.Array
    entropy: jax.Array
    mutual_info: jax.Array
    token_var: jax.Array


def predictive_stats(logits_k: jax.Array) -> StepStats:
    """(K, B, V) per-draw logits -> next token from the predictive mean
    plus per-token uncertainty. All math in fp32; the mean over draws is
    computed in log space (logsumexp − log K) so huge vocabularies do
    not underflow."""
    K = logits_k.shape[0]
    logp = jax.nn.log_softmax(logits_k.astype(jnp.float32), axis=-1)
    mean_logp = jax.nn.logsumexp(logp, axis=0) - jnp.log(float(K))
    token = jnp.argmax(mean_logp, axis=-1).astype(jnp.int32)     # (B,)
    probs = jnp.exp(logp)                                        # (K,B,V)
    mean_probs = jnp.exp(mean_logp)                              # (B,V)
    h_pred = -jnp.sum(mean_probs * mean_logp, axis=-1)
    h_each = -jnp.sum(probs * logp, axis=-1)                     # (K,B)
    idx = jnp.broadcast_to(token[None, :, None], (K,) + token.shape + (1,))
    p_tok = jnp.take_along_axis(probs, idx, axis=-1)[..., 0]     # (K,B)
    conf = jnp.take_along_axis(mean_logp, token[:, None], axis=-1)[:, 0]
    return StepStats(token=token, mean_logprob=conf, entropy=h_pred,
                     mutual_info=h_pred - h_each.mean(0),
                     token_var=p_tok.var(0))


def ensemble_prefill(draws: PyTree, cfg, prompt: jax.Array,
                     cache_len: int, *,
                     enc_embeds: Optional[jax.Array] = None):
    """ONE prefill for the whole ensemble: the anchor draw (k=0) runs the
    full prompt forward and its decode cache is broadcast to all K draws
    (the prompt region is shared; decode writes diverge per draw).
    Returns (anchor last-token logits (B, V), caches with (K, ...)
    leaves). The first generated token therefore comes from the anchor —
    the price of prefilling once — and ensemble uncertainty starts at
    the second token; K=1 is exactly the legacy single-draw path."""
    k = jax.tree.leaves(draws)[0].shape[0]
    anchor = jax.tree.map(lambda l: l[0], draws)
    kw = {} if enc_embeds is None else {"enc_embeds": enc_embeds}
    logits, cache = prefill_with_cache(anchor, cfg, prompt, cache_len,
                                       **kw)
    return logits, broadcast_cache(cache, k)
