"""Ensemble posterior serving (Bayesian model averaging over K draws)."""
from repro.serve.ensemble import (  # noqa: F401
    StepStats,
    ensemble_prefill,
    predictive_stats,
)
from repro.serve.server import EnsembleServer, ServeResult  # noqa: F401
