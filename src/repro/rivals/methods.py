"""The facade's method table: every sampler the ``method=`` axis names.

One row per algorithm the repo can race head-to-head. ``cfg_method`` is
the :class:`repro.configs.base.SamplerConfig` drift family the method
lowers to (FA-LD shares DSGLD's unbiased local-gradient drift — what
distinguishes it is the server-side averaging and the noise
calibration, which live in the engine's ``aggregation`` axis), and
``aggregation`` is the ``MeshChainEngine`` aggregation mode.
"""
from __future__ import annotations

import dataclasses
import difflib


@dataclasses.dataclass(frozen=True)
class Method:
    """One facade-level sampling method.

    name: the facade/CLI spelling (``api.FSGLD(method=name)``).
    cfg_method: the SamplerConfig drift family it lowers to.
    aggregation: the engine aggregation mode ('none' | 'fald').
    needs_surrogate: whether the method carries the conducive-gradient
      correction (FSGLD only — the surrogate bank is meaningless for
      the others and is dropped).
    paper: the reference the implementation follows.
    """
    name: str
    cfg_method: str
    aggregation: str = "none"
    needs_surrogate: bool = False
    paper: str = ""
    description: str = ""


METHODS = {
    "sgld": Method(
        name="sgld", cfg_method="sgld",
        paper="Welling & Teh 2011",
        description="centralized SGLD over the pooled data (baseline)"),
    "dsgld": Method(
        name="dsgld", cfg_method="dsgld",
        paper="Ahn et al. 2014",
        description="distributed SGLD: chains hop clients, local "
                    "unbiased gradients, no correction"),
    "fsgld": Method(
        name="fsgld", cfg_method="fsgld", needs_surrogate=True,
        paper="arXiv:2004.11231",
        description="DSGLD + conducive-gradient surrogate correction "
                    "(the source paper)"),
    "fald": Method(
        name="fald", cfg_method="dsgld", aggregation="fald",
        paper="arXiv:2112.05120",
        description="federated averaging Langevin: server-averaged "
                    "clients, noise amplified sqrt(C) per client"),
}


def method_names() -> tuple:
    """All method names, stable order (benchmarks/CI iterate this)."""
    return tuple(METHODS)


def get_method(name: str) -> Method:
    """Resolve a method name, with an actionable error on a miss."""
    try:
        return METHODS[name]
    except (KeyError, TypeError):
        near = difflib.get_close_matches(str(name), method_names(), n=1)
        hint = f" (did you mean {near[0]!r}?)" if near else ""
        raise ValueError(
            f"unknown sampling method {name!r}{hint}; available: "
            f"{', '.join(method_names())}") from None
