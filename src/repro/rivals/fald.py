"""Pure-JAX FA-LD oracle: the reference every executor cell must match.

FA-LD (Deng et al., arXiv:2112.05120) runs C Langevin clients for T
local steps between communication rounds; at each round the server
averages the participating clients' iterates and broadcasts the average
back. Each client injects noise at ``temperature * C`` so the AVERAGED
iterate — whose injected-noise variance is the per-client variance / C —
targets the configured temperature (the paper's ``sqrt(2 h N / p_c)``
client noise with uniform weights p_c = 1/C).

This module is the bitwise regression reference for
``MeshChainEngine(aggregation='fald')``, the same role
``FederatedSampler.run_vmap`` plays for the plain engine: a host-side
loop over rounds whose per-round RNG derivation, reassignment draw,
schedule masks, compression operators, and averaging expression are the
SAME jnp expressions the engine's scanned ``fed_round_body`` traces
(the schedule/compression helpers are imported, not re-implemented), so
on the host mesh — where the engine's chain block is the whole chain
axis and its masked ``psum`` is an identity — engine and oracle agree
bit for bit, on every executor. Fault-free runs only (no chaos/recovery
mirroring): the parity tests pin the engine to the oracle, and the
chaos suite pins the engine's fault paths to its own fault-free runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig
from repro.core.engine import _perm_sids_slice, make_round_fn
from repro.core.sampler import LogLikFn, ShardScheme, make_step_fn
from repro.fed import schedule as fsched
from repro.fed.compress import (Compression, make_compressor,
                                make_flattener)
from repro.fed.registry import get_scenario

PyTree = Any


def fald_run_vmap(log_lik_fn: LogLikFn, cfg: SamplerConfig,
                  shard_data: PyTree, minibatch: int, key: jax.Array,
                  theta0: PyTree, num_rounds: int, *, n_chains: int,
                  bank=None, reassign: str = "categorical",
                  collect_every: int = 1, federation=None,
                  sizes: Optional[tuple] = None,
                  use_kernel: bool = False) -> PyTree:
    """Host-loop FA-LD reference run; returns the stacked trace with
    leading axes (n_chains, num_rounds * T_local / collect_every, ...).

    ``federation`` (None | registry name | Federation) supplies the
    communication schedule and compression exactly as the engine takes
    them; None means every-round exact averaging. ``use_kernel``
    selects the fused-update step (what the per_leaf/packed executors
    run) so every executor cell has a matching oracle flavor.
    """
    leaf = jax.tree.leaves(shard_data)[0]
    S, max_n = leaf.shape[0], leaf.shape[1]
    assert S == cfg.num_shards, (S, cfg.num_shards)
    sizes = (max_n,) * S if sizes is None else tuple(sizes)
    scheme = ShardScheme(sizes=sizes, probs=cfg.probs())
    fed = get_scenario(federation) if federation is not None else None
    sched = fed.schedule if fed is not None else fsched.CommSchedule()
    comp = fed.compression if fed is not None else Compression()
    use_part = sched.participation < 1.0
    use_strag = sched.straggler_prob > 0.0
    use_comp = not comp.identity
    use_primal, use_dual = comp.use_primal, comp.use_dual

    # FA-LD noise calibration: per-client temperature * C (see module
    # docstring) — the ONLY config difference vs a DSGLD client
    cfg_dyn = dataclasses.replace(
        cfg, temperature=cfg.temperature * n_chains)
    step_fn = make_step_fn(log_lik_fn, cfg_dyn, scheme, bank,
                           use_kernel=use_kernel)
    one_chain = make_round_fn(log_lik_fn, cfg_dyn, scheme, step_fn,
                              minibatch, collect=True)
    vround = jax.vmap(one_chain, in_axes=(0, 0, 0, None, None))

    chains = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (n_chains,) + t.shape).copy(),
        theta0)
    flatten, unflatten, dim = make_flattener(chains)
    compress = make_compressor(comp, dim) if use_comp else None
    probs = jnp.asarray(cfg.probs())
    sids = jnp.zeros((n_chains,), jnp.int32)
    if use_comp:
        ref = jnp.array(flatten(chains), copy=True)
        err = jnp.zeros_like(ref)
        derr = jnp.zeros_like(ref) if use_dual else None

    out = []
    for r in range(num_rounds):
        key, k_assign, k_run, k_fed = jax.random.split(key, 4)
        if cfg.method == "sgld":
            new_sids = jnp.zeros((n_chains,), jnp.int32)
        elif reassign == "categorical":
            new_sids = jax.random.categorical(
                k_assign, jnp.log(probs)[None].repeat(n_chains, 0))
        else:
            new_sids = _perm_sids_slice(k_assign, S, 0, n_chains,
                                        n_chains)
        comm = (r % sched.delay) == 0
        if use_part:
            exch = comm & fsched.participation_mask(
                sched, jax.random.fold_in(k_fed, 0), r, n_chains)
        else:
            exch = jnp.broadcast_to(jnp.asarray(comm), (n_chains,))
        sids = jnp.where(exch, new_sids.astype(jnp.int32), sids)
        if comm:
            # the exchange, mirroring the engine's do_exchange: primal
            # leg -> server average -> dual leg, writes masked per chain
            flat = flatten(chains)
            if use_primal:
                upd = flat - ref + err
                dhat = compress(upd, jax.random.fold_in(k_fed, 1))
                m_flat = ref + dhat
                err_new = (upd - dhat if comp.error_feedback
                           else jnp.zeros_like(upd))
            else:
                m_flat = flat
            w = exch
            cnt = jnp.sum(w.astype(jnp.float32))
            tot = jnp.sum(jnp.where(w[:, None], m_flat, 0.0), axis=0)
            avg = tot / jnp.maximum(cnt, 1.0)
            m_flat = jnp.where(w[:, None], avg[None], m_flat)
            if use_dual:
                dupd = m_flat - ref + derr
                dd = compress(dupd, jax.random.fold_in(k_fed, 3))
                v_new = ref + dd
                derr_new = (dupd - dd if comp.error_feedback
                            else jnp.zeros_like(dupd))
            else:
                v_new = m_flat
            if use_comp:
                mm = exch[:, None]
                ref = jnp.where(mm, v_new, ref)
                if use_primal:
                    err = jnp.where(mm, err_new, err)
                if use_dual:
                    derr = jnp.where(mm, derr_new, derr)
            th_srv = unflatten(v_new)
            chains = jax.tree.map(
                lambda srv, old: jnp.where(
                    exch.reshape((n_chains,) + (1,) * (old.ndim - 1)),
                    srv, old),
                th_srv, chains)
        pre = chains
        keys = jax.random.split(k_run, n_chains)
        chains, trace = vround(chains, keys, sids, shard_data, bank)
        if use_strag:
            strag = fsched.straggler_mask(
                sched, jax.random.fold_in(k_fed, 2), n_chains)

            def keep(new, old):
                m = strag.reshape((n_chains,) + (1,) * (new.ndim - 1))
                return jnp.where(m, old, new)

            chains = jax.tree.map(keep, chains, pre)
            trace = jax.tree.map(
                lambda t, p: jnp.where(
                    strag.reshape((n_chains,) + (1,) * (t.ndim - 1)),
                    p[:, None], t),
                trace, pre)
        out.append(jax.tree.map(lambda t: t[:, ::collect_every], trace))
    return (out[0] if len(out) == 1
            else jax.tree.map(lambda *xs: jnp.concatenate(xs, 1), *out))
