"""Rival federated samplers as first-class engine citizens.

The source paper's claim — conducive gradients let FSGLD survive
delayed, non-IID communication where DSGLD diverges — deserves to be
tested against the literature's direct competitors, not only against
its own baseline:

  * **FA-LD** (Deng et al., "On Convergence of Federated Averaging
    Langevin Dynamics", arXiv:2112.05120) — server-averaged Langevin
    clients with local steps and amplified injected noise. Implemented
    as ``MeshChainEngine(aggregation='fald')`` — the averaging is a
    masked psum INSIDE the scanned round body, so the jaxpr gate (one
    scan, one pallas_call, no pad) holds — with the pure-JAX oracle
    :func:`repro.rivals.fald.fald_run_vmap` every executor cell is
    regression-tested against bitwise.
  * **ELF** (Karagulyan & Richtárik, "ELF: Federated Langevin
    Algorithms with Primal, Dual and Bidirectional Compression",
    arXiv:2303.04622) — compression on the server→client broadcast
    (dual) or both legs (bidir), each with its own error-feedback
    state. Implemented in ``repro.fed.compress`` (``direction=``) and
    surfaced as registry scenarios (``elf-bidir-topk-1%``, ...).

:mod:`repro.rivals.methods` is the facade's method table: every method
name the ``api.FSGLD(method=...)`` axis and ``launch/train.py
--method`` accept, with its engine lowering and paper reference.
``benchmarks/bench_frontier.py`` races them on a shared
convergence-vs-bytes frontier.
"""
from repro.rivals.fald import fald_run_vmap
from repro.rivals.methods import METHODS, Method, get_method

__all__ = ["METHODS", "Method", "get_method", "fald_run_vmap"]
