"""Causal flash-attention Pallas TPU kernel.

This is the TPU-target replacement for the pure-JAX chunked attention in
models/layers.py: the roofline (EXPERIMENTS.md §Perf) shows the remaining
train/prefill HBM term is score/probability traffic that XLA materialises
between the QK^T and PV matmuls — a Pallas kernel keeps the running
(m, l, acc) statistics in VMEM across KV blocks so scores never touch HBM.

Layout: heads are pre-expanded to the query-head count (GQA handled by the
caller, as in layers.chunked_attention) and folded into the grid:

    grid = (B*H, Sq / BLOCK_Q)
    q tile    : (BLOCK_Q, hd)                VMEM
    k, v      : (Sk, hd) for this (b,h)      VMEM (fits <= 8k seq; longer
                sequences tile KV as a third grid dim — documented ext.)
    out tile  : (BLOCK_Q, hd)

Masking supports causal and sliding-window; positions are implicit
(q row = absolute position), matching training/prefill use.

Validated in interpret mode against kernels/ref.py:naive_attention over
shape/dtype sweeps (tests/test_flash_kernel.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int,
                  block_k: int, seq_k: int, causal: bool, window):
    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    hd = q.shape[-1]
    scale = hd ** -0.5
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1), 0)

    nb = seq_k // block_k

    def body(ik, carry):
        m, l, acc = carry
        k_blk = k_ref[0, pl.ds(ik * block_k, block_k), :].astype(
            jnp.float32)
        v_blk = v_ref[0, pl.ds(ik * block_k, block_k), :].astype(
            jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        valid = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            valid &= k_pos <= q_pos
        if window is not None:
            valid &= k_pos > q_pos - window
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)
    if causal:
        # skip KV blocks strictly above the diagonal for this q tile
        nb_eff = jnp.minimum(nb, (iq + 1) * block_q // block_k
                             + (1 if block_q % block_k else 0))
        nb_eff = jnp.maximum(nb_eff, 1)
    else:
        nb_eff = nb
    m, l, acc = jax.lax.fori_loop(0, nb_eff, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window=None, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True
                    ) -> jax.Array:
    """q,k,v: (B, S, H, hd) with H already expanded (GQA: repeat KV heads
    before the call). Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd)
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)

    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    qf, kf, vf = fold(q), fold(k), fold(v)
    grid = (B * H, S // bq)
    kernel = functools.partial(_flash_kernel, block_q=bq, block_k=bk,
                               seq_k=S, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec((1, S, hd), lambda bh, iq: (bh, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda bh, iq: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
