"""Pure-jnp oracle for the fused FSGLD update kernel.

Implements bit-identical math to fsgld_update.py (same counter-based hash,
same Box-Muller) so tests can assert end-to-end equality INCLUDING noise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mix(h: jax.Array) -> jax.Array:
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def gaussian_noise(seed: jax.Array, idx: jax.Array) -> jax.Array:
    seed = seed.astype(jnp.uint32)
    h1 = mix(idx * jnp.uint32(2) + jnp.uint32(1)
             + seed * jnp.uint32(0x9E3779B9))
    h2 = mix(idx * jnp.uint32(2) + seed * jnp.uint32(0x85EBCA77))
    u1 = (h1 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24)) \
        + (0.5 / (1 << 24))
    u2 = (h2 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos((2.0 * jnp.pi) * u2)


def fsgld_update_flat(theta, g, seed, *, h, scale, f_s, prior_prec, alpha,
                      temperature, mu_g=None, mu_s=None, lam_g=None,
                      lam_s=None):
    """Flat-vector oracle. lam_g/lam_s may be scalars ('scalar' structure)
    or vectors ('diag'); mu_* None means plain SGLD/DSGLD (alpha ignored)."""
    theta = theta.astype(jnp.float32)
    g = g.astype(jnp.float32)
    drift = -prior_prec * theta + scale * g
    if mu_g is not None:
        cond = lam_g * (mu_g.astype(jnp.float32) - theta) \
            - (lam_s / f_s) * (mu_s.astype(jnp.float32) - theta)
        drift = drift + alpha * cond
    idx = jnp.arange(theta.shape[0], dtype=jnp.uint32)
    xi = gaussian_noise(jnp.asarray(seed), idx)
    return theta + (h / 2) * drift + jnp.sqrt(h * temperature) * xi
