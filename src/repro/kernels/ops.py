"""jit'd public wrappers around the fused FSGLD update kernel.

`fused_update_tree` applies the kernel leaf-by-leaf over a parameter pytree:
ravel -> pad to (rows, 128) -> pallas_call -> unpad/reshape, with a
deterministic per-leaf seed folded out of a JAX PRNG key. On this CPU
container the kernel runs in interpret mode (the TPU path is identical
modulo `interpret=False`).

`PackedChains` is the single-launch layout (PR 2): every leaf of every
chain lives in ONE chain-major (C * rows_total, 128) buffer, built once
per run by `pack`; per-step updates go through `packed_step`, which issues
exactly one `pallas_call` for the whole chain block using the layout's
static segment table (see kernels/fsgld_update.py). The layout is
MULTI-SEGMENT (PR 4): SGHMC dynamics add a second chain-major momentum
buffer sharing the same segment table, and non-fp32 parameter leaves ride
the fp32 buffer with a per-step `quantize` round-trip back to their
storage dtype — bit-identical to the per-leaf kernel's dtype handling.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.fsgld_update import (LANE, PACK_BLOCK_ROWS, SCALAR_COLS,
                                        fsgld_update_2d, fsgld_update_packed)

PyTree = Any

# CPU container: interpret=True executes the kernel body in Python/XLA-CPU.
# On a real TPU runtime set this to False (same kernel).
INTERPRET = jax.default_backend() != "tpu"


def _pad_2d(vec: jax.Array, block_rows: int):
    n = vec.shape[0]
    per_block = block_rows * LANE
    padded = -(-n // per_block) * per_block
    vec = jnp.pad(vec.astype(jnp.float32), (0, padded - n))
    return vec.reshape(-1, LANE), n


def _scalars_row(h, scale, f_s, prior_prec, alpha, temperature, lam_g,
                 lam_s, friction=0.0) -> jax.Array:
    return jnp.stack([
        jnp.float32(h), jnp.asarray(scale, jnp.float32),
        jnp.asarray(f_s, jnp.float32), jnp.float32(prior_prec),
        jnp.float32(alpha), jnp.float32(temperature),
        jnp.asarray(lam_g, jnp.float32), jnp.asarray(lam_s, jnp.float32),
        jnp.asarray(friction, jnp.float32),
    ]).reshape(1, SCALAR_COLS)


def fused_update_flat(theta: jax.Array, g: jax.Array, seed: jax.Array, *,
                      h, scale, f_s=1.0, prior_prec=0.0, alpha=0.0,
                      temperature=1.0, mu_g=None, mu_s=None, lam_g=None,
                      lam_s=None, momentum=None, friction=0.0,
                      dynamics: str = "langevin", block_rows: int = 256,
                      interpret: Optional[bool] = None):
    """Fused update of one flat vector. Seeds: uint32 scalar.

    ``dynamics='langevin'`` (default) returns theta'; ``'sghmc'`` carries
    the ``momentum`` operand through the SGHMC integrator and returns the
    pair (theta', momentum'). Non-fp32 operands round-trip through fp32
    per step (the kernels compute at fp32 and cast back out).
    """
    interpret = INTERPRET if interpret is None else interpret
    orig_shape, orig_dtype = theta.shape, theta.dtype
    th2, n = _pad_2d(theta.reshape(-1), block_rows)
    g2, _ = _pad_2d(g.reshape(-1), block_rows)
    rows = th2.shape[0]
    br = min(block_rows, rows)
    while rows % br:
        br //= 2

    if mu_g is None:
        variant = "plain"
        kw = {}
        lam_row = (0.0, 0.0)
    elif jnp.ndim(lam_g) == 0:
        variant = "scalar"
        kw = {"mu_g": _pad_2d(mu_g.reshape(-1), block_rows)[0],
              "mu_s": _pad_2d(mu_s.reshape(-1), block_rows)[0]}
        lam_row = (lam_g, lam_s)
    else:
        variant = "diag"
        kw = {"mu_g": _pad_2d(mu_g.reshape(-1), block_rows)[0],
              "mu_s": _pad_2d(mu_s.reshape(-1), block_rows)[0],
              "lam_g": _pad_2d(lam_g.reshape(-1), block_rows)[0],
              "lam_s": _pad_2d(lam_s.reshape(-1), block_rows)[0]}
        lam_row = (0.0, 0.0)
    if dynamics == "sghmc":
        kw["r2d"] = _pad_2d(momentum.reshape(-1), block_rows)[0]

    sc = _scalars_row(h, scale, f_s, prior_prec, alpha, temperature,
                      *lam_row, friction)
    out = fsgld_update_2d(th2, g2, seed.reshape(1).astype(jnp.uint32), sc,
                          variant=variant, dynamics=dynamics,
                          interpret=interpret, block_rows=br, **kw)

    def unpad(o, dt):
        return o.reshape(-1)[:n].reshape(orig_shape).astype(dt)

    if dynamics == "sghmc":
        return unpad(out[0], orig_dtype), unpad(out[1], momentum.dtype)
    return unpad(out, orig_dtype)


def fused_update_chains_flat(theta: jax.Array, g: jax.Array,
                             seeds: jax.Array, *, h, scale, f_s,
                             prior_prec=0.0, alpha=0.0, temperature=1.0,
                             mu_g=None, mu_s=None, lam_g=None, lam_s=None,
                             momentum=None, friction=0.0,
                             dynamics: str = "langevin",
                             block_rows: int = 256,
                             interpret: Optional[bool] = None):
    """CHAIN-BATCHED fused update: one pallas_call over a whole chain block.

    theta, g: (C, ...) stacked per-chain tensors; seeds: (C,) uint32;
    scale, f_s: per-chain scalars (C,) — each chain is resident at a
    different client so its unbiasing factor N_s/(f_s m) differs.
    mu_g / lam_g: the GLOBAL surrogate, shared by every chain ((P,) or
    scalar lam); mu_s / lam_s: per-chain resident-client surrogates
    ((C, P), or (C,) scalar lams). The kernel reads shared operands once
    per chain via BlockSpec index maps instead of materialising a (C, P)
    broadcast, so the hot elementwise update stays one HBM pass per
    chain-block. Bit-identical to C separate fused_update_flat calls.
    ``dynamics='sghmc'`` carries the (C, ...) ``momentum`` stack through
    the SGHMC integrator and returns the (theta', momentum') pair.
    """
    interpret = INTERPRET if interpret is None else interpret
    C = theta.shape[0]
    orig_shape, orig_dtype = theta.shape, theta.dtype
    per_block = block_rows * LANE

    def pad_chains(x):  # (C, ...) -> (C*rows_c, LANE)
        x = x.reshape(C, -1).astype(jnp.float32)
        n = x.shape[1]
        padded = -(-n // per_block) * per_block
        x = jnp.pad(x, ((0, 0), (0, padded - n)))
        return x.reshape(C * (padded // LANE), LANE)

    def pad_shared(x):  # (P,) -> (rows_c, LANE)
        return _pad_2d(x.reshape(-1), block_rows)[0]

    n = theta.reshape(C, -1).shape[1]
    th2 = pad_chains(theta)
    g2 = pad_chains(g)
    rows_c = th2.shape[0] // C

    scale_c = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (C,))
    fs_c = jnp.broadcast_to(jnp.asarray(f_s, jnp.float32), (C,))

    if mu_g is None:
        variant = "plain"
        kw = {}
        lam_rows = (jnp.zeros((C,), jnp.float32),) * 2
    elif jnp.ndim(lam_g) == 0:
        variant = "scalar"
        kw = {"mu_g": pad_shared(mu_g), "mu_s": pad_chains(mu_s)}
        lam_rows = (jnp.broadcast_to(jnp.asarray(lam_g, jnp.float32), (C,)),
                    jnp.broadcast_to(jnp.asarray(lam_s, jnp.float32), (C,)))
    else:
        variant = "diag"
        kw = {"mu_g": pad_shared(mu_g), "mu_s": pad_chains(mu_s),
              "lam_g": pad_shared(lam_g), "lam_s": pad_chains(lam_s)}
        lam_rows = (jnp.zeros((C,), jnp.float32),) * 2

    def col(v):
        return jnp.broadcast_to(jnp.asarray(v, jnp.float32), (C,))

    if dynamics == "sghmc":
        kw["r2d"] = pad_chains(momentum)

    sc = jnp.stack([col(h), scale_c, fs_c, col(prior_prec), col(alpha),
                    col(temperature), lam_rows[0], lam_rows[1],
                    col(friction)], axis=1)
    br = min(block_rows, rows_c)
    out = fsgld_update_2d(th2, g2, seeds.astype(jnp.uint32), sc,
                          variant=variant, dynamics=dynamics,
                          interpret=interpret, block_rows=br, chains=C,
                          **kw)

    def unpad(o, dt):
        return o.reshape(C, -1)[:, :n].reshape(orig_shape).astype(dt)

    if dynamics == "sghmc":
        return unpad(out[0], orig_dtype), unpad(out[1], momentum.dtype)
    return unpad(out, orig_dtype)


def fused_update_chains_tree(theta: PyTree, g: PyTree, keys: jax.Array, *,
                             h, scale, f_s, prior_prec=0.0, alpha=0.0,
                             temperature=1.0, bank=None, sids=None,
                             surrogate_kind: Optional[str] = None,
                             momentum: Optional[PyTree] = None,
                             friction=0.0, dynamics: str = "langevin"):
    """Chain-batched fused update across a parameter pytree whose leaves
    carry a leading chain axis (C, ...).

    keys: (C, 2) per-chain PRNG keys; scale/f_s: (C,) per-chain factors;
    bank: SurrogateBank ('diag' or 'scalar') with sids (C,) selecting each
    chain's resident client, or None for SGLD/DSGLD. Per-leaf per-chain
    seeds are derived exactly as fused_update_tree does per chain, so the
    result bit-matches a vmap of the single-chain kernel path.
    ``dynamics='sghmc'`` takes the ``momentum`` pytree (same structure,
    leading chain axis) and returns the (theta', momentum') pair.
    """
    leaves, treedef = jax.tree.flatten(theta)
    gleaves = jax.tree.leaves(g)
    rleaves = (jax.tree.leaves(momentum) if momentum is not None
               else [None] * len(leaves))
    L = len(leaves)
    all_seeds = jax.vmap(lambda k: jax.random.split(k, L))(keys)  # (C, L, 2)

    if bank is None:
        mu_gs = mu_ss = lg = ls = [None] * L
    elif surrogate_kind == "diag":
        assert L == 1, "diag surrogates operate on flat vectors"
        mu_gs, lg = [bank.global_.mean], [bank.global_.prec]
        mu_ss, ls = [bank.means[sids]], [bank.precs[sids]]
    elif surrogate_kind == "scalar":
        mu_gs = jax.tree.leaves(bank.global_.mean)
        lg = jax.tree.leaves(bank.global_.prec)
        mu_ss = [m[sids] for m in jax.tree.leaves(bank.means)]
        ls = [p[sids] for p in jax.tree.leaves(bank.precs)]
    else:
        raise ValueError(surrogate_kind)

    out, out_r = [], []
    for i, (t, gg, rr) in enumerate(zip(leaves, gleaves, rleaves)):
        seed_c = jax.vmap(
            lambda s: jax.random.randint(s, (), 0, 2**31 - 1)
            .astype(jnp.uint32))(all_seeds[:, i])
        res = fused_update_chains_flat(
            t, gg, seed_c, h=h, scale=scale, f_s=f_s,
            prior_prec=prior_prec, alpha=alpha, temperature=temperature,
            mu_g=mu_gs[i], mu_s=mu_ss[i],
            lam_g=(jnp.asarray(lg[i], jnp.float32)
                   if lg[i] is not None else None),
            lam_s=(jnp.asarray(ls[i], jnp.float32)
                   if ls[i] is not None else None),
            momentum=rr, friction=friction, dynamics=dynamics)
        if dynamics == "sghmc":
            out.append(res[0])
            out_r.append(res[1])
        else:
            out.append(res)
    if dynamics == "sghmc":
        return (jax.tree.unflatten(treedef, out),
                jax.tree.unflatten(treedef, out_r))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# packed single-launch chain-state layout (PR 2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackedChains:
    """STATIC layout of a whole parameter pytree packed into one chain-major
    (C * rows_total, 128) fp32 buffer.

    Leaf l owns rows [row_offsets[l], row_offsets[l] + rows[l]) of every
    chain's segment; its first ``sizes[l]`` elements are live, the tail up
    to ``rows[l] * 128`` is pad (written by the kernel, never read back).
    ``seg_leaf``/``seg_base`` are the per-block tables the packed kernel's
    BlockSpec index maps consume: block j of a chain belongs to leaf
    ``seg_leaf[j]`` and starts at in-leaf element ``seg_base[j]`` — that
    base index is what keeps the in-kernel noise stream bit-identical to
    the per-leaf kernel. Hashable (all-tuple) so it can key jit caches.
    """
    treedef: Any
    shapes: tuple
    dtypes: tuple
    sizes: tuple          # live element count per leaf
    rows: tuple           # padded row count per leaf (block_rows multiple)
    row_offsets: tuple    # first row of each leaf inside a chain segment
    rows_total: int
    block_rows: int
    seg_leaf: tuple       # in-chain block -> leaf id
    seg_base: tuple       # in-chain block -> element offset within leaf

    @property
    def num_leaves(self) -> int:
        return len(self.shapes)

    @property
    def bpc(self) -> int:
        """Blocks per chain (grid steps each chain contributes)."""
        return len(self.seg_leaf)

    def pack(self, tree: PyTree) -> jax.Array:
        """Leaves (C, *shape) -> (C * rows_total, 128) fp32, chain-major.
        Pure update-slices into a zero buffer: no ``pad`` primitive, so
        packing can sit inside a scanned round body without tripping the
        no-pad jaxpr gate (it is still hoisted out of the step loop)."""
        leaves, treedef = jax.tree.flatten(tree)
        assert treedef == self.treedef, (treedef, self.treedef)
        c = leaves[0].shape[0]
        buf = jnp.zeros((c, self.rows_total * LANE), jnp.float32)
        for leaf, off, n in zip(leaves, self.row_offsets, self.sizes):
            buf = jax.lax.dynamic_update_slice(
                buf, leaf.reshape(c, n).astype(jnp.float32),
                (0, off * LANE))
        return buf.reshape(c * self.rows_total, LANE)

    def pack_shared(self, tree: PyTree) -> jax.Array:
        """Chain-free pytree (global surrogate) -> (rows_total, 128)."""
        return self.pack(jax.tree.map(lambda t: t[None], tree))

    def unpack(self, buf: jax.Array) -> PyTree:
        """(C * rows_total, 128) -> leaves (C, *shape) in original dtypes."""
        flat = buf.reshape(-1, self.rows_total * LANE)
        c = flat.shape[0]
        leaves = []
        for shape, dt, off, n in zip(self.shapes, self.dtypes,
                                     self.row_offsets, self.sizes):
            seg = jax.lax.slice(flat, (0, off * LANE), (c, off * LANE + n))
            leaves.append(seg.reshape((c,) + shape).astype(dt))
        return jax.tree.unflatten(self.treedef, leaves)

    @property
    def all_fp32(self) -> bool:
        return all(dt == jnp.float32 for dt in self.dtypes)

    def quantize(self, buf: jax.Array) -> jax.Array:
        """Per-step storage-dtype round-trip for non-fp32 leaves.

        The packed buffer carries fp32 state across steps, but the
        per-leaf kernel path casts each leaf back to its own dtype at
        every step end (``fused_update_flat``'s ``astype(orig_dtype)``)
        and re-widens it on the next step. Replaying that round-trip
        (fp32 -> leaf dtype -> fp32) on each non-fp32 leaf's row segment
        keeps the packed executor bit-identical to the per-leaf path —
        and to the ``run_vmap`` oracle — for bf16/fp16 parameter leaves.
        Identity (the SAME array, zero ops) when every leaf is fp32;
        static slices + update-slices otherwise, so it can sit inside a
        scanned round body without tripping the no-pad jaxpr gate.
        """
        if self.all_fp32:
            return buf
        flat = buf.reshape(-1, self.rows_total * LANE)
        c = flat.shape[0]
        for dt, off, r in zip(self.dtypes, self.row_offsets, self.rows):
            if dt == jnp.float32:
                continue
            seg = jax.lax.slice(flat, (0, off * LANE),
                                (c, (off + r) * LANE))
            seg = seg.astype(dt).astype(jnp.float32)
            flat = jax.lax.dynamic_update_slice(flat, seg, (0, off * LANE))
        return flat.reshape(buf.shape)


def make_packed_layout(theta: PyTree,
                       block_rows: int = PACK_BLOCK_ROWS) -> PackedChains:
    """Build the packed layout from a SINGLE-chain example pytree (shapes
    without the leading chain axis)."""
    leaves, treedef = jax.tree.flatten(theta)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(l.size) for l in leaves)
    per_block = block_rows * LANE
    rows = tuple(-(-n // per_block) * block_rows for n in sizes)
    row_offsets, acc = [], 0
    for r in rows:
        row_offsets.append(acc)
        acc += r
    seg_leaf, seg_base = [], []
    for li, r in enumerate(rows):
        for b in range(r // block_rows):
            seg_leaf.append(li)
            seg_base.append(b * per_block)
    return PackedChains(
        treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
        rows=rows, row_offsets=tuple(row_offsets), rows_total=acc,
        block_rows=block_rows, seg_leaf=tuple(seg_leaf),
        seg_base=tuple(seg_base))


def chain_leaf_seeds(keys: jax.Array, num_leaves: int) -> jax.Array:
    """(C, 2) per-chain step keys -> (C, L) uint32 per-(chain, leaf) seeds,
    derived EXACTLY as ``fused_update_chains_tree`` derives them (split the
    chain key into L leaf keys, draw one int31 per leaf) so packed and
    per-leaf kernels consume identical noise streams."""
    all_seeds = jax.vmap(lambda k: jax.random.split(k, num_leaves))(keys)
    draw = lambda s: jax.random.randint(  # noqa: E731 - mirrors per-leaf path
        s, (), 0, 2**31 - 1).astype(jnp.uint32)
    return jax.vmap(jax.vmap(draw))(all_seeds)


def packed_scalar_rows(layout: PackedChains, *, h, scale, f_s, prior_prec,
                       alpha, temperature, lam_g_leaf=None,
                       lam_s_leaf=None, friction=0.0) -> jax.Array:
    """Prebuild the (C, L, SCALAR_COLS) scalar-operand rows for a whole
    round: scale and f_s vary per chain (resident client), lam_g/lam_s
    vary per leaf in the 'scalar' surrogate variant ((L,) global / (C, L)
    resident scalar precisions); friction is the SGHMC alpha_f (dead for
    langevin dynamics); everything else broadcasts."""
    C = scale.shape[0]
    L = layout.num_leaves
    col = lambda v: jnp.broadcast_to(  # noqa: E731
        jnp.asarray(v, jnp.float32), (C, L))
    lamg = col(0.0) if lam_g_leaf is None \
        else jnp.broadcast_to(lam_g_leaf[None].astype(jnp.float32), (C, L))
    lams = col(0.0) if lam_s_leaf is None \
        else lam_s_leaf.astype(jnp.float32)
    return jnp.stack([
        col(h), col(scale[:, None]), col(f_s[:, None]), col(prior_prec),
        col(alpha), col(temperature), lamg, lams, col(friction)], axis=-1)


def packed_step(layout: PackedChains, theta_p: jax.Array, g_p: jax.Array,
                seeds: jax.Array, scalars: jax.Array, *, variant: str,
                mu_g=None, mu_s=None, lam_g=None, lam_s=None, r_p=None,
                dynamics: str = "langevin",
                interpret: Optional[bool] = None):
    """ONE pallas_call updating every leaf of every chain in the block.

    theta_p/g_p/mu_s/lam_s (and ``r_p``, the packed momenta, for
    ``dynamics='sghmc'``): (C * rows_total, 128) packed buffers;
    mu_g/lam_g: (rows_total, 128) packed global surrogate (re-read per
    chain by the kernel's shared BlockSpec); seeds: (C, L) uint32 from
    ``chain_leaf_seeds``; scalars: (C, L, SCALAR_COLS) from
    ``packed_scalar_rows``. Returns theta_p' or (theta_p', r_p').
    """
    interpret = INTERPRET if interpret is None else interpret
    C = seeds.shape[0]
    return fsgld_update_packed(
        theta_p, g_p, seeds, scalars, variant=variant, dynamics=dynamics,
        r2d=r_p, mu_g=mu_g, mu_s=mu_s, lam_g=lam_g, lam_s=lam_s,
        seg_leaf=layout.seg_leaf, seg_base=layout.seg_base,
        block_rows=layout.block_rows, chains=C, interpret=interpret)


def fused_update_tree(theta: PyTree, g: PyTree, key: jax.Array, *, h, scale,
                      f_s=1.0, prior_prec=0.0, alpha=0.0, temperature=1.0,
                      q_global=None, q_shard=None,
                      surrogate_kind: Optional[str] = None,
                      momentum: Optional[PyTree] = None, friction=0.0,
                      dynamics: str = "langevin"):
    """Apply the fused update across a parameter pytree.

    q_global/q_shard: repro.core.surrogate.Gaussian with 'diag' (flat-vector
    params) or 'scalar' (pytree means + per-leaf scalar precisions)
    structure, or None for SGLD/DSGLD. ``dynamics='sghmc'`` takes the
    ``momentum`` pytree and returns the (theta', momentum') pair; leaf
    seeds are derived identically for both dynamics (split the step key
    per leaf, one int31 draw each).
    """
    leaves, treedef = jax.tree.flatten(theta)
    gleaves = jax.tree.leaves(g)
    rleaves = (jax.tree.leaves(momentum) if momentum is not None
               else [None] * len(leaves))
    seeds = jax.random.split(key, len(leaves))

    if q_global is None:
        mu_gs = mu_ss = lg = ls = [None] * len(leaves)
    elif surrogate_kind == "diag":
        assert len(leaves) == 1, "diag surrogates operate on flat vectors"
        mu_gs, mu_ss = [q_global.mean], [q_shard.mean]
        lg, ls = [q_global.prec], [q_shard.prec]
    elif surrogate_kind == "scalar":
        mu_gs = jax.tree.leaves(q_global.mean)
        mu_ss = jax.tree.leaves(q_shard.mean)
        lg = jax.tree.leaves(q_global.prec)
        ls = jax.tree.leaves(q_shard.prec)
    else:
        raise ValueError(surrogate_kind)

    out, out_r = [], []
    for i, (t, gg, rr) in enumerate(zip(leaves, gleaves, rleaves)):
        seed = jax.random.randint(seeds[i], (), 0, 2**31 - 1).astype(
            jnp.uint32)
        res = fused_update_flat(
            t, gg, seed, h=h, scale=scale, f_s=f_s, prior_prec=prior_prec,
            alpha=alpha, temperature=temperature, mu_g=mu_gs[i],
            mu_s=mu_ss[i],
            lam_g=(jnp.asarray(lg[i], jnp.float32)
                   if lg[i] is not None else None),
            lam_s=(jnp.asarray(ls[i], jnp.float32)
                   if ls[i] is not None else None),
            momentum=rr, friction=friction, dynamics=dynamics)
        if dynamics == "sghmc":
            out.append(res[0])
            out_r.append(res[1])
        else:
            out.append(res)
    if dynamics == "sghmc":
        return (jax.tree.unflatten(treedef, out),
                jax.tree.unflatten(treedef, out_r))
    return jax.tree.unflatten(treedef, out)
