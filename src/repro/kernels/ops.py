"""jit'd public wrappers around the fused FSGLD update kernel.

`fused_update_tree` applies the kernel leaf-by-leaf over a parameter pytree:
ravel -> pad to (rows, 128) -> pallas_call -> unpad/reshape, with a
deterministic per-leaf seed folded out of a JAX PRNG key. On this CPU
container the kernel runs in interpret mode (the TPU path is identical
modulo `interpret=False`).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.fsgld_update import LANE, fsgld_update_2d

PyTree = Any

# CPU container: interpret=True executes the kernel body in Python/XLA-CPU.
# On a real TPU runtime set this to False (same kernel).
INTERPRET = jax.default_backend() != "tpu"


def _pad_2d(vec: jax.Array, block_rows: int):
    n = vec.shape[0]
    per_block = block_rows * LANE
    padded = -(-n // per_block) * per_block
    vec = jnp.pad(vec.astype(jnp.float32), (0, padded - n))
    return vec.reshape(-1, LANE), n


def _scalars_row(h, scale, f_s, prior_prec, alpha, temperature, lam_g,
                 lam_s) -> jax.Array:
    return jnp.stack([
        jnp.float32(h), jnp.asarray(scale, jnp.float32),
        jnp.asarray(f_s, jnp.float32), jnp.float32(prior_prec),
        jnp.float32(alpha), jnp.float32(temperature),
        jnp.asarray(lam_g, jnp.float32), jnp.asarray(lam_s, jnp.float32),
    ]).reshape(1, 8)


def fused_update_flat(theta: jax.Array, g: jax.Array, seed: jax.Array, *,
                      h, scale, f_s=1.0, prior_prec=0.0, alpha=0.0,
                      temperature=1.0, mu_g=None, mu_s=None, lam_g=None,
                      lam_s=None, block_rows: int = 256,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Fused Langevin update of one flat fp32 vector. Seeds: uint32 scalar."""
    interpret = INTERPRET if interpret is None else interpret
    orig_shape, orig_dtype = theta.shape, theta.dtype
    th2, n = _pad_2d(theta.reshape(-1), block_rows)
    g2, _ = _pad_2d(g.reshape(-1), block_rows)
    rows = th2.shape[0]
    br = min(block_rows, rows)
    while rows % br:
        br //= 2

    if mu_g is None:
        variant = "plain"
        kw = {}
        lam_row = (0.0, 0.0)
    elif jnp.ndim(lam_g) == 0:
        variant = "scalar"
        kw = {"mu_g": _pad_2d(mu_g.reshape(-1), block_rows)[0],
              "mu_s": _pad_2d(mu_s.reshape(-1), block_rows)[0]}
        lam_row = (lam_g, lam_s)
    else:
        variant = "diag"
        kw = {"mu_g": _pad_2d(mu_g.reshape(-1), block_rows)[0],
              "mu_s": _pad_2d(mu_s.reshape(-1), block_rows)[0],
              "lam_g": _pad_2d(lam_g.reshape(-1), block_rows)[0],
              "lam_s": _pad_2d(lam_s.reshape(-1), block_rows)[0]}
        lam_row = (0.0, 0.0)

    sc = _scalars_row(h, scale, f_s, prior_prec, alpha, temperature,
                      *lam_row)
    out = fsgld_update_2d(th2, g2, seed.reshape(1).astype(jnp.uint32), sc,
                          variant=variant, interpret=interpret,
                          block_rows=br, **kw)
    return out.reshape(-1)[:n].reshape(orig_shape).astype(orig_dtype)


def fused_update_chains_flat(theta: jax.Array, g: jax.Array,
                             seeds: jax.Array, *, h, scale, f_s,
                             prior_prec=0.0, alpha=0.0, temperature=1.0,
                             mu_g=None, mu_s=None, lam_g=None, lam_s=None,
                             block_rows: int = 256,
                             interpret: Optional[bool] = None) -> jax.Array:
    """CHAIN-BATCHED fused update: one pallas_call over a whole chain block.

    theta, g: (C, ...) stacked per-chain tensors; seeds: (C,) uint32;
    scale, f_s: per-chain scalars (C,) — each chain is resident at a
    different client so its unbiasing factor N_s/(f_s m) differs.
    mu_g / lam_g: the GLOBAL surrogate, shared by every chain ((P,) or
    scalar lam); mu_s / lam_s: per-chain resident-client surrogates
    ((C, P), or (C,) scalar lams). The kernel reads shared operands once
    per chain via BlockSpec index maps instead of materialising a (C, P)
    broadcast, so the hot elementwise update stays one HBM pass per
    chain-block. Bit-identical to C separate fused_update_flat calls.
    """
    interpret = INTERPRET if interpret is None else interpret
    C = theta.shape[0]
    orig_shape, orig_dtype = theta.shape, theta.dtype
    per_block = block_rows * LANE

    def pad_chains(x):  # (C, ...) -> (C*rows_c, LANE)
        x = x.reshape(C, -1).astype(jnp.float32)
        n = x.shape[1]
        padded = -(-n // per_block) * per_block
        x = jnp.pad(x, ((0, 0), (0, padded - n)))
        return x.reshape(C * (padded // LANE), LANE)

    def pad_shared(x):  # (P,) -> (rows_c, LANE)
        return _pad_2d(x.reshape(-1), block_rows)[0]

    n = theta.reshape(C, -1).shape[1]
    th2 = pad_chains(theta)
    g2 = pad_chains(g)
    rows_c = th2.shape[0] // C

    scale_c = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (C,))
    fs_c = jnp.broadcast_to(jnp.asarray(f_s, jnp.float32), (C,))

    if mu_g is None:
        variant = "plain"
        kw = {}
        lam_rows = (jnp.zeros((C,), jnp.float32),) * 2
    elif jnp.ndim(lam_g) == 0:
        variant = "scalar"
        kw = {"mu_g": pad_shared(mu_g), "mu_s": pad_chains(mu_s)}
        lam_rows = (jnp.broadcast_to(jnp.asarray(lam_g, jnp.float32), (C,)),
                    jnp.broadcast_to(jnp.asarray(lam_s, jnp.float32), (C,)))
    else:
        variant = "diag"
        kw = {"mu_g": pad_shared(mu_g), "mu_s": pad_chains(mu_s),
              "lam_g": pad_shared(lam_g), "lam_s": pad_chains(lam_s)}
        lam_rows = (jnp.zeros((C,), jnp.float32),) * 2

    def col(v):
        return jnp.broadcast_to(jnp.asarray(v, jnp.float32), (C,))

    sc = jnp.stack([col(h), scale_c, fs_c, col(prior_prec), col(alpha),
                    col(temperature), lam_rows[0], lam_rows[1]], axis=1)
    br = min(block_rows, rows_c)
    out = fsgld_update_2d(th2, g2, seeds.astype(jnp.uint32), sc,
                          variant=variant, interpret=interpret,
                          block_rows=br, chains=C, **kw)
    return (out.reshape(C, -1)[:, :n].reshape(orig_shape)
            .astype(orig_dtype))


def fused_update_chains_tree(theta: PyTree, g: PyTree, keys: jax.Array, *,
                             h, scale, f_s, prior_prec=0.0, alpha=0.0,
                             temperature=1.0, bank=None, sids=None,
                             surrogate_kind: Optional[str] = None) -> PyTree:
    """Chain-batched fused update across a parameter pytree whose leaves
    carry a leading chain axis (C, ...).

    keys: (C, 2) per-chain PRNG keys; scale/f_s: (C,) per-chain factors;
    bank: SurrogateBank ('diag' or 'scalar') with sids (C,) selecting each
    chain's resident client, or None for SGLD/DSGLD. Per-leaf per-chain
    seeds are derived exactly as fused_update_tree does per chain, so the
    result bit-matches a vmap of the single-chain kernel path.
    """
    leaves, treedef = jax.tree.flatten(theta)
    gleaves = jax.tree.leaves(g)
    L = len(leaves)
    all_seeds = jax.vmap(lambda k: jax.random.split(k, L))(keys)  # (C, L, 2)

    if bank is None:
        mu_gs = mu_ss = lg = ls = [None] * L
    elif surrogate_kind == "diag":
        assert L == 1, "diag surrogates operate on flat vectors"
        mu_gs, lg = [bank.global_.mean], [bank.global_.prec]
        mu_ss, ls = [bank.means[sids]], [bank.precs[sids]]
    elif surrogate_kind == "scalar":
        mu_gs = jax.tree.leaves(bank.global_.mean)
        lg = jax.tree.leaves(bank.global_.prec)
        mu_ss = [m[sids] for m in jax.tree.leaves(bank.means)]
        ls = [p[sids] for p in jax.tree.leaves(bank.precs)]
    else:
        raise ValueError(surrogate_kind)

    out = []
    for i, (t, gg) in enumerate(zip(leaves, gleaves)):
        seed_c = jax.vmap(
            lambda s: jax.random.randint(s, (), 0, 2**31 - 1)
            .astype(jnp.uint32))(all_seeds[:, i])
        out.append(fused_update_chains_flat(
            t, gg, seed_c, h=h, scale=scale, f_s=f_s,
            prior_prec=prior_prec, alpha=alpha, temperature=temperature,
            mu_g=mu_gs[i], mu_s=mu_ss[i],
            lam_g=(jnp.asarray(lg[i], jnp.float32)
                   if lg[i] is not None else None),
            lam_s=(jnp.asarray(ls[i], jnp.float32)
                   if ls[i] is not None else None)))
    return jax.tree.unflatten(treedef, out)


def fused_update_tree(theta: PyTree, g: PyTree, key: jax.Array, *, h, scale,
                      f_s=1.0, prior_prec=0.0, alpha=0.0, temperature=1.0,
                      q_global=None, q_shard=None,
                      surrogate_kind: Optional[str] = None) -> PyTree:
    """Apply the fused update across a parameter pytree.

    q_global/q_shard: repro.core.surrogate.Gaussian with 'diag' (flat-vector
    params) or 'scalar' (pytree means + per-leaf scalar precisions)
    structure, or None for SGLD/DSGLD.
    """
    leaves, treedef = jax.tree.flatten(theta)
    gleaves = jax.tree.leaves(g)
    seeds = jax.random.split(key, len(leaves))

    if q_global is None:
        mu_gs = mu_ss = lg = ls = [None] * len(leaves)
    elif surrogate_kind == "diag":
        assert len(leaves) == 1, "diag surrogates operate on flat vectors"
        mu_gs, mu_ss = [q_global.mean], [q_shard.mean]
        lg, ls = [q_global.prec], [q_shard.prec]
    elif surrogate_kind == "scalar":
        mu_gs = jax.tree.leaves(q_global.mean)
        mu_ss = jax.tree.leaves(q_shard.mean)
        lg = jax.tree.leaves(q_global.prec)
        ls = jax.tree.leaves(q_shard.prec)
    else:
        raise ValueError(surrogate_kind)

    out = []
    for i, (t, gg) in enumerate(zip(leaves, gleaves)):
        seed = jax.random.randint(seeds[i], (), 0, 2**31 - 1).astype(
            jnp.uint32)
        out.append(fused_update_flat(
            t, gg, seed, h=h, scale=scale, f_s=f_s, prior_prec=prior_prec,
            alpha=alpha, temperature=temperature, mu_g=mu_gs[i],
            mu_s=mu_ss[i],
            lam_g=(jnp.asarray(lg[i], jnp.float32)
                   if lg[i] is not None else None),
            lam_s=(jnp.asarray(ls[i], jnp.float32)
                   if ls[i] is not None else None)))
    return jax.tree.unflatten(treedef, out)
