"""jit'd public wrappers around the fused FSGLD update kernel.

`fused_update_tree` applies the kernel leaf-by-leaf over a parameter pytree:
ravel -> pad to (rows, 128) -> pallas_call -> unpad/reshape, with a
deterministic per-leaf seed folded out of a JAX PRNG key. On this CPU
container the kernel runs in interpret mode (the TPU path is identical
modulo `interpret=False`).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.fsgld_update import LANE, fsgld_update_2d

PyTree = Any

# CPU container: interpret=True executes the kernel body in Python/XLA-CPU.
# On a real TPU runtime set this to False (same kernel).
INTERPRET = jax.default_backend() != "tpu"


def _pad_2d(vec: jax.Array, block_rows: int):
    n = vec.shape[0]
    per_block = block_rows * LANE
    padded = -(-n // per_block) * per_block
    vec = jnp.pad(vec.astype(jnp.float32), (0, padded - n))
    return vec.reshape(-1, LANE), n


def _scalars_row(h, scale, f_s, prior_prec, alpha, temperature, lam_g,
                 lam_s) -> jax.Array:
    return jnp.stack([
        jnp.float32(h), jnp.asarray(scale, jnp.float32),
        jnp.asarray(f_s, jnp.float32), jnp.float32(prior_prec),
        jnp.float32(alpha), jnp.float32(temperature),
        jnp.asarray(lam_g, jnp.float32), jnp.asarray(lam_s, jnp.float32),
    ]).reshape(1, 8)


def fused_update_flat(theta: jax.Array, g: jax.Array, seed: jax.Array, *,
                      h, scale, f_s=1.0, prior_prec=0.0, alpha=0.0,
                      temperature=1.0, mu_g=None, mu_s=None, lam_g=None,
                      lam_s=None, block_rows: int = 256,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Fused Langevin update of one flat fp32 vector. Seeds: uint32 scalar."""
    interpret = INTERPRET if interpret is None else interpret
    orig_shape, orig_dtype = theta.shape, theta.dtype
    th2, n = _pad_2d(theta.reshape(-1), block_rows)
    g2, _ = _pad_2d(g.reshape(-1), block_rows)
    rows = th2.shape[0]
    br = min(block_rows, rows)
    while rows % br:
        br //= 2

    if mu_g is None:
        variant = "plain"
        kw = {}
        lam_row = (0.0, 0.0)
    elif jnp.ndim(lam_g) == 0:
        variant = "scalar"
        kw = {"mu_g": _pad_2d(mu_g.reshape(-1), block_rows)[0],
              "mu_s": _pad_2d(mu_s.reshape(-1), block_rows)[0]}
        lam_row = (lam_g, lam_s)
    else:
        variant = "diag"
        kw = {"mu_g": _pad_2d(mu_g.reshape(-1), block_rows)[0],
              "mu_s": _pad_2d(mu_s.reshape(-1), block_rows)[0],
              "lam_g": _pad_2d(lam_g.reshape(-1), block_rows)[0],
              "lam_s": _pad_2d(lam_s.reshape(-1), block_rows)[0]}
        lam_row = (0.0, 0.0)

    sc = _scalars_row(h, scale, f_s, prior_prec, alpha, temperature,
                      *lam_row)
    out = fsgld_update_2d(th2, g2, seed.reshape(1).astype(jnp.uint32), sc,
                          variant=variant, interpret=interpret,
                          block_rows=br, **kw)
    return out.reshape(-1)[:n].reshape(orig_shape).astype(orig_dtype)


def fused_update_tree(theta: PyTree, g: PyTree, key: jax.Array, *, h, scale,
                      f_s=1.0, prior_prec=0.0, alpha=0.0, temperature=1.0,
                      q_global=None, q_shard=None,
                      surrogate_kind: Optional[str] = None) -> PyTree:
    """Apply the fused update across a parameter pytree.

    q_global/q_shard: repro.core.surrogate.Gaussian with 'diag' (flat-vector
    params) or 'scalar' (pytree means + per-leaf scalar precisions)
    structure, or None for SGLD/DSGLD.
    """
    leaves, treedef = jax.tree.flatten(theta)
    gleaves = jax.tree.leaves(g)
    seeds = jax.random.split(key, len(leaves))

    if q_global is None:
        mu_gs = mu_ss = lg = ls = [None] * len(leaves)
    elif surrogate_kind == "diag":
        assert len(leaves) == 1, "diag surrogates operate on flat vectors"
        mu_gs, mu_ss = [q_global.mean], [q_shard.mean]
        lg, ls = [q_global.prec], [q_shard.prec]
    elif surrogate_kind == "scalar":
        mu_gs = jax.tree.leaves(q_global.mean)
        mu_ss = jax.tree.leaves(q_shard.mean)
        lg = jax.tree.leaves(q_global.prec)
        ls = jax.tree.leaves(q_shard.prec)
    else:
        raise ValueError(surrogate_kind)

    out = []
    for i, (t, gg) in enumerate(zip(leaves, gleaves)):
        seed = jax.random.randint(seeds[i], (), 0, 2**31 - 1).astype(
            jnp.uint32)
        out.append(fused_update_flat(
            t, gg, seed, h=h, scale=scale, f_s=f_s, prior_prec=prior_prec,
            alpha=alpha, temperature=temperature, mu_g=mu_gs[i],
            mu_s=mu_ss[i],
            lam_g=(jnp.asarray(lg[i], jnp.float32)
                   if lg[i] is not None else None),
            lam_s=(jnp.asarray(ls[i], jnp.float32)
                   if ls[i] is not None else None)))
    return jax.tree.unflatten(treedef, out)
