"""Fused FSGLD parameter-update Pallas TPU kernel.

The per-step hot spot the paper's method adds to SGLD is elementwise but
multi-operand:

    theta' = theta + (h/2) * [ -prior_prec*theta + scale*g_hat
                               + alpha*( lam_g*(mu_g - theta)
                                         - (lam_s/f_s)*(mu_s - theta) ) ]
             + sqrt(h*temperature) * xi,        xi ~ N(0, 1)

Unfused this costs ~7 HBM round-trips over P parameters (theta, g, mu_g,
mu_s, xi, out + the precision vectors); the kernel does ONE pass with
(8,128)-aligned VMEM tiles and generates xi *in kernel* from a counter-based
hash (murmur3 finalizer + Box-Muller), so the noise tensor never touches HBM.

Using a counter-based hash (instead of pltpu.prng_random_bits) keeps the
kernel bit-exactly reproducible by the pure-jnp oracle in ref.py — the
correctness tests assert end-to-end equality including the noise.

Three DRIFT variants:
  plain   — SGLD/DSGLD (alpha = 0): operands (theta, g)
  scalar  — per-tensor scalar precisions: operands (theta, g, mu_g, mu_s)
  diag    — diagonal precisions: operands (theta, g, mu_g, mu_s, lam_g, lam_s)

crossed with two DYNAMICS (the paper's conducive correction is drift-level,
so it composes with any SG-MCMC integrator — see core/sghmc.py):
  langevin — the update above (one output);
  sghmc    — naive-Euler SGHMC with friction alpha_f (S_FRIC scalar row):
                 r'     = (1 - a) r + h * drift + sqrt(2 a tau) sqrt(h) xi
                 theta' = theta + r'
             extra momentum operand, two outputs (theta', r').

All operate on parameters reshaped to (rows, 128); the jit'd wrapper in
ops.py handles ravel / pad / unpad and per-tensor seeds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
BLOCK_ROWS = 256  # 256 x 128 fp32 = 128 KiB per operand tile in VMEM
PACK_BLOCK_ROWS = 8  # packed multi-leaf grid: fp32 min tile, small pad waste

# scalar-operand layout (one f32 row broadcast to every block of a
# (chain, leaf)); S_FRIC is the SGHMC friction alpha_f, dead for langevin
(S_H, S_SCALE, S_FS, S_PRIOR, S_ALPHA, S_TEMP, S_LAMG, S_LAMS,
 S_FRIC) = range(9)
SCALAR_COLS = 9

_N_SUR = {"plain": 0, "scalar": 2, "diag": 4}


def _mix(h: jax.Array) -> jax.Array:
    """murmur3 fmix32 — full avalanche integer hash (uint32 -> uint32)."""
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _gaussian_noise(seed: jax.Array, idx: jax.Array) -> jax.Array:
    """Standard normal per element via two hash streams + Box-Muller.
    ``idx``: uint32 global element indices; ``seed``: uint32 scalar."""
    h1 = _mix(idx * jnp.uint32(2) + jnp.uint32(1) + seed * jnp.uint32(0x9E3779B9))
    h2 = _mix(idx * jnp.uint32(2) + seed * jnp.uint32(0x85EBCA77))
    # 24-bit mantissas -> u in (0, 1); u1 strictly > 0 for the log
    u1 = (h1 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24)) \
        + (0.5 / (1 << 24))
    u2 = (h2 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos((2.0 * jnp.pi) * u2)


def _global_idx(block_rows: int, blocks_per_chain: int) -> jax.Array:
    """uint32 element index WITHIN the current chain's parameter vector.

    The grid is chain-major: blocks [c*bpc, (c+1)*bpc) belong to chain c, so
    the in-chain block index is ``pid % blocks_per_chain``. With one chain
    (bpc == grid size) this reduces to the global index — bit-identical to
    the original single-chain kernel.
    """
    pid = pl.program_id(0)
    base = ((pid % blocks_per_chain) * block_rows * LANE).astype(jnp.uint32)
    row = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANE), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANE), 1)
    return base + row * jnp.uint32(LANE) + col


def _drift(variant, sc, th, g, sur):
    """The shared FSGLD drift: prior + scaled minibatch gradient
    (+ conducive term for the surrogate variants)."""
    base = -sc[0, S_PRIOR] * th + sc[0, S_SCALE] * g
    if variant == "plain":
        return base
    if variant == "scalar":
        mg, ms = sur
        cond = sc[0, S_LAMG] * (mg - th) \
            - (sc[0, S_LAMS] / sc[0, S_FS]) * (ms - th)
    else:  # diag
        mg, ms, lg, ls = sur
        cond = lg * (mg - th) - (ls / sc[0, S_FS]) * (ms - th)
    return base + sc[0, S_ALPHA] * cond


def _make_kernel(variant: str, dynamics: str, *, block_rows: int, bpc: int,
                 packed: bool):
    """Kernel body for one (drift variant, dynamics, layout) cell.

    Ref order: [seg, base,] seed, scalars, theta, [momentum,] g,
    [surrogate operands...], theta_out[, momentum_out]. The langevin cells
    reproduce the original per-dynamics kernels expression-for-expression,
    so noise and rounding are unchanged.
    """
    n_sur = _N_SUR[variant]
    momentum = dynamics == "sghmc"

    def kernel(*refs):
        if packed:
            _seg_ref, base_ref, seed_ref, sc_ref = refs[:4]
            refs = refs[4:]
        else:
            seed_ref, sc_ref = refs[:2]
            refs = refs[2:]
        th_ref = refs[0]
        r_ref = refs[1] if momentum else None
        k = 2 if momentum else 1
        g_ref = refs[k]
        sur = [refs[k + 1 + i][...].astype(jnp.float32)
               for i in range(n_sur)]
        outs = refs[k + 1 + n_sur:]

        sc = sc_ref[0] if packed else sc_ref[...]     # (1, SCALAR_COLS)
        th = th_ref[...].astype(jnp.float32)
        g = g_ref[...].astype(jnp.float32)
        drift = _drift(variant, sc, th, g, sur)

        if packed:
            # in-leaf element index from the prefetched segment table:
            # keeps the noise stream bit-identical to the per-leaf kernel
            seed = seed_ref[0, 0]
            base = base_ref[pl.program_id(0) % bpc].astype(jnp.uint32)
            row = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANE), 0)
            col = jax.lax.broadcasted_iota(jnp.uint32, (block_rows, LANE), 1)
            idx = base + row * jnp.uint32(LANE) + col
        else:
            seed = seed_ref[0]
            idx = _global_idx(block_rows, bpc)
        xi = _gaussian_noise(seed, idx)

        h = sc[0, S_H]
        if dynamics == "langevin":
            sig = jnp.sqrt(h * sc[0, S_TEMP])
            outs[0][...] = th + (h * 0.5) * drift + sig * xi
        else:
            a = sc[0, S_FRIC]
            noise_sig = jnp.sqrt(2.0 * a * sc[0, S_TEMP])
            r = r_ref[...].astype(jnp.float32)
            r_new = (1.0 - a) * r + h * drift \
                + (noise_sig * jnp.sqrt(h)) * xi
            outs[0][...] = th + r_new
            outs[1][...] = r_new

    return kernel


def _variant_ops(variant, mu_g, mu_s, lam_g, lam_s, tile, shared_tile):
    """Surrogate operand / BlockSpec lists shared by both launchers.
    Shared (global) operands re-read per chain via ``shared_tile``."""
    if variant == "plain":
        return [], []
    if variant == "scalar":
        return [mu_g, mu_s], [shared_tile, tile]
    if variant == "diag":
        return [mu_g, mu_s, lam_g, lam_s], \
            [shared_tile, tile, shared_tile, tile]
    raise ValueError(variant)


@functools.partial(jax.jit, static_argnames=("variant", "dynamics",
                                             "interpret", "block_rows",
                                             "chains"))
def fsgld_update_2d(theta2d: jax.Array, g2d: jax.Array, seed: jax.Array,
                    scalars: jax.Array, *, variant: str = "plain",
                    dynamics: str = "langevin", r2d=None,
                    mu_g=None, mu_s=None, lam_g=None, lam_s=None,
                    interpret: bool = False,
                    block_rows: int = BLOCK_ROWS,
                    chains: int = 1):
    """Run the fused update on (rows, 128)-shaped operands.

    scalars: (chains, SCALAR_COLS) f32 rows [h, scale, f_s, prior_prec,
    alpha, temperature, lam_g, lam_s, friction]; seed: (chains,) uint32.
    ``dynamics='sghmc'`` takes the (rows, 128) momentum buffer ``r2d`` and
    returns the pair (theta', r'); 'langevin' returns theta' alone.

    CHAIN-BATCHED mode (``chains`` > 1): the leading ``rows`` axis is
    chain-major — rows [c*rows_c, (c+1)*rows_c) hold chain c's parameters
    (rows_c = rows / chains). Per-chain operands (theta, r, g, mu_s, lam_s)
    are full-height; per-chain *scalars* and *seeds* are selected by the
    BlockSpec index map ``i // bpc`` and SHARED operands (mu_g, lam_g — the
    global surrogate, identical for every chain) are (rows_c, 128) and
    re-read per chain via ``i % bpc``, so one pallas_call covers the whole
    chain block in a single HBM pass with no broadcast materialisation.
    Noise streams are per-chain (seed c + in-chain element index), making
    the batched kernel bit-identical to ``chains`` separate calls.
    """
    rows = theta2d.shape[0]
    assert theta2d.shape[1] == LANE, theta2d.shape
    assert rows % chains == 0, (rows, chains)
    rows_c = rows // chains
    br = min(block_rows, rows_c)
    assert rows_c % br == 0, (rows_c, br)
    bpc = rows_c // br  # blocks per chain
    grid = (rows // br,)

    tile = pl.BlockSpec((br, LANE), lambda i: (i, 0))
    shared_tile = pl.BlockSpec((br, LANE), lambda i: (i % bpc, 0))
    scalar_spec = pl.BlockSpec((1, SCALAR_COLS), lambda i: (i // bpc, 0))
    seed_spec = pl.BlockSpec((1,), lambda i: (i // bpc,))

    kernel = _make_kernel(variant, dynamics, block_rows=br, bpc=bpc,
                          packed=False)
    sur_ops, sur_specs = _variant_ops(variant, mu_g, mu_s, lam_g, lam_s,
                                      tile, shared_tile)
    if dynamics == "sghmc":
        assert r2d is not None and r2d.shape == theta2d.shape
        ops = [theta2d, r2d, g2d] + sur_ops
        specs = [tile, tile, tile] + sur_specs
        out_specs = (tile, tile)
        out_shape = (jax.ShapeDtypeStruct((rows, LANE), jnp.float32),) * 2
    else:
        ops = [theta2d, g2d] + sur_ops
        specs = [tile, tile] + sur_specs
        out_specs = tile
        out_shape = jax.ShapeDtypeStruct((rows, LANE), jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seed_spec, scalar_spec] + specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(seed, scalars, *ops)


# ---------------------------------------------------------------------------
# packed multi-leaf single-launch kernel (PR 2; SGHMC + mixed dtypes PR 4)
#
# The whole parameter pytree of a whole chain block rides in ONE
# (C * rows_total, 128) buffer: each leaf owns a contiguous run of rows
# padded up to a block multiple, chains are major. A static SEGMENT TABLE
# (seg_leaf: block -> leaf id, seg_base: block -> element offset within the
# leaf) rides in as scalar-prefetch operands; seed/scalar BlockSpec index
# maps look the (chain, leaf) coordinate up in it, so one pallas_call per
# step covers every leaf of every chain while noise streams stay
# bit-identical to the per-leaf kernel above (same per-(chain, leaf) seed,
# same in-leaf element index). ``dynamics='sghmc'`` adds a SECOND
# chain-major buffer — the momenta — sharing the same segment table.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "variant", "dynamics", "interpret", "block_rows", "chains", "seg_leaf",
    "seg_base"))
def fsgld_update_packed(theta2d: jax.Array, g2d: jax.Array,
                        seeds: jax.Array, scalars: jax.Array, *,
                        variant: str = "plain",
                        dynamics: str = "langevin", r2d=None,
                        mu_g=None, mu_s=None, lam_g=None, lam_s=None,
                        seg_leaf: tuple = (0,), seg_base: tuple = (0,),
                        interpret: bool = False,
                        block_rows: int = PACK_BLOCK_ROWS,
                        chains: int = 1):
    """SINGLE-LAUNCH fused update over a packed multi-leaf chain block.

    theta2d/g2d (and ``r2d``, the momenta, for ``dynamics='sghmc'``):
    (chains * rows_total, 128) chain-major packed buffers, rows_total =
    block_rows * len(seg_leaf). seeds: (chains, L) uint32 — one stream per
    (chain, leaf), matching the per-leaf kernel's seed derivation.
    scalars: (chains, L, SCALAR_COLS) rows in the S_* layout (per-leaf
    scalar precisions for the 'scalar' variant live in S_LAMG/S_LAMS, the
    SGHMC friction in S_FRIC). mu_g/lam_g: (rows_total, 128) packed GLOBAL
    surrogate, re-read per chain; mu_s/lam_s: (chains * rows_total, 128)
    packed per-chain resident-client surrogates.

    seg_leaf[j] names the leaf block j belongs to; seg_base[j] is the
    element offset of block j inside that leaf's padded vector. Both are
    STATIC tuples shipped as scalar-prefetch operands so the BlockSpec
    index maps can route seed/scalar rows per (chain, leaf) — one grid,
    one HBM pass, zero per-leaf dispatch. Bit-identical to per-leaf
    ``fsgld_update_2d`` calls because pad rows at each leaf tail are
    discarded at unpack and live elements keep their in-leaf index.
    Returns theta' ('langevin') or the pair (theta', r') ('sghmc').
    """
    rows = theta2d.shape[0]
    assert theta2d.shape[1] == LANE, theta2d.shape
    bpc = len(seg_leaf)
    assert len(seg_base) == bpc, (len(seg_base), bpc)
    assert rows == chains * bpc * block_rows, (rows, chains, bpc, block_rows)
    grid = (chains * bpc,)
    seg_t = jnp.asarray(seg_leaf, jnp.int32)
    base_t = jnp.asarray(seg_base, jnp.int32)

    tile = pl.BlockSpec((block_rows, LANE), lambda i, sg, bs: (i, 0))
    shared_tile = pl.BlockSpec((block_rows, LANE),
                               lambda i, sg, bs: (i % bpc, 0))
    seed_spec = pl.BlockSpec((1, 1),
                             lambda i, sg, bs: (i // bpc, sg[i % bpc]))
    scalar_spec = pl.BlockSpec((1, 1, SCALAR_COLS),
                               lambda i, sg, bs: (i // bpc, sg[i % bpc], 0))

    kernel = _make_kernel(variant, dynamics, block_rows=block_rows, bpc=bpc,
                          packed=True)
    sur_ops, sur_specs = _variant_ops(variant, mu_g, mu_s, lam_g, lam_s,
                                      tile, shared_tile)
    if dynamics == "sghmc":
        assert r2d is not None and r2d.shape == theta2d.shape
        ops = [theta2d, r2d, g2d] + sur_ops
        specs = [tile, tile, tile] + sur_specs
        out_specs = (tile, tile)
        out_shape = (jax.ShapeDtypeStruct((rows, LANE), jnp.float32),) * 2
    else:
        ops = [theta2d, g2d] + sur_ops
        specs = [tile, tile] + sur_specs
        out_specs = tile
        out_shape = jax.ShapeDtypeStruct((rows, LANE), jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[seed_spec, scalar_spec] + specs,
        out_specs=out_specs,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(seg_t, base_t, seeds, scalars, *ops)
