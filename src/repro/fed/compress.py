"""Compressed communication operators for round-boundary payloads.

ELF (federated Langevin with primal/dual compression) and the QSGD /
top-k literature treat the bits a client uploads per round as a
first-class axis. Here the payload is the chain's parameter DELTA since
the last communication: at each communication round the server applies

    upd   = (theta - theta_ref) + err          # delta + error feedback
    dhat  = C(upd)                             # the compressed payload
    ref'  = theta_ref + dhat                   # the server's view
    err'  = upd - dhat                         # error-feedback residual
    theta <- ref'                              # chain continues from the
                                               # server view (what every
                                               # other client will see)

so with error feedback the quantization error is re-injected on the next
exchange instead of accumulating as bias. ``kind='none'`` (or
``frac=1`` top-k) makes ``dhat == upd`` and the round is exact.

ELF's *dual* direction compresses the server→client broadcast the same
way: the server's post-aggregation model ``m`` is sent as a compressed
delta against the shared reference ``v`` (what both sides last agreed
on), with its own error-feedback residual ``derr``::

    dupd  = (m - v) + derr                     # server-side delta + EF
    dd    = C_d(dupd)                          # the downlink payload
    v'    = v + dd                             # both sides' new reference
    derr' = dupd - dd                          # dual EF residual

``direction`` selects which legs are compressed: ``'primal'`` (client→
server, the default — today's behavior), ``'dual'`` (server→client
only), or ``'bidir'`` (both, each leg with independent EF state).

All operators are pure jnp on (C, P) chain-major flat matrices — they
run *inside* the engine's jitted scan — and each spec reports the
estimated ``bytes_per_round`` it moves per chain per communication
round, BOTH directions (uncompressed legs count 4 bytes/coordinate).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Compression:
    """Declarative round-boundary payload compression.

    kind:
      'none'  — exact exchange (the identity; elided by the engine).
      'topk'  — keep the ``frac`` largest-|.| coordinates per chain
                (ties at the threshold are all kept).
      'randk' — keep each coordinate independently with prob ``frac``,
                rescaled by 1/frac so the operator stays unbiased.
      'qsgd'  — stochastic uniform quantization to 2^bits - 1 levels of
                |upd| / max|upd| with a per-chain fp32 scale (QSGD-style;
                unbiased by stochastic rounding).
    ``error_feedback`` keeps the residual state (top-k without it is
    biased; randk/qsgd are unbiased either way).

    ``direction`` — ELF-style leg selection: ``'primal'`` compresses
    client→server uploads (the default), ``'dual'`` compresses the
    server→client broadcast, ``'bidir'`` compresses both with
    independent error-feedback state per leg.
    """
    kind: str = "none"
    frac: float = 0.01
    bits: int = 8
    error_feedback: bool = True
    direction: str = "primal"

    def __post_init__(self):
        assert self.kind in ("none", "topk", "randk", "qsgd"), self.kind
        assert 0.0 < self.frac <= 1.0, self.frac
        assert 1 <= self.bits <= 16, self.bits
        assert self.direction in ("primal", "dual", "bidir"), self.direction

    @property
    def identity(self) -> bool:
        return self.kind == "none"

    @property
    def use_primal(self) -> bool:
        """Client→server uploads go through the operator."""
        return self.kind != "none" and self.direction in ("primal", "bidir")

    @property
    def use_dual(self) -> bool:
        """Server→client broadcasts go through the operator."""
        return self.kind != "none" and self.direction in ("dual", "bidir")

    def payload_bytes(self, dim: int) -> float:
        """Estimated bytes of ONE compressed payload for a dim-P chain."""
        if self.kind == "none":
            return 4.0 * dim
        if self.kind in ("topk", "randk"):
            k = max(1, int(round(self.frac * dim)))
            return 8.0 * k  # fp32 value + int32 index per kept coordinate
        return dim * self.bits / 8.0 + 4.0  # qsgd: levels + fp32 scale

    def bytes_per_round(self, dim: int) -> float:
        """Estimated bytes per chain per communication round, BOTH
        directions: compressed legs report the operator's payload,
        uncompressed legs count the exact 4 bytes/coordinate."""
        up = self.payload_bytes(dim) if self.use_primal else 4.0 * dim
        down = self.payload_bytes(dim) if self.use_dual else 4.0 * dim
        return up + down


def make_flattener(thetas: PyTree):
    """(C, ...)-leaf pytree <-> (C, P) fp32 flat matrix.

    Compression operates in fp32 flat space; ``unflatten`` casts each
    slice back to its leaf's storage dtype. Shapes are taken from the
    (traced or concrete) template, so the closures are shape-static.
    """
    leaves, treedef = jax.tree.flatten(thetas)
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(math.prod(s)) for s in shapes]
    dtypes = [l.dtype for l in leaves]

    def flatten(tree):
        ls = jax.tree.leaves(tree)
        return jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in ls],
            axis=1)

    def unflatten(flat):
        out, off = [], 0
        for shp, sz, dt in zip(shapes, sizes, dtypes):
            out.append(flat[:, off:off + sz]
                       .reshape((flat.shape[0],) + shp).astype(dt))
            off += sz
        return jax.tree.unflatten(treedef, out)

    return flatten, unflatten, int(sum(sizes))


def make_compressor(spec: Compression, dim: int):
    """Lower a :class:`Compression` spec to ``compress(upd, key) -> dhat``
    over (C, P) flat payloads. Pure jnp — safe inside the engine scan."""
    if spec.kind == "none":
        return lambda upd, key: upd
    if spec.kind == "topk":
        k = max(1, int(round(spec.frac * dim)))

        def topk(upd, key):
            mag = jnp.abs(upd)
            thr = jax.lax.top_k(mag, k)[0][:, -1:]          # (C, 1)
            return jnp.where(mag >= thr, upd, 0.0)

        return topk
    if spec.kind == "randk":
        def randk(upd, key):
            keep = jax.random.bernoulli(key, spec.frac, upd.shape)
            return jnp.where(keep, upd / spec.frac, 0.0)

        return randk

    levels = float(2 ** spec.bits - 1)

    def qsgd(upd, key):
        scale = jnp.max(jnp.abs(upd), axis=1, keepdims=True)  # (C, 1)
        y = jnp.abs(upd) / jnp.maximum(scale, 1e-30) * levels
        lo = jnp.floor(y)
        lvl = lo + (jax.random.uniform(key, upd.shape) < (y - lo))
        return jnp.where(scale > 0.0,
                         jnp.sign(upd) * scale * lvl / levels, 0.0)

    return qsgd
