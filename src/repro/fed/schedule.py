"""Communication schedules: WHEN chains exchange state with the server.

The paper's delayed-communication experiments (Figs. 2-3) vary how many
local updates a chain takes between reassignments; FA-LD and the partial-
participation federated-Langevin literature add client sampling on top.
:class:`CommSchedule` makes that axis declarative, and the chain engine
lowers it to per-round boolean operands *inside* the jitted
``lax.scan`` — no host round-trips, no retrace per scenario:

  * ``delay``         — chains communicate (are reassigned, and exchange
    compressed payloads) only every ``delay``-th round; in between they
    stay resident on their client, so ``delay=k`` with ``local_steps=T``
    behaves like ``k*T`` local updates per communication (the Fig. 2-3
    x-axis, expressed as a schedule instead of a rewired loop).
  * ``participation`` — at each communication round every chain
    participates independently with this probability (partial
    participation / client sampling); non-participating chains keep
    their client and skip the payload exchange. Round 0 always has full
    participation so every chain gets an initial assignment.
  * ``straggler_prob`` — per round, each chain's update is DROPPED with
    this probability (the client failed to return in time): its state
    does not advance and its trace repeats the pre-round position.

The identity schedule (``delay=1, participation=1, straggler_prob=0``)
lowers to *nothing*: the engine elides every mask and stays bit-identical
to the oracle round body.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Declarative communication cadence for the chain engine."""
    delay: int = 1
    participation: float = 1.0
    straggler_prob: float = 0.0

    def __post_init__(self):
        assert self.delay >= 1, self.delay
        assert 0.0 < self.participation <= 1.0, self.participation
        assert 0.0 <= self.straggler_prob < 1.0, self.straggler_prob

    @property
    def identity(self) -> bool:
        """True iff lowering this schedule adds no ops to the round body."""
        return (self.delay == 1 and self.participation >= 1.0
                and self.straggler_prob <= 0.0)


def comm_mask(sched: CommSchedule, r: jax.Array) -> jax.Array:
    """Scalar bool: does communication happen at (traced) round ``r``?
    Round 0 always communicates (r % delay == 0 holds at r=0)."""
    return (r % sched.delay) == 0


def participation_mask(sched: CommSchedule, key: jax.Array, r: jax.Array,
                       n_chains: int) -> jax.Array:
    """(n_chains,) bool participation draws for one round; forced all-True
    at round 0 so every chain receives an initial assignment."""
    if sched.participation >= 1.0:
        return jnp.ones((n_chains,), bool)
    draw = jax.random.bernoulli(key, sched.participation, (n_chains,))
    return draw | (r == 0)


def straggler_mask(sched: CommSchedule, key: jax.Array,
                   n_chains: int) -> jax.Array:
    """(n_chains,) bool — True where the chain's round update is dropped."""
    return jax.random.bernoulli(key, sched.straggler_prob, (n_chains,))
