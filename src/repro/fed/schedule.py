"""Communication schedules: WHEN chains exchange state with the server.

The paper's delayed-communication experiments (Figs. 2-3) vary how many
local updates a chain takes between reassignments; FA-LD and the partial-
participation federated-Langevin literature add client sampling on top.
:class:`CommSchedule` makes that axis declarative, and the chain engine
lowers it to per-round boolean operands *inside* the jitted
``lax.scan`` — no host round-trips, no retrace per scenario:

  * ``delay``         — chains communicate (are reassigned, and exchange
    compressed payloads) only every ``delay``-th round; in between they
    stay resident on their client, so ``delay=k`` with ``local_steps=T``
    behaves like ``k*T`` local updates per communication (the Fig. 2-3
    x-axis, expressed as a schedule instead of a rewired loop).
  * ``participation`` — at each communication round every chain
    participates independently with this probability (partial
    participation / client sampling); non-participating chains keep
    their client and skip the payload exchange. Round 0 always has full
    participation so every chain gets an initial assignment.
  * ``straggler_prob`` — per round, each chain's update is DROPPED with
    this probability (the client failed to return in time): its state
    does not advance and its trace repeats the pre-round position.

The identity schedule (``delay=1, participation=1, straggler_prob=0``)
lowers to *nothing*: the engine elides every mask and stays bit-identical
to the oracle round body.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Declarative communication cadence for the chain engine."""
    delay: int = 1
    participation: float = 1.0
    straggler_prob: float = 0.0

    def __post_init__(self):
        assert self.delay >= 1, self.delay
        assert 0.0 < self.participation <= 1.0, self.participation
        assert 0.0 <= self.straggler_prob < 1.0, self.straggler_prob

    @property
    def identity(self) -> bool:
        """True iff lowering this schedule adds no ops to the round body."""
        return (self.delay == 1 and self.participation >= 1.0
                and self.straggler_prob <= 0.0)


def comm_mask(sched: CommSchedule, r: jax.Array) -> jax.Array:
    """Scalar bool: does communication happen at (traced) round ``r``?
    Round 0 always communicates (r % delay == 0 holds at r=0)."""
    return (r % sched.delay) == 0


def participation_mask(sched: CommSchedule, key: jax.Array, r: jax.Array,
                       n_chains: int) -> jax.Array:
    """(n_chains,) bool participation draws for one round; forced all-True
    at round 0 so every chain receives an initial assignment."""
    if sched.participation >= 1.0:
        return jnp.ones((n_chains,), bool)
    draw = jax.random.bernoulli(key, sched.participation, (n_chains,))
    return draw | (r == 0)


def straggler_mask(sched: CommSchedule, key: jax.Array,
                   n_chains: int) -> jax.Array:
    """(n_chains,) bool — True where the chain's round update is dropped."""
    return jax.random.bernoulli(key, sched.straggler_prob, (n_chains,))


# ---------------------------------------------------------------------------
# Resident-set planning for the streamed client axis.
#
# The streamed runtime (core/engine.py) keeps only a K-client resident
# window on device and prefetches the next window while the current scan
# segment runs. Which clients a segment needs is fully determined by the
# engine's RNG chain: ``replay_sids`` re-runs EXACTLY the per-round key
# splits and (for federated runs) the comm/participation masks of the
# scanned round bodies — using the very ``comm_mask``/``participation_mask``
# functions the engine lowers — so the plan can never drift from the
# in-scan assignment. ``plan_stream`` then slices the assignment into
# fixed-length windows and emits one sorted, tail-padded resident id set
# per window.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamWindow:
    """One prefetch unit of a streamed run.

    ``resident_ids`` is (resident,) int32, sorted ascending, tail-padded by
    repeating the largest id so every window has identical shape (one
    compiled executor per window *length*, not per window). Padding with a
    repeated real id keeps the in-scan global->local remap
    (sum of ``resident_ids < sid``) exact for every real id.
    """
    r0: int
    length: int
    resident_ids: np.ndarray

    def __post_init__(self):
        assert self.length >= 1, self.length
        ids = np.asarray(self.resident_ids)
        assert ids.ndim == 1 and ids.dtype == np.int32, (ids.shape, ids.dtype)


def replay_sids(key: jax.Array, *, num_rounds: int, n_chains: int,
                num_shards: int, federated: bool = False,
                sched: Optional[CommSchedule] = None,
                reassign: str = "permutation") -> np.ndarray:
    """(num_rounds, n_chains) int32 — the client id each REAL chain holds at
    every round, replayed from the engine's executor RNG chain.

    ``key`` must be the exact key the engine passes to its compiled
    executor for round 0 (the streamed ``run`` threads the returned key
    between segments, so one replay from round 0 covers every segment).
    Only ``reassign='permutation'`` is replayable/supported — the streamed
    runtime refuses other modes before ever calling this.
    """
    if reassign != "permutation":
        raise ValueError(
            f"replay_sids supports reassign='permutation' only, got "
            f"{reassign!r} (the streamed runtime refuses other modes)")
    sched = CommSchedule() if sched is None else sched
    use_part = sched.participation < 1.0
    reps = -(-n_chains // num_shards)  # ceil — block-cyclic tiling

    def tiled(k_assign):
        perm = jax.random.permutation(k_assign, num_shards)
        if reps > 1:
            perm = jnp.tile(perm, reps)
        return perm[:n_chains].astype(jnp.int32)

    rounds = jnp.arange(num_rounds, dtype=jnp.int32)

    if not federated:
        # round_body: key, k_assign, k_run = split(key, 3); fresh sids.
        def body(k, _r):
            k, k_assign, _ = jax.random.split(k, 3)
            return k, tiled(k_assign)

        _, sids = jax.lax.scan(body, key, rounds)
    else:
        # fed_round_body: key, k_assign, k_run, k_fed = split(key, 4);
        # sids carried, exchanged only where comm & participation.
        def body(carry, r):
            k, sids = carry
            k, k_assign, _, k_fed = jax.random.split(k, 4)
            new = tiled(k_assign)
            comm = comm_mask(sched, r)
            if use_part:
                part = participation_mask(
                    sched, jax.random.fold_in(k_fed, 0), r, n_chains)
                exch = comm & part
            else:
                exch = jnp.broadcast_to(comm, (n_chains,))
            sids = jnp.where(exch, new, sids)
            return (k, sids), sids

        sids0 = jnp.zeros((n_chains,), jnp.int32)
        _, sids = jax.lax.scan(body, (key, sids0), rounds)

    return np.asarray(jax.device_get(sids), np.int32)


def plan_stream(sids: np.ndarray, *, resident: int,
                window: int = 1) -> list:
    """Slice a replayed (R, n_chains) assignment into ``StreamWindow``s.

    Raises an actionable error naming the minimum viable ``resident`` when
    any window needs more distinct clients than fit on device.
    """
    sids = np.asarray(sids)
    assert sids.ndim == 2 and sids.shape[0] >= 1, sids.shape
    if window < 1:
        raise ValueError(f"stream window must be >= 1, got {window}")
    num_rounds = sids.shape[0]
    blocks = [(r0, sids[r0:r0 + window]) for r0 in range(0, num_rounds,
                                                         window)]
    need = max(np.unique(blk).size for _, blk in blocks)
    if need > resident:
        raise ValueError(
            f"stream plan needs up to {need} distinct resident clients per "
            f"{window}-round window but Stream(resident={resident}); raise "
            f"resident to at least {need}, or shrink the window / chain "
            f"count")
    out = []
    for r0, blk in blocks:
        ids = np.unique(blk).astype(np.int32)  # sorted ascending
        pad = np.full((resident - ids.size,), ids[-1], np.int32)
        out.append(StreamWindow(r0=r0, length=int(blk.shape[0]),
                                resident_ids=np.concatenate([ids, pad])))
    return out
