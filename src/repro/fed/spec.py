"""The composed federation scenario spec.

One :class:`Federation` names a complete scenario along the three axes
this package provides: WHERE the data lives (:class:`PartitionSpec` —
applied host-side, once), WHEN chains communicate
(:class:`CommSchedule`) and WHAT crosses the wire
(:class:`Compression`) — the latter two lowered by the chain engine to
operands inside its jitted ``lax.scan``. The identity spec lowers to
nothing and is bit-identical to the oracle round body.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.fed.compress import Compression
from repro.fed.partition import PartitionSpec
from repro.fed.schedule import CommSchedule


@dataclasses.dataclass(frozen=True)
class Stream:
    """The streamed client axis: HOW MANY clients are resident on device.

    Only ``resident`` clients live on device at a time; the engine plans
    which clients each fixed-length ``window`` of rounds needs (from the
    same RNG chain the scan lowers — ``repro.fed.schedule.replay_sids``)
    and, with ``prefetch=True``, stages the next window's shards onto the
    device while the current scan segment runs. Fault-free streamed runs
    are bitwise-identical to the resident path on configs both support.

    ``resident`` must cover the distinct clients any single window can
    touch (at most ``n_chains * window``; the planner names the minimum
    viable value when it refuses). Fixed ``window`` keeps the number of
    compiled executor variants at <= 2 (full windows + one tail).
    """
    resident: int
    window: int = 1
    prefetch: bool = True

    def __post_init__(self):
        assert self.resident >= 1, self.resident
        assert self.window >= 1, self.window


@dataclasses.dataclass(frozen=True)
class Federation:
    """A complete federation scenario (hashable: engine executors cache
    per spec)."""
    partition: Optional[PartitionSpec] = None
    schedule: CommSchedule = CommSchedule()
    compression: Compression = Compression()

    @property
    def engine_identity(self) -> bool:
        """True iff the ENGINE-side pieces (schedule + compression) add
        no ops to the round body — the partition axis is host-side and
        never touches the scan."""
        return self.schedule.identity and self.compression.identity

    @property
    def identity(self) -> bool:
        return self.partition is None and self.engine_identity
