"""Hierarchical cross-silo -> cross-device aggregation for the streamed
client axis.

At ~10^6 clients server-side reductions cannot touch every client in one
flat pass: the streamed runtime (core/engine.py) only ever has the
resident window's clients on device. Aggregation therefore runs in two
tiers:

  * cross-DEVICE — inside a resident window, over the clients/chains that
    are actually on the mesh. For FA-LD server averaging this tier is the
    engine's existing masked ``psum`` over the ``data`` axis inside the
    scanned round body: it only ever reads the participating chains, so
    it composes with streaming unchanged (and stays bitwise identical to
    the resident path — proven in tests/test_stream.py).
  * cross-SILO — host-side, over per-silo partial aggregates. Client
    metadata reductions (the partition-aware ``shard_probs`` presets,
    client-count normalizations) run here in bounded-memory blocks so a
    10^6-client reduction never materializes more than one silo of
    intermediates at float64.

The helpers below implement the host tier. They are deliberately numpy:
the quantities they reduce (sizes, probabilities, per-silo sums) are
planner inputs, not traced values.
"""
from __future__ import annotations

import numpy as np

# Default clients per silo for the host-tier reductions. Any value gives
# the same result up to float64 associativity (tested against the flat
# reduction in tests/test_stream.py); the default bounds the working set
# to ~0.5 MB per silo at 10^6 clients.
SILO = 65536


def silo_slices(n: int, silo: int = SILO):
    """Yield (start, stop) blocks covering [0, n) in silo-sized runs."""
    if silo < 1:
        raise ValueError(f"silo size must be >= 1, got {silo}")
    for start in range(0, n, silo):
        yield start, min(start + silo, n)


def hierarchical_sum(x, silo: int = SILO) -> float:
    """Two-tier sum: per-silo float64 partial sums, then a sum across the
    silo partials — the cross-silo leg of a streamed-axis reduction."""
    x = np.asarray(x)
    partials = [np.sum(x[a:b], dtype=np.float64)
                for a, b in silo_slices(x.shape[0], silo)]
    return float(np.sum(np.asarray(partials, np.float64)))


def hierarchical_mean(values, weights=None, silo: int = SILO) -> float:
    """Weighted mean via per-silo (sum w*v, sum w) partials.

    This is the server-averaging shape of the streamed axis: each silo
    contributes one (numerator, denominator) pair and the server combines
    pairs, never the raw per-client values.
    """
    v = np.asarray(values, np.float64)
    w = (np.ones_like(v) if weights is None
         else np.asarray(weights, np.float64))
    if v.shape[0] != w.shape[0]:
        raise ValueError(f"values/weights length mismatch: "
                         f"{v.shape[0]} != {w.shape[0]}")
    num = den = 0.0
    for a, b in silo_slices(v.shape[0], silo):
        num += float(np.sum(w[a:b] * v[a:b]))
        den += float(np.sum(w[a:b]))
    if den == 0.0:
        raise ValueError("hierarchical_mean: all weights are zero")
    return num / den


def normalize_hierarchical(x, silo: int = SILO) -> np.ndarray:
    """x / sum(x) with the denominator from ``hierarchical_sum`` — the
    normalization step of the partition-aware ``shard_probs`` presets.
    Returns float32 (the engine's f_s dtype); raises on a zero total."""
    x = np.asarray(x, np.float64)
    total = hierarchical_sum(x, silo)
    if total <= 0.0:
        raise ValueError(
            f"cannot normalize to probabilities: total is {total}")
    return (x / total).astype(np.float32)
