"""Named federation scenarios: the paper's configurations as a registry.

Benchmarks, examples, and the CI ``scenario-matrix`` lane enumerate
scenarios BY NAME so "the delayed-communication regime where DSGLD
diverges" is one string, not a hand-rolled loop. ``get_scenario``
accepts a name or passes a :class:`Federation` through unchanged, so
every facade entry point takes either.
"""
from __future__ import annotations

import difflib

from repro.fed.compress import Compression
from repro.fed.partition import PartitionSpec
from repro.fed.schedule import CommSchedule
from repro.fed.spec import Federation

SCENARIOS = {
    # the control: no partition override, every-round exact communication
    "identity": Federation(),
    # partition axis (host-side; data passed to the facade must be POOLED)
    "iid": Federation(partition=PartitionSpec(kind="iid")),
    "dirichlet-0.1": Federation(
        partition=PartitionSpec(kind="dirichlet", alpha=0.1)),
    "dirichlet-100": Federation(
        partition=PartitionSpec(kind="dirichlet", alpha=100.0)),
    "quantity-0.5": Federation(
        partition=PartitionSpec(kind="quantity", alpha=0.5)),
    "covariate": Federation(partition=PartitionSpec(kind="covariate")),
    # communication-schedule axis (in-scan)
    "delayed-5x": Federation(schedule=CommSchedule(delay=5)),
    "delayed-10x": Federation(schedule=CommSchedule(delay=10)),
    "delayed-100x": Federation(schedule=CommSchedule(delay=100)),
    "partial-50%": Federation(schedule=CommSchedule(participation=0.5)),
    "straggler-10%": Federation(
        schedule=CommSchedule(straggler_prob=0.1)),
    # compressed-rounds axis (in-scan, error feedback on)
    "topk-1%": Federation(compression=Compression(kind="topk", frac=0.01)),
    "randk-10%": Federation(
        compression=Compression(kind="randk", frac=0.10)),
    "qsgd-8bit": Federation(compression=Compression(kind="qsgd", bits=8)),
    # ELF-style leg selection: dual compresses the server->client
    # broadcast, bidir compresses both legs with independent EF state
    "elf-dual-topk-1%": Federation(
        compression=Compression(kind="topk", frac=0.01, direction="dual")),
    "elf-bidir-topk-1%": Federation(
        compression=Compression(kind="topk", frac=0.01, direction="bidir")),
    "elf-bidir-randk-10%": Federation(
        compression=Compression(kind="randk", frac=0.10,
                                direction="bidir")),
    "elf-bidir-qsgd-8bit": Federation(
        compression=Compression(kind="qsgd", bits=8, direction="bidir")),
}


def scenario_names() -> tuple:
    """All registry names, stable order (the CI matrix iterates this)."""
    return tuple(SCENARIOS)


def get_scenario(name_or_spec) -> Federation:
    """Resolve a registry name to its spec; pass Federation through."""
    if isinstance(name_or_spec, Federation):
        return name_or_spec
    try:
        return SCENARIOS[name_or_spec]
    except (KeyError, TypeError):
        near = difflib.get_close_matches(str(name_or_spec),
                                         scenario_names(), n=1)
        hint = f" (did you mean {near[0]!r}?)" if near else ""
        raise KeyError(
            f"unknown federation scenario {name_or_spec!r}{hint}; "
            f"available: {', '.join(scenario_names())}") from None
