"""Declarative non-IID partitioners: pooled data -> padded client shards.

The paper's scenarios start from HOW the data lands on clients. These
splitters take POOLED data (a dict/pytree of (N, ...) arrays, e.g. the
``data/synthetic.py`` generators before sharding) and produce the
engine's shard format — stacked (S, max_n, ...) leaves padded to the
longest client via ``core.engine.pad_shards`` (NaN pad rows, provably
dead) plus the true per-client ``sizes``:

  * 'iid'       — uniform random equal split (the control scenario).
  * 'dirichlet' — Dirichlet(alpha) LABEL skew (Hsu et al.): each class's
    examples are divided among clients by a per-class Dirichlet draw;
    low alpha => each client is dominated by few classes.
  * 'quantity'  — Dirichlet(alpha) QUANTITY skew: clients hold the same
    distribution but very different amounts of data (ragged shards).
  * 'covariate' — covariate shift: examples sorted by their principal
    feature direction and split contiguously, so clients see disjoint
    regions of input space (feature skew without labels).

Partitioning is host-side, once, before sampling — the schedules and
compression operators in this package are the in-scan pieces.
"""
from __future__ import annotations

import dataclasses
import difflib

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.hierarchy import normalize_hierarchical


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """How pooled data is split onto clients."""
    kind: str = "iid"
    num_shards: int = 10
    alpha: float = 0.5          # Dirichlet concentration (dirichlet/quantity)
    label_key: str = "y"        # dirichlet: which field carries the labels
    feature_key: str = "x"      # covariate: which field carries the inputs
    min_size: int = 2           # every client keeps at least this many rows
    seed: int = 0               # partition RNG (independent of sampling)

    def __post_init__(self):
        assert self.kind in ("iid", "dirichlet", "quantity", "covariate"), \
            self.kind
        assert self.num_shards >= 1 and self.min_size >= 1


def _take(data, idx):
    return jax.tree.map(lambda a: np.asarray(a)[idx], data)


def _rebalance(assign: list, min_size: int) -> list:
    """Move rows from the largest clients until every client holds at
    least ``min_size`` (tiny Dirichlet draws can empty a client; an empty
    shard would break the S-axis stacking and the N_s/(f_s m) unbiasing)."""
    assign = [list(a) for a in assign]
    while True:
        small = min(range(len(assign)), key=lambda s: len(assign[s]))
        if len(assign[small]) >= min_size:
            return [np.asarray(a, np.int64) for a in assign]
        big = max(range(len(assign)), key=lambda s: len(assign[s]))
        assert len(assign[big]) > min_size, "not enough rows to rebalance"
        assign[small].append(assign[big].pop())


def _pooled_n(data) -> int:
    return int(jax.tree.leaves(data)[0].shape[0])


def iid_partition(key, data, spec: PartitionSpec):
    """Uniform random equal split (drops the < S remainder)."""
    N, S = _pooled_n(data), spec.num_shards
    perm = np.asarray(jax.random.permutation(key, N))
    per = N // S
    assert per >= spec.min_size, (N, S)
    return [perm[s * per:(s + 1) * per] for s in range(S)]


def dirichlet_label_skew(key, data, spec: PartitionSpec):
    """Per-class Dirichlet(alpha) proportions over clients; each class's
    (shuffled) examples are split by those proportions."""
    S = spec.num_shards
    labels = np.asarray(jax.tree.leaves(
        {spec.label_key: data[spec.label_key]})[0]).reshape(-1)
    classes = np.unique(labels)
    k_perm, k_dir = jax.random.split(key)
    # Dirichlet via normalized Gamma (the token_shards idiom)
    g = np.asarray(jax.random.gamma(
        k_dir, spec.alpha, (len(classes), S))) + 1e-12
    props = g / g.sum(1, keepdims=True)
    assign = [[] for _ in range(S)]
    for ci, c in enumerate(classes):
        idx = np.flatnonzero(labels == c)
        idx = idx[np.asarray(jax.random.permutation(
            jax.random.fold_in(k_perm, ci), len(idx)))]
        cuts = (np.cumsum(props[ci])[:-1] * len(idx)).astype(np.int64)
        for s, part in enumerate(np.split(idx, cuts)):
            assign[s].extend(part.tolist())
    return _rebalance(assign, spec.min_size)


def quantity_skew(key, data, spec: PartitionSpec):
    """Same distribution everywhere, Dirichlet(alpha)-skewed AMOUNTS."""
    N, S = _pooled_n(data), spec.num_shards
    assert N >= S * spec.min_size, (N, S, spec.min_size)
    k_perm, k_dir = jax.random.split(key)
    g = np.asarray(jax.random.gamma(k_dir, spec.alpha, (S,))) + 1e-12
    w = g / g.sum()
    sizes = np.maximum((w * N).astype(np.int64), spec.min_size)
    # trim the largest STILL-above-min clients until the sizes fit back
    # into N — the min_size floor holds for every client (feasible by
    # the assert above)
    while sizes.sum() > N:
        big = int(np.argmax(np.where(sizes > spec.min_size, sizes, -1)))
        assert sizes[big] > spec.min_size
        sizes[big] -= 1
    perm = np.asarray(jax.random.permutation(k_perm, N))
    cuts = np.cumsum(sizes)[:-1]
    return list(np.split(perm[:int(sizes.sum())], cuts))


def covariate_shift(key, data, spec: PartitionSpec):
    """Sort by the principal direction of the features and split
    contiguously: client s sees the s-th slice of input space."""
    N, S = _pooled_n(data), spec.num_shards
    x = np.asarray(data[spec.feature_key], np.float64).reshape(N, -1)
    xc = x - x.mean(0)
    # one power-iteration pass is plenty for a split direction
    v = np.asarray(jax.random.normal(key, (xc.shape[1],), jnp.float32),
                   np.float64)
    for _ in range(8):
        v = xc.T @ (xc @ v)
        v /= np.linalg.norm(v) + 1e-30
    order = np.argsort(xc @ v, kind="stable")
    per = N // S
    assert per >= spec.min_size, (N, S)
    return [order[s * per:(s + 1) * per] for s in range(S)]


_KINDS = {
    "iid": iid_partition,
    "dirichlet": dirichlet_label_skew,
    "quantity": quantity_skew,
    "covariate": covariate_shift,
}


def partition(key: jax.Array, data, spec: PartitionSpec):
    """Pooled pytree -> (padded shard_data, sizes) in the engine format.

    ``key`` may be None: the spec's own ``seed`` then drives the split
    (partition randomness is deliberately independent of the sampling
    stream, so changing the scenario never perturbs the chains' RNG).
    """
    from repro.core.engine import pad_shards  # lazy: engine imports us not
    if key is None:
        key = jax.random.PRNGKey(spec.seed)
    idx_per_client = _KINDS[spec.kind](key, data, spec)
    shards = [_take(data, np.sort(np.asarray(idx, np.int64)))
              for idx in idx_per_client]
    stacked, sizes = pad_shards(shards)
    return stacked, sizes


# ---------------------------------------------------------------------------
# partition-aware shard_probs presets (paper Eq. 4's f_s selection probs)
# ---------------------------------------------------------------------------

SHARD_PROB_PRESETS = {
    # f_s = 1/S — the paper's default; identical values to probs=None.
    "uniform": lambda sizes: np.full(
        (len(sizes),), 1.0 / len(sizes), np.float32),
    # f_s = N_s / N — visits proportional to data held, so the DSGLD
    # unbiasing factor N_s/(f_s m) = N/m is the SAME for every client
    # (the variance-minimizing choice under quantity skew).
    "size-proportional": lambda sizes: normalize_hierarchical(
        np.asarray(sizes, np.float64)),
    # f_s ∝ sqrt(N_s) — the compromise between uniform exploration and
    # size-proportional visit rates for heavy-tailed client sizes.
    "sqrt-size": lambda sizes: normalize_hierarchical(
        np.sqrt(np.asarray(sizes, np.float64))),
}


def shard_prob_preset_names():
    return sorted(SHARD_PROB_PRESETS)


def resolve_shard_probs(name_or_probs, sizes) -> np.ndarray:
    """Resolve a ``shard_probs`` preset name (or pass explicit probs
    through) to an (S,) float32 array normalized against the TRUE client
    sizes. Unknown names get the registry error contract: a KeyError with
    a did-you-mean hint and the available names."""
    if not isinstance(name_or_probs, str):
        return np.asarray(name_or_probs, np.float32)
    try:
        fn = SHARD_PROB_PRESETS[name_or_probs]
    except KeyError:
        near = difflib.get_close_matches(str(name_or_probs),
                                         shard_prob_preset_names(), n=1)
        hint = f" (did you mean {near[0]!r}?)" if near else ""
        raise KeyError(
            f"unknown shard_probs preset {name_or_probs!r}{hint}; "
            f"available: {', '.join(shard_prob_preset_names())}") from None
    return fn(np.asarray(sizes))


# ---------------------------------------------------------------------------
# lazy client sources: the streamed-axis data contract
# ---------------------------------------------------------------------------
#
# A *client source* replaces the materialize-all (S, max_n, ...) stacked
# pytree when S is too large to hold: it answers ``rows(ids)`` for the
# resident subset only. Duck-typed (the engine never imports this module
# at class level): anything exposing
#
#     num_clients : int
#     sizes       : (S,) numpy int array — true per-client row counts
#     max_size    : int — the padded per-client row count
#     rows(ids)   : (K,) int array -> pytree of (K, max_size, ...) leaves
#
# is a client source. ``rows`` must be a pure function of ``ids`` — the
# streamed runtime calls it once per resident window, possibly again for
# the same window after a replan, and the resident-path oracle calls it
# with arange(S); determinism is what makes streamed == resident bitwise.


def is_client_source(obj) -> bool:
    return (hasattr(obj, "rows") and hasattr(obj, "num_clients")
            and hasattr(obj, "sizes") and hasattr(obj, "max_size"))


class SyntheticClientSource:
    """~10^6-client synthetic non-IID token data, generated per client on
    demand.

    Each client's unigram distribution is its OWN Dirichlet(alpha) draw
    derived by ``fold_in(key, client_id)`` — client c's rows are a pure
    function of (key, c), so any resident subset can be generated without
    touching the other clients (contrast ``data.synthetic.token_shards``,
    which draws the (S, vocab) logit matrix jointly and is therefore
    materialize-all by construction).
    """

    def __init__(self, key, *, num_clients: int, shard_size: int,
                 seq_len: int, vocab_size: int, alpha: float = 0.1):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.key = key
        self.num_clients = int(num_clients)
        self.shard_size = int(shard_size)
        self.seq_len = int(seq_len)
        self.vocab_size = int(vocab_size)
        self.alpha = float(alpha)
        self.sizes = np.full((self.num_clients,), self.shard_size,
                             np.int64)
        self.max_size = self.shard_size

        def one(cid):
            k = jax.random.fold_in(self.key, cid)
            k_dir, k_tok = jax.random.split(k)
            g = jax.random.gamma(k_dir, self.alpha, (self.vocab_size,))
            lp = jnp.log(g / g.sum() + 1e-20)
            t = jax.random.categorical(
                k_tok, lp, shape=(self.shard_size, self.seq_len + 1))
            return {"tokens": t[..., :-1].astype(jnp.int32),
                    "labels": t[..., 1:].astype(jnp.int32)}

        # one compile per distinct K (the streamed runtime uses a fixed
        # resident width, so in practice exactly one)
        self._rows = jax.jit(jax.vmap(one))

    def rows(self, ids):
        return self._rows(jnp.asarray(np.asarray(ids, np.int32)))


class PartitionedSource:
    """Lazy per-client shard construction over pooled data: the
    ``partition()`` split without the materialize-all stacking.

    The client->row index lists are computed once (cheap: O(N) host
    work); ``rows(ids)`` gathers and pads only the requested clients with
    ``pad_shards``'s exact fill semantics (NaN floats / int-min ints), so
    materializing arange(S) reproduces ``partition()``'s stacked output.
    """

    def __init__(self, data, spec: PartitionSpec, key=None):
        if key is None:
            key = jax.random.PRNGKey(spec.seed)
        self.data = jax.tree.map(np.asarray, data)
        self.spec = spec
        assign = _KINDS[spec.kind](key, data, spec)
        self._assign = [np.sort(np.asarray(a, np.int64)) for a in assign]
        self.num_clients = spec.num_shards
        self.sizes = np.asarray([len(a) for a in self._assign], np.int64)
        self.max_size = int(self.sizes.max())

    def rows(self, ids):
        def pad_one(leaf):
            out_shape = (len(ids), self.max_size) + leaf.shape[1:]
            if np.issubdtype(leaf.dtype, np.inexact):
                out = np.full(out_shape, np.nan, leaf.dtype)
            else:
                out = np.full(out_shape, np.iinfo(leaf.dtype).min,
                              leaf.dtype)
            for j, cid in enumerate(np.asarray(ids)):
                idx = self._assign[int(cid)]
                out[j, :len(idx)] = leaf[idx]
            return out

        return jax.tree.map(pad_one, self.data)
