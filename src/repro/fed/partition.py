"""Declarative non-IID partitioners: pooled data -> padded client shards.

The paper's scenarios start from HOW the data lands on clients. These
splitters take POOLED data (a dict/pytree of (N, ...) arrays, e.g. the
``data/synthetic.py`` generators before sharding) and produce the
engine's shard format — stacked (S, max_n, ...) leaves padded to the
longest client via ``core.engine.pad_shards`` (NaN pad rows, provably
dead) plus the true per-client ``sizes``:

  * 'iid'       — uniform random equal split (the control scenario).
  * 'dirichlet' — Dirichlet(alpha) LABEL skew (Hsu et al.): each class's
    examples are divided among clients by a per-class Dirichlet draw;
    low alpha => each client is dominated by few classes.
  * 'quantity'  — Dirichlet(alpha) QUANTITY skew: clients hold the same
    distribution but very different amounts of data (ragged shards).
  * 'covariate' — covariate shift: examples sorted by their principal
    feature direction and split contiguously, so clients see disjoint
    regions of input space (feature skew without labels).

Partitioning is host-side, once, before sampling — the schedules and
compression operators in this package are the in-scan pieces.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """How pooled data is split onto clients."""
    kind: str = "iid"
    num_shards: int = 10
    alpha: float = 0.5          # Dirichlet concentration (dirichlet/quantity)
    label_key: str = "y"        # dirichlet: which field carries the labels
    feature_key: str = "x"      # covariate: which field carries the inputs
    min_size: int = 2           # every client keeps at least this many rows
    seed: int = 0               # partition RNG (independent of sampling)

    def __post_init__(self):
        assert self.kind in ("iid", "dirichlet", "quantity", "covariate"), \
            self.kind
        assert self.num_shards >= 1 and self.min_size >= 1


def _take(data, idx):
    return jax.tree.map(lambda a: np.asarray(a)[idx], data)


def _rebalance(assign: list, min_size: int) -> list:
    """Move rows from the largest clients until every client holds at
    least ``min_size`` (tiny Dirichlet draws can empty a client; an empty
    shard would break the S-axis stacking and the N_s/(f_s m) unbiasing)."""
    assign = [list(a) for a in assign]
    while True:
        small = min(range(len(assign)), key=lambda s: len(assign[s]))
        if len(assign[small]) >= min_size:
            return [np.asarray(a, np.int64) for a in assign]
        big = max(range(len(assign)), key=lambda s: len(assign[s]))
        assert len(assign[big]) > min_size, "not enough rows to rebalance"
        assign[small].append(assign[big].pop())


def _pooled_n(data) -> int:
    return int(jax.tree.leaves(data)[0].shape[0])


def iid_partition(key, data, spec: PartitionSpec):
    """Uniform random equal split (drops the < S remainder)."""
    N, S = _pooled_n(data), spec.num_shards
    perm = np.asarray(jax.random.permutation(key, N))
    per = N // S
    assert per >= spec.min_size, (N, S)
    return [perm[s * per:(s + 1) * per] for s in range(S)]


def dirichlet_label_skew(key, data, spec: PartitionSpec):
    """Per-class Dirichlet(alpha) proportions over clients; each class's
    (shuffled) examples are split by those proportions."""
    S = spec.num_shards
    labels = np.asarray(jax.tree.leaves(
        {spec.label_key: data[spec.label_key]})[0]).reshape(-1)
    classes = np.unique(labels)
    k_perm, k_dir = jax.random.split(key)
    # Dirichlet via normalized Gamma (the token_shards idiom)
    g = np.asarray(jax.random.gamma(
        k_dir, spec.alpha, (len(classes), S))) + 1e-12
    props = g / g.sum(1, keepdims=True)
    assign = [[] for _ in range(S)]
    for ci, c in enumerate(classes):
        idx = np.flatnonzero(labels == c)
        idx = idx[np.asarray(jax.random.permutation(
            jax.random.fold_in(k_perm, ci), len(idx)))]
        cuts = (np.cumsum(props[ci])[:-1] * len(idx)).astype(np.int64)
        for s, part in enumerate(np.split(idx, cuts)):
            assign[s].extend(part.tolist())
    return _rebalance(assign, spec.min_size)


def quantity_skew(key, data, spec: PartitionSpec):
    """Same distribution everywhere, Dirichlet(alpha)-skewed AMOUNTS."""
    N, S = _pooled_n(data), spec.num_shards
    assert N >= S * spec.min_size, (N, S, spec.min_size)
    k_perm, k_dir = jax.random.split(key)
    g = np.asarray(jax.random.gamma(k_dir, spec.alpha, (S,))) + 1e-12
    w = g / g.sum()
    sizes = np.maximum((w * N).astype(np.int64), spec.min_size)
    # trim the largest STILL-above-min clients until the sizes fit back
    # into N — the min_size floor holds for every client (feasible by
    # the assert above)
    while sizes.sum() > N:
        big = int(np.argmax(np.where(sizes > spec.min_size, sizes, -1)))
        assert sizes[big] > spec.min_size
        sizes[big] -= 1
    perm = np.asarray(jax.random.permutation(k_perm, N))
    cuts = np.cumsum(sizes)[:-1]
    return list(np.split(perm[:int(sizes.sum())], cuts))


def covariate_shift(key, data, spec: PartitionSpec):
    """Sort by the principal direction of the features and split
    contiguously: client s sees the s-th slice of input space."""
    N, S = _pooled_n(data), spec.num_shards
    x = np.asarray(data[spec.feature_key], np.float64).reshape(N, -1)
    xc = x - x.mean(0)
    # one power-iteration pass is plenty for a split direction
    v = np.asarray(jax.random.normal(key, (xc.shape[1],), jnp.float32),
                   np.float64)
    for _ in range(8):
        v = xc.T @ (xc @ v)
        v /= np.linalg.norm(v) + 1e-30
    order = np.argsort(xc @ v, kind="stable")
    per = N // S
    assert per >= spec.min_size, (N, S)
    return [order[s * per:(s + 1) * per] for s in range(S)]


_KINDS = {
    "iid": iid_partition,
    "dirichlet": dirichlet_label_skew,
    "quantity": quantity_skew,
    "covariate": covariate_shift,
}


def partition(key: jax.Array, data, spec: PartitionSpec):
    """Pooled pytree -> (padded shard_data, sizes) in the engine format.

    ``key`` may be None: the spec's own ``seed`` then drives the split
    (partition randomness is deliberately independent of the sampling
    stream, so changing the scenario never perturbs the chains' RNG).
    """
    from repro.core.engine import pad_shards  # lazy: engine imports us not
    if key is None:
        key = jax.random.PRNGKey(spec.seed)
    idx_per_client = _KINDS[spec.kind](key, data, spec)
    shards = [_take(data, np.sort(np.asarray(idx, np.int64)))
              for idx in idx_per_client]
    stacked, sizes = pad_shards(shards)
    return stacked, sizes
