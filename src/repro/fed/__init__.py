"""Federation scenario subsystem: declarative non-IID partitioners,
communication schedules, and compressed rounds.

Three composable axes, all surfaced as one :class:`Federation` spec on
the ``repro.api`` facade (``FSGLD(..., federation=...)`` /
``FSGLD.sample(..., federation=...)``) and executed by the chain engine
*inside* its jitted scan:

  * :mod:`repro.fed.partition` — pooled data -> padded client shards
    (iid / Dirichlet label skew / quantity skew / covariate shift);
  * :mod:`repro.fed.schedule`  — delayed rounds, partial participation,
    straggler drops;
  * :mod:`repro.fed.compress`  — top-k / rand-k / stochastic
    quantization of round-boundary payloads with error feedback.

``repro.fed.registry`` names the paper's configurations (``iid``,
``dirichlet-0.1``, ``delayed-5x``, ``partial-50%``, ``topk-1%``, ...)
so benchmarks, examples, and CI enumerate scenarios by string.
"""
from repro.fed.compress import (Compression, make_compressor,
                                make_flattener)
from repro.fed.hierarchy import (hierarchical_mean, hierarchical_sum,
                                 normalize_hierarchical)
from repro.fed.partition import (PartitionSpec, PartitionedSource,
                                 SyntheticClientSource, is_client_source,
                                 partition, resolve_shard_probs,
                                 shard_prob_preset_names)
from repro.fed.registry import SCENARIOS, get_scenario, scenario_names
from repro.fed.schedule import (CommSchedule, StreamWindow, plan_stream,
                                replay_sids)
from repro.fed.spec import Federation, Stream

__all__ = [
    "Federation", "Stream", "PartitionSpec", "CommSchedule", "Compression",
    "partition", "make_compressor", "make_flattener",
    "SCENARIOS", "get_scenario", "scenario_names",
    "resolve_shard_probs", "shard_prob_preset_names",
    "SyntheticClientSource", "PartitionedSource", "is_client_source",
    "StreamWindow", "replay_sids", "plan_stream",
    "hierarchical_sum", "hierarchical_mean", "normalize_hierarchical",
]
