"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.

Griffin: RG-LRU recurrent blocks + local (sliding-window) attention, pattern
(recurrent, recurrent, local-attn). Sub-quadratic -> eligible for long_500k.
[arXiv:2402.19427]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,          # 18 rglru + 8 swa (period-3 pattern, 26 = 3*8 + 2)
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,         # MQA on the local-attention layers
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    ffn_type="geglu",
    layer_pattern=("rglru", "rglru", "swa"),
    swa_window=2048,
    source="arXiv:2402.19427",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=256, num_heads=2, num_kv_heads=1,
        head_dim=128, d_ff=512, vocab_size=512, swa_window=64,
    )
