"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

Cross-attention image layers every 5th layer; the vision encoder is a STUB per
assignment — input_specs() provides precomputed patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision, 90B variant]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    ffn_type="silu",
    layer_pattern=("attn", "attn", "attn", "attn", "xattn"),
    num_patches=6404,  # 4 tiles x 1601 patches (560px / 14 + cls)
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, num_patches=16,
        layer_pattern=("attn", "xattn"),
    )
