"""Architecture registry: ``--arch <id>`` resolution for the launcher."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, InputShape, MoEConfig, SamplerConfig
from repro.configs.shapes import SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K

_MODULES: Dict[str, str] = {
    "qwen3-1.7b": "qwen3_1_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "minitron-8b": "minitron_8b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "grok-1-314b": "grok_1_314b",
    "whisper-large-v3": "whisper_large_v3",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "gemma-7b": "gemma_7b",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_NAMES = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).smoke_config()


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


__all__ = [
    "ArchConfig", "InputShape", "MoEConfig", "SamplerConfig",
    "ARCH_NAMES", "SHAPES", "get_config", "get_smoke_config", "get_shape",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
