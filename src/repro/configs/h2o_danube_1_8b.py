"""h2o-danube-1.8b [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

llama+mistral mix with sliding-window attention. [arXiv:2401.16818]
Sub-quadratic via SWA -> eligible for long_500k decode.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    head_dim=80,
    layer_pattern=("swa",),
    swa_window=4096,
    ffn_type="silu",
    source="arXiv:2401.16818",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512, swa_window=64,
    )
