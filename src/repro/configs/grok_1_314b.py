"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.

MoE: 8 experts, top-2 routing. [hf:xai-org/grok-1]
"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    head_dim=128,
    ffn_type="geglu",
    moe=MoEConfig(num_experts=8, top_k=2),
    param_dtype="float32",
    source="hf:xai-org/grok-1",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2),
    )
