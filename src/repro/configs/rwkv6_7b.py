"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.

RWKV-6 "Finch": data-dependent diagonal decay linear recurrence, computed in
chunked linear-attention form. Sub-quadratic -> eligible for long_500k.
[arXiv:2404.05892]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,           # 64 heads x head_dim 64
    num_kv_heads=64,
    d_ff=14_336,
    vocab_size=65_536,
    head_dim=64,
    ffn_type="silu",
    layer_pattern=("rwkv",),
    source="arXiv:2404.05892",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, d_ff=512, vocab_size=512,
    )
