"""whisper-large-v3 [audio] — 32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.

Encoder-decoder; the mel-spectrogram + conv frontend is a STUB per assignment —
input_specs() provides 1500 precomputed frame embeddings. Decoder layers do
self-attention + cross-attention to the encoder output. [arXiv:2212.04356]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,          # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,        # MHA (no GQA in whisper)
    d_ff=5120,
    vocab_size=51_866,
    head_dim=64,
    ffn_type="gelu",
    layer_pattern=("xattn",),  # audio decoder layer = self-attn + cross-attn
    source="arXiv:2212.04356",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, encoder_seq=32, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512,
    )
