"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064.

MoE: 16 experts, top-2 routing. [hf:microsoft/Phi-3.5-MoE-instruct]
"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    head_dim=128,
    ffn_type="silu",
    moe=MoEConfig(num_experts=16, top_k=2),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2),
    )
