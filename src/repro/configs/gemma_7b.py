"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU FFN, head_dim=256 (q/kv projections 3072 -> 4096). [arXiv:2403.08295]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24_576,
    vocab_size=256_000,
    head_dim=256,
    ffn_type="geglu",
    source="arXiv:2403.08295",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        head_dim=64, d_ff=512, vocab_size=512,
    )
