"""Config dataclasses for architectures, input shapes, and sampler settings.

Every assigned architecture gets one ``<id>.py`` module in this package that
exposes ``CONFIG`` (the exact published configuration, cited) and
``smoke_config()`` (a reduced variant of the same family for CPU tests:
<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Family = str  # 'dense' | 'moe' | 'vlm' | 'audio' | 'hybrid' | 'ssm'


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Transformer-family architecture description.

    ``layer_pattern`` is the repeating per-period layer recipe used by the
    scan-over-layers model builder. Entries:
      'attn'   full self attention (GQA per num_kv_heads)
      'swa'    sliding-window self attention (window = swa_window)
      'rglru'  RG-LRU recurrent block (Griffin)
      'rwkv'   RWKV6 time-mix block
      'xattn'  cross attention to encoder/frontend embeddings
    A dense decoder layer is ('attn',); recurrentgemma is
    ('rglru','rglru','swa'); the VLM is ('attn',)*4 + ('xattn',).
    """

    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int
    source: str  # citation: hf model card or arXiv id

    ffn_type: str = "silu"  # 'silu' (SwiGLU) | 'geglu' | 'gelu'
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    layer_pattern: Tuple[str, ...] = ("attn",)
    swa_window: int = 4096
    moe: Optional[MoEConfig] = None

    # encoder-decoder (audio): number of encoder layers; decoder uses
    # num_layers. Encoder input is a stubbed frame-embedding sequence.
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper: 30 s of audio -> 1500 frames
    # vlm: number of stubbed image patch embeddings cross-attended to.
    num_patches: int = 0

    # sampler-facing knobs
    param_dtype: str = "float32"
    surrogate_dtype: str = "bfloat16"
    remat: bool = True

    def __post_init__(self):
        assert self.family in ("dense", "moe", "vlm", "audio", "hybrid", "ssm")
        for kind in self.layer_pattern:
            assert kind in ("attn", "swa", "rglru", "rwkv", "xattn"), kind
        if self.family == "moe":
            assert self.moe is not None

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer does full self attention over the sequence."""
        return all(k != "attn" for k in self.layer_pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d
        head = v * d  # untied output head
        n = 0
        per = {}
        per["attn"] = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        per["swa"] = per["attn"]
        per["xattn"] = per["attn"]
        per["rglru"] = 4 * d * d  # in/out projections + gates (approx.)
        per["rwkv"] = 4 * d * d + 6 * d  # r,k,v,o + decay/mix vectors (approx.)
        if self.ffn_type in ("silu", "geglu"):
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.moe is not None:
            ffn = self.moe.num_experts * ffn + d * self.moe.num_experts
        pat = self.layer_pattern
        for i in range(self.num_layers):
            n += per[pat[i % len(pat)]] + ffn + 2 * d  # + norms
        if self.encoder_layers:
            enc_ffn = 3 * d * f if self.ffn_type in ("silu", "geglu") else 2 * d * f
            n += self.encoder_layers * (per["attn"] + enc_ffn + 2 * d)
            # decoder cross-attn to encoder happens via 'xattn' entries
        return emb + head + n

    def active_param_count(self) -> int:
        """Params touched per token (MoE counts only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        dense_like = dataclasses.replace(self, family="dense", moe=None)
        d, f = self.d_model, self.d_ff
        ffn = 3 * d * f if self.ffn_type in ("silu", "geglu") else 2 * d * f
        extra = self.num_layers * ffn * (self.moe.top_k - 1)
        return dense_like.param_count() + extra


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# Above this client count an implicit uniform ``probs()`` tuple is not
# materialized (a ~10^6-element tuple costs tens of MB); ``probs()``
# returns None and consumers treat None as uniform 1/S
# (``core.sampler.ShardScheme`` lowers both spellings to the same fp32
# values, so the cutoff never changes results).
_PROBS_TUPLE_LIMIT = 65536


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """FSGLD / DSGLD / SGLD settings (paper Secs. 2-3).

    ``shard_probs`` may be a tuple, a numpy array (the streamed-client
    scale format — see ``repro.fed.partition.resolve_shard_probs`` for
    the named presets), or None for uniform f_s = 1/S.
    """

    method: str = "fsgld"  # 'sgld' | 'dsgld' | 'fsgld'
    step_size: float = 1e-4
    num_shards: int = 16
    shard_probs: Optional[Tuple[float, ...]] = None  # None -> uniform
    local_updates: int = 40  # T_local between reassignments (paper Sec 5.3)
    alpha: float = 1.0  # Remark 1 exploration knob; 0 recovers DSGLD
    surrogate: str = "diag"  # 'full' | 'diag' | 'scalar'
    prior_precision: float = 1.0  # N(0, lambda^-1 I) prior on params
    temperature: float = 1.0  # noise scale; 0 -> MAP/SGD limit

    def probs(self) -> Optional[Tuple[float, ...]]:
        if self.shard_probs is not None:
            assert len(self.shard_probs) == self.num_shards
            return self.shard_probs
        if self.num_shards > _PROBS_TUPLE_LIMIT:
            return None  # uniform, lowered lazily by ShardScheme
        return tuple(1.0 / self.num_shards for _ in range(self.num_shards))
