"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.

Pruned Nemotron. [arXiv:2407.14679]
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=256_000,
    head_dim=128,
    ffn_type="silu",
    source="arXiv:2407.14679",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
    )
