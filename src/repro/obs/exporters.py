"""MetricsFrame exporters: JSONL (round-major records) + Prometheus
textfile. Both are plain-text, append-friendly formats an operator can
tail / node-exporter can scrape; both round-trip losslessly enough to be
CI-gated (the JSONL reader rebuilds the frame bitwise at fp32)."""
from __future__ import annotations

import json

import numpy as np

from repro.obs.telemetry import MetricsFrame


def write_metrics_jsonl(frame: MetricsFrame, path: str) -> None:
    """One header record (names + shape) then one record per round with
    the per-chain fp32 values of every metric."""
    with open(path, "w") as f:
        f.write(json.dumps({
            "type": "header", "schema": "repro-metrics-v1",
            "names": list(frame.names), "rounds": frame.rounds,
            "chains": frame.n_chains}) + "\n")
        for r in range(frame.rounds):
            rec = {"type": "round", "round": r}
            for name in frame.names:
                rec[name] = [float(v) for v in frame.metrics[name][r]]
            f.write(json.dumps(rec) + "\n")


def read_metrics_jsonl(path: str) -> MetricsFrame:
    with open(path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    assert records and records[0].get("type") == "header", path
    head = records[0]
    assert head.get("schema") == "repro-metrics-v1", head.get("schema")
    names, rounds = head["names"], head["rounds"]
    rows = [r for r in records[1:] if r.get("type") == "round"]
    assert len(rows) == rounds, (len(rows), rounds)
    metrics = {
        n: np.asarray([rows[r][n] for r in range(rounds)], np.float32)
        for n in names}
    return MetricsFrame(metrics)


def write_prometheus(frame: MetricsFrame, path: str, *,
                     prefix: str = "fsgld") -> None:
    """Prometheus TEXTFILE format (node_exporter textfile collector):
    per-chain gauges of the FINAL round plus run-mean aggregates —
    the scrape-friendly projection of the frame (history stays in the
    JSONL)."""
    last = frame.last_round()
    mean = frame.summary()
    lines = [f"# HELP {prefix}_rounds_total communication rounds run",
             f"# TYPE {prefix}_rounds_total counter",
             f"{prefix}_rounds_total {frame.rounds}"]
    for name in frame.names:
        metric = f"{prefix}_{name}"
        lines.append(f"# HELP {metric} telemetry row '{name}' "
                     "(last round per chain; _mean = run mean)")
        lines.append(f"# TYPE {metric} gauge")
        for c, v in enumerate(last[name]):
            lines.append(f'{metric}{{chain="{c}"}} {float(v):.9g}')
        lines.append(f"{prefix}_{name}_mean {mean[name]:.9g}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def parse_prometheus(path: str) -> dict:
    """Parse a Prometheus textfile back to {metric_name: value} /
    {metric_name{labels}: value} floats — the CI smoke's format check."""
    out = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            key, val = line.rsplit(" ", 1)
            out[key] = float(val)
    assert out, f"no samples parsed from {path}"
    return out
