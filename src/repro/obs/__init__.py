"""Observability layer: in-scan sampler telemetry + host-side tracing.

Two complementary views of a run:

  * ``Telemetry`` / ``MetricsFrame`` (``repro.obs.telemetry``) — DEVICE
    facts: per-round per-chain metric rows lowered into the engine's
    scanned round body as extra scan outputs (grad/drift/conducive
    norms, noise scale, participation, wire bytes, health words).
    Telemetry-off runs are bitwise identical to today; telemetry-on
    probes draw from a ``fold_in``-salted key stream, so they are too.
  * ``trace`` (``repro.obs.trace``) — HOST facts: monotonic-clock spans
    and structured events (JSONL sink, optional ``jax.profiler``
    annotations) around engine segments, streamed-window prefetch,
    snapshot I/O, draw-bank refresh, and serving prefill/decode.

``exporters`` surfaces frames as JSONL and Prometheus textfiles for the
``train --metrics-dir`` / ``serve`` CLIs and the CI smoke gates.
"""
from repro.obs import trace
from repro.obs.exporters import (parse_prometheus, read_metrics_jsonl,
                                 write_metrics_jsonl, write_prometheus)
from repro.obs.telemetry import (TELEMETRY_PROBE_SALT, MetricsFrame,
                                 Telemetry)

__all__ = [
    "Telemetry", "MetricsFrame", "TELEMETRY_PROBE_SALT", "trace",
    "write_metrics_jsonl", "read_metrics_jsonl", "write_prometheus",
    "parse_prometheus",
]
