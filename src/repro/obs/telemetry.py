"""In-scan sampler telemetry: the ``Telemetry`` spec + ``MetricsFrame``.

The paper's claims are about dynamics over ROUNDS — conducive gradients
shrinking the estimator correction, delayed chains staying near the
posterior — so the metrics live where the rounds live: lowered INTO the
engine's scanned round body as extra scan outputs (core/engine.py), one
fp32 row per metric per round per chain. Everything is computed from
values the round body already holds (post-round state, exchange masks,
health words) plus one optional PROBE evaluation per round whose key is
``fold_in(k_run, TELEMETRY_PROBE_SALT)`` — the same stream-isolation
pattern as the health detector's ``HEALTH_PROBE_SALT``, so telemetry-on
runs are bitwise identical to telemetry-off runs.

Metric rows (all (rounds, chains) fp32 in the frame):

  theta_norm      ||theta|| at the round end.
  drift_norm      ||theta_end - theta_start|| over the round's T local
                  steps (per-round movement; collapses when a chain is
                  frozen by a straggler/quarantine mask).
  noise_scale     the nominal injected-noise std of one local step:
                  sqrt(h * tau) for Langevin (FA-LD's amplified
                  per-client tau included), sqrt(2 * friction * tau * h)
                  for SGHMC.
  conducive_norm  ||g_s(theta)|| — the paper's Eq. 5 correction at the
                  round-end state against the live surrogate bank
                  (zero when the method carries no surrogate).
  participation   1.0 when the chain exchanged with the server this
                  round (comm schedule AND participation draw AND not
                  quarantined), else 0.0; always 1.0 on the
                  identity/oracle path (every round reassigns).
  bytes_per_round participation * the wire-byte estimate of one
                  exchange, both legs (``Compression.bytes_per_round``;
                  8 bytes/coordinate for exact exchange).
  health_word     the recovery health word after this round's check
                  (0.0 = healthy; zeros when no Recovery policy).
  grad_norm       [probe] ||grad log_lik(theta, probe minibatch)|| at
                  the round-end state.
  log_post        [probe] log_lik(theta, probe minibatch)
                  - 0.5 * prior_precision * ||theta||^2 — the same
                  statistic the health detector probes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Probe-key salt: telemetry probes draw their minibatches from
# fold_in(k_run, SALT), never from the sampling stream — distinct from
# core.health.HEALTH_PROBE_SALT so the two probes are independent too.
TELEMETRY_PROBE_SALT = 0x0B5E7B

_BASE_NAMES = ("theta_norm", "drift_norm", "noise_scale",
               "conducive_norm", "participation", "bytes_per_round",
               "health_word")
_PROBE_NAMES = ("grad_norm", "log_post")


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """What the scanned round body measures (``Execution.telemetry``).

    ``probe=True`` adds the probe-batch metrics (grad_norm, log_post) —
    one extra likelihood value_and_grad per chain per ROUND, ~1/T of the
    round's gradient work. ``probe=False`` keeps only the closed-form
    metrics (no extra likelihood evaluations at all).

    ``log_every`` splits the run into that many-round segments and emits
    an ``engine.progress`` trace event after each (round counter,
    steps/s, per-metric means) — the periodic progress reporting
    ``launch/train.py --log-every`` surfaces. Segmentation threads the
    full carry through the executor I/O (the same mechanism snapshots
    use), so a segmented run stays bitwise identical to a one-shot run.

    Frozen/hashable: a Telemetry spec is part of the engine's executor
    cache key.
    """
    probe: bool = True
    log_every: Optional[int] = None

    def __post_init__(self):
        if self.log_every is not None and self.log_every < 1:
            raise ValueError(
                f"Telemetry.log_every must be >= 1, got {self.log_every}")

    @property
    def names(self) -> tuple:
        """Metric-row names in frame order — sorted, matching the
        key-sorted dict pytrees the executor's scan carries."""
        return tuple(sorted(
            _BASE_NAMES + (_PROBE_NAMES if self.probe else ())))


@dataclasses.dataclass
class MetricsFrame:
    """Round-major telemetry: ``metrics[name]`` is a (rounds, chains)
    fp32 array. The exporters (``repro.obs.exporters``) serialize it to
    JSONL (one record per round) and Prometheus textfile format."""
    metrics: dict

    @property
    def names(self) -> tuple:
        return tuple(self.metrics)

    @property
    def rounds(self) -> int:
        return int(next(iter(self.metrics.values())).shape[0])

    @property
    def n_chains(self) -> int:
        return int(next(iter(self.metrics.values())).shape[1])

    def __post_init__(self):
        assert self.metrics, "empty MetricsFrame"
        shape = next(iter(self.metrics.values())).shape
        for name, arr in self.metrics.items():
            assert arr.ndim == 2 and arr.shape == shape, (name, arr.shape)

    def summary(self) -> dict:
        """Per-metric mean over all rounds and chains (floats)."""
        return {n: float(np.mean(a)) for n, a in self.metrics.items()}

    def last_round(self) -> dict:
        """Per-metric (chains,) row of the final round."""
        return {n: np.asarray(a[-1]) for n, a in self.metrics.items()}

    @classmethod
    def concat(cls, frames: list) -> "MetricsFrame":
        """Stitch per-segment frames along the round axis."""
        assert frames, "nothing to concat"
        names = frames[0].names
        assert all(f.names == names for f in frames), \
            [f.names for f in frames]
        return cls({n: np.concatenate([f.metrics[n] for f in frames])
                    for n in names})
