"""Host-side tracing: monotonic-clock spans + structured events, JSONL.

The sampler's device work is one opaque scan dispatch; everything the
HOST does around it — staging streamed windows, snapshot I/O, draw-bank
refreshes, serving prefill/decode — is what this module makes visible.
One module-level tracer (disabled by default: every call is a no-op on a
shared null object, so instrumented code paths cost nothing when nobody
is watching), configured once per process by the CLI entry points::

    from repro.obs import trace
    trace.configure(path="run/trace.jsonl", echo=True)
    with trace.span("engine.segment", r0=0, rounds=8):
        ...
    trace.event("engine.progress", round=8, steps_per_s=1.2e5)

Span lines carry the WALL-clock start (``ts``, epoch seconds — for
cross-process alignment) and a MONOTONIC duration (``dur_s`` — immune to
clock steps), plus the nesting ``depth`` and ``parent`` span name from a
thread-local stack, so a reader can rebuild the span tree from the flat
JSONL. ``echo=True`` additionally prints one compact human line per
event — the structured replacement for the bare ``print``/``warnings``
progress messages the CLIs used to emit. ``profiler=True`` wraps every
span in a ``jax.profiler.TraceAnnotation`` so host spans line up with
device traces in the profiler UI.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "attrs", "t0", "ts", "depth", "parent",
                 "_prof")

    def __init__(self, tracer, name, attrs):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self._prof = None

    def __enter__(self):
        tls = self.tracer._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        self.depth = len(stack)
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self.ts = time.time()
        self.t0 = time.monotonic()
        if self.tracer.profiler:
            try:
                import jax
                self._prof = jax.profiler.TraceAnnotation(self.name)
                self._prof.__enter__()
            except Exception:  # noqa: BLE001 - annotations are best-effort
                self._prof = None
        return self

    def __exit__(self, *exc):
        dur = time.monotonic() - self.t0
        if self._prof is not None:
            self._prof.__exit__(*exc)
        self.tracer._tls.stack.pop()
        rec = {"type": "span", "name": self.name, "ts": self.ts,
               "dur_s": dur, "depth": self.depth, "parent": self.parent}
        rec.update(self.attrs)
        self.tracer._emit(rec)
        return False


class Tracer:
    """A span/event sink. ``path=None`` and ``echo=False`` disables it
    entirely (``span`` returns a shared no-op context manager)."""

    def __init__(self, path: Optional[str] = None, *, echo: bool = False,
                 profiler: bool = False):
        self.path = path
        self.echo = echo
        self.profiler = profiler
        self._fh = None
        self._lock = threading.Lock()
        self._tls = threading.local()

    @property
    def enabled(self) -> bool:
        return self.path is not None or self.echo

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs):
        if not self.enabled:
            return
        stack = getattr(self._tls, "stack", [])
        rec = {"type": "event", "name": name, "ts": time.time(),
               "depth": len(stack),
               "parent": stack[-1] if stack else None}
        rec.update(attrs)
        self._emit(rec)

    def _emit(self, rec: dict):
        line = json.dumps(rec, default=str)
        with self._lock:
            if self.path is not None:
                if self._fh is None:
                    self._fh = open(self.path, "a")
                self._fh.write(line + "\n")
                self._fh.flush()
            if self.echo:
                ts = time.strftime("%H:%M:%S", time.localtime(rec["ts"]))
                kv = " ".join(
                    f"{k}={rec[k]}" for k in rec
                    if k not in ("type", "name", "ts", "depth", "parent"))
                print(f"[{ts}] {rec['name']} {kv}".rstrip(), flush=True)

    def close(self):
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


_TRACER = Tracer()


def configure(path: Optional[str] = None, *, echo: bool = False,
              profiler: bool = False) -> Tracer:
    """Install the process-wide tracer (and return it). Call with no
    arguments to disable tracing again."""
    global _TRACER
    _TRACER.close()
    _TRACER = Tracer(path, echo=echo, profiler=profiler)
    return _TRACER


def get_tracer() -> Tracer:
    return _TRACER


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **attrs):
    """Context manager timing one named host-side segment."""
    return _TRACER.span(name, **attrs)


def event(name: str, **attrs):
    """One timestamped structured log line (no duration)."""
    _TRACER.event(name, **attrs)


def read_jsonl(path: str) -> list:
    """Parse a trace JSONL file back into a list of record dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
