"""Synthetic federated non-IID data generators.

The paper's datasets (UCI isolet / SUSY / concrete / noise / conductivity)
are not available offline (repro gate, DESIGN.md Sec 2); these generators
reproduce the *structure* each experiment relies on:

  * gaussian_shards     — Sec 5.1: S shards from N(mu_s, I), mu_s ~ U[-6,6]^2
  * metric_pairs        — Sec 5.2: isolet-like Gaussian class clusters,
                          class-DISJOINT shards of similar/dissimilar pairs
  * susy_shards         — Sec 5.3: binary classification, per-shard label
                          proportions pi_s ~ Beta(a, a)  (a=100 IID, 0.5 non-IID)
  * linreg_datasets     — App F.1: three regression datasets
  * token_shards        — LM-scale: per-client Dirichlet-skewed unigram
                          token distributions (federated non-IID text)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gaussian_shards(key, *, num_shards=10, shard_size=200, dim=2,
                    spread=6.0):
    k1, k2 = jax.random.split(key)
    mus = jax.random.uniform(k1, (num_shards, dim), minval=-spread,
                             maxval=spread)
    x = mus[:, None, :] + jax.random.normal(k2, (num_shards, shard_size,
                                                 dim))
    return {"x": x}, mus


def susy_shards(key, *, num_shards=30, shard_size=9_000, dim=18,
                beta_a=0.5, sep=1.2):
    """Label-imbalanced binary classification shards. Positive/negative
    class-conditional distributions are fixed Gaussians with mean
    separation ``sep``; shard s draws labels Bernoulli(pi_s),
    pi_s ~ Beta(beta_a, beta_a)."""
    k_pi, k_y, k_x, k_mu = jax.random.split(key, 4)
    mu_pos = jax.random.normal(k_mu, (dim,)) * 0.3 + sep / 2
    mu_neg = -mu_pos
    pi = jax.random.beta(k_pi, beta_a, beta_a, (num_shards,))
    y = (jax.random.uniform(k_y, (num_shards, shard_size))
         < pi[:, None]).astype(jnp.float32)
    noise = jax.random.normal(k_x, (num_shards, shard_size, dim))
    x = jnp.where(y[..., None] > 0.5, mu_pos, mu_neg) + noise
    return {"x": x, "y": y}, pi


def susy_test_set(key, *, size=10_000, dim=18, sep=1.2):
    data, _ = susy_shards(key, num_shards=1, shard_size=size, dim=dim,
                          beta_a=1e6, sep=sep)  # Beta(1e6,1e6) ~ balanced
    return {"x": data["x"][0], "y": data["y"][0]}


def metric_pairs(key, *, num_classes=26, dim=64, num_shards=10,
                 pairs_per_shard=1000, class_sep=2.0):
    """Isolet-like: Gaussian clusters per class; shards get class-DISJOINT
    pair sets (the paper's federated non-IID construction). Returns shards
    of (xi, xj, y) with y=1 similar (same class), y=0 dissimilar."""
    assert num_classes % num_shards == 0 or num_classes >= num_shards
    k_mu, k_x, k_pair = jax.random.split(key, 3)
    centers = jax.random.normal(k_mu, (num_classes, dim)) * class_sep
    per_shard = num_classes // num_shards

    def shard_pairs(s, k):
        classes = jnp.arange(per_shard) + s * per_shard
        kk = jax.random.split(k, 6)
        half = pairs_per_shard // 2
        # similar: two draws from the same class
        cs = classes[jax.random.randint(kk[0], (half,), 0, per_shard)]
        xi_s = centers[cs] + jax.random.normal(kk[1], (half, dim))
        xj_s = centers[cs] + jax.random.normal(kk[2], (half, dim))
        # dissimilar: two distinct classes within the shard
        c1 = classes[jax.random.randint(kk[3], (half,), 0, per_shard)]
        off = jax.random.randint(kk[4], (half,), 1, per_shard)
        c2 = classes[(c1 - classes[0] + off) % per_shard]
        xi_d = centers[c1] + jax.random.normal(kk[5], (half, dim))
        xj_d = centers[c2] + jax.random.normal(kk[0], (half, dim))
        xi = jnp.concatenate([xi_s, xi_d])
        xj = jnp.concatenate([xj_s, xj_d])
        y = jnp.concatenate([jnp.ones(half), jnp.zeros(half)])
        return {"xi": xi, "xj": xj, "y": y}

    keys = jax.random.split(k_pair, num_shards)
    shards = [shard_pairs(s, keys[s]) for s in range(num_shards)]
    data = jax.tree.map(lambda *xs: jnp.stack(xs), *shards)
    return data, centers


def metric_test_pairs(key, centers, *, num_pairs=1000):
    num_classes, dim = centers.shape
    kk = jax.random.split(key, 6)
    half = num_pairs // 2
    cs = jax.random.randint(kk[0], (half,), 0, num_classes)
    xi_s = centers[cs] + jax.random.normal(kk[1], (half, dim))
    xj_s = centers[cs] + jax.random.normal(kk[2], (half, dim))
    c1 = jax.random.randint(kk[3], (half,), 0, num_classes)
    c2 = (c1 + jax.random.randint(kk[4], (half,), 1, num_classes)) \
        % num_classes
    xi_d = centers[c1] + jax.random.normal(kk[5], (half, dim))
    xj_d = centers[c2] + jax.random.normal(kk[0], (half, dim))
    return {"xi": jnp.concatenate([xi_s, xi_d]),
            "xj": jnp.concatenate([xj_s, xj_d]),
            "y": jnp.concatenate([jnp.ones(half), jnp.zeros(half)])}


def linreg_datasets(key):
    """Three synthetic stand-ins for concrete/noise/conductivity:
    (name, n, d) matched; fixed true beta, heteroscedastic noise levels."""
    specs = [("concrete", 1030, 9, 0.3), ("noise", 1503, 6, 0.8),
             ("conductivity", 17389, 81, 0.5)]
    out = {}
    for i, (name, n, d, sig) in enumerate(specs):
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
        beta = jax.random.normal(k1, (d,))
        x = jax.random.normal(k2, (n, d))
        y = x @ beta + sig * jax.random.normal(k3, (n,))
        out[name] = {"x": x, "y": y, "beta": beta, "sigma": sig}
    return out


def split_shards(data, num_shards):
    """Split a dict of (N, ...) arrays into (S, N/S, ...) shard stacks."""
    def sp(a):
        n = a.shape[0] // num_shards * num_shards
        return a[:n].reshape(num_shards, -1, *a.shape[1:])
    return jax.tree.map(sp, data)


def token_shards(key, *, num_shards, shard_size, seq_len, vocab_size,
                 alpha=0.1):
    """Federated non-IID token streams: client s samples tokens from its own
    Dirichlet(alpha)-skewed unigram distribution. Low alpha => highly
    heterogeneous clients (the regime where conducive gradients matter)."""
    k_dir, k_tok = jax.random.split(key)
    # sample Dirichlet via normalized Gamma (jax.random.dirichlet exists but
    # this keeps memory bounded for 256k vocabs by sampling in fp32)
    logits = jax.random.gamma(k_dir, alpha, (num_shards, vocab_size))
    logp = jnp.log(logits / logits.sum(-1, keepdims=True) + 1e-20)
    toks = jax.vmap(
        lambda lp, k: jax.random.categorical(
            k, lp, shape=(shard_size, seq_len + 1)))(
        logp, jax.random.split(k_tok, num_shards))
    return {"tokens": toks[..., :-1].astype(jnp.int32),
            "labels": toks[..., 1:].astype(jnp.int32)}


def make_batch(cfg, shape, key=None, dtype=jnp.int32):
    """Concrete random batch for an (arch, input-shape) pair — used by the
    end-to-end examples; the dry-run uses launch.specs.input_specs."""
    key = key if key is not None else jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          dtype),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          dtype)}
    if cfg.family == "vlm":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch
