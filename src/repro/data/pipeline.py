"""Host-side federated input pipeline.

Production substrate for launch/train.py: per-client token streams with
epoch shuffling, client scheduling that follows the sampler's shard draws,
and double-buffered prefetch onto device. Pure numpy on the host side (the
guest containers feed from disk/network in reality); device puts happen one
batch ahead of consumption.
"""
from __future__ import annotations

import collections
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class ClientDataset:
    """One client's examples: dict of (N, ...) numpy arrays."""

    def __init__(self, data: dict, seed: int = 0):
        self.data = {k: np.asarray(v) for k, v in data.items()}
        self.n = next(iter(self.data.values())).shape[0]
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(self.n)
        self._cursor = 0

    def next_batch(self, m: int) -> dict:
        """Without-replacement batches with epoch reshuffling (the
        with-replacement variant in core/_minibatch matches the theory;
        epochs are the production-friendly choice — note in DESIGN.md)."""
        if self._cursor + m > self.n:
            self._order = self.rng.permutation(self.n)
            self._cursor = 0
        idx = self._order[self._cursor:self._cursor + m]
        self._cursor += m
        return {k: v[idx] for k, v in self.data.items()}


class FederatedPipeline:
    """Client-scheduled, prefetching batch stream.

    ``schedule`` yields client ids (the server's Categorical(f) draws);
    batches are staged to device one step ahead on a worker thread.
    """

    def __init__(self, clients: list, batch_size: int,
                 schedule: Iterator[int], prefetch: int = 2,
                 sharding: Optional[jax.sharding.Sharding] = None):
        self.clients = clients
        self.m = batch_size
        self.schedule = schedule
        self.sharding = sharding
        self._q = collections.deque()
        self._lock = threading.Lock()
        self._prefetch = prefetch
        self._fill()

    def _produce(self):
        s = next(self.schedule)
        host = self.clients[s].next_batch(self.m)
        if self.sharding is not None:
            dev = {k: jax.device_put(v, self.sharding)
                   for k, v in host.items()}
        else:
            dev = {k: jax.device_put(v) for k, v in host.items()}
        return s, dev

    def _fill(self):
        while len(self._q) < self._prefetch:
            self._q.append(self._produce())

    def __next__(self):
        with self._lock:
            s, batch = self._q.popleft()
            self._fill()
        return s, batch

    def __iter__(self):
        return self


def round_robin(num_clients: int) -> Iterator[int]:
    i = 0
    while True:
        yield i % num_clients
        i += 1


def categorical_schedule(probs, seed: int = 0) -> Iterator[int]:
    rng = np.random.default_rng(seed)
    probs = np.asarray(probs)
    while True:
        yield int(rng.choice(len(probs), p=probs))
