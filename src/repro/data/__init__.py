from repro.data.synthetic import (  # noqa: F401
    gaussian_shards,
    linreg_datasets,
    make_batch,
    metric_pairs,
    metric_test_pairs,
    split_shards,
    susy_shards,
    susy_test_set,
    token_shards,
)
