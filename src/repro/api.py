"""One FSGLD front door: the declarative sampler facade.

The paper's pitch is that conducive gradients are a *drop-in* correction
to DSGLD — one algorithm family parameterized by surrogate, schedule, and
execution (cf. FA-LD, arXiv:2112.05120; ELF, arXiv:2303.04622). This
module is that family's single entry point: every workload — the Sec 5.1
Gaussian toy, the BNN benchmarks, and the billion-parameter transformer
posterior — routes through the SAME mesh-parallel chain engine
(``repro.core.engine.MeshChainEngine``), so a new variant lands once, not
once per scale.

Four declarative pieces:

  * :class:`Posterior`     — log-likelihood + Gaussian prior + temperature.
  * :class:`SurrogateSpec` — the conducive-gradient surrogates q_s: kind
    (``none``/``diag``/``scalar``/``linear``/``full``), how to fit them
    (a prefit bank, gradient-matching ``refresh``, Fisher–Laplace
    ``fisher``, or per-client ``local_sgld`` runs), and the adaptive
    refresh schedule.
  * :class:`Schedule`      — rounds, local steps T, chain count,
    reassignment rule, trace thinning.
  * :class:`Execution`     — mesh, executor (``vmap``/``per_leaf``/
    ``packed``/``auto``), surrogate storage dtype (bf16 at scale),
    whether to collect a trace or return final states.
  * :class:`Federation`    — the scenario (``repro.fed``): non-IID
    partitioner, communication schedule (delayed rounds / partial
    participation / stragglers), compressed round payloads — passed as
    a spec or a registry name (``'dirichlet-0.1'``, ``'delayed-5x'``,
    ``'topk-1%'``, ...), and executed INSIDE the engine's jitted scan.

and one verb::

    fsgld = FSGLD(posterior, data, minibatch=10, surrogate=spec,
                  schedule=Schedule(rounds=300, local_steps=100))
    samples = fsgld.sample(jax.random.PRNGKey(0), theta0)

``sample`` preserves the engine's bit-exactness contract: with the
default executor on the host mesh it equals the legacy
``FederatedSampler.run_vmap`` oracle at fp32, noise included.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SamplerConfig
from repro.core.engine import MeshChainEngine, pad_shards
from repro.core.federated import fit_bank_fisher, refresh_bank
from repro.core.health import Recovery, RunHealth
from repro.core.surrogate import SurrogateBank, fit_scalar_tree, make_bank
from repro.fed import Federation, Stream, SyntheticClientSource, get_scenario
from repro.obs import MetricsFrame, Telemetry
from repro.fed.partition import (is_client_source,
                                 partition as partition_clients,
                                 resolve_shard_probs)
from repro.rivals.methods import get_method

PyTree = Any
LogLikFn = Callable[[PyTree, PyTree], jax.Array]

__all__ = [
    "Posterior", "SurrogateSpec", "Schedule", "Execution", "Federation",
    "Stream", "SyntheticClientSource", "Recovery", "RunHealth", "Serving",
    "Telemetry", "MetricsFrame",
    "FSGLD", "fit_bank_local_sgld", "get_scenario",
]

_COLLECT_SIGNALS = ("mean", "entropy", "mutual_info", "variance")

_EXECUTORS = ("auto", "vmap", "per_leaf", "packed")


@dataclasses.dataclass(frozen=True)
class Posterior:
    """The target: log p(theta | x) ∝ prior * likelihood.

    ``log_lik(theta, batch) -> scalar`` is the minibatch log-likelihood
    (summed over the batch); the prior is N(0, prior_precision^-1 I).
    ``temperature`` scales the injected noise (0 -> MAP/SGD limit).
    """
    log_lik: LogLikFn
    prior_precision: float = 1.0
    temperature: float = 1.0


@dataclasses.dataclass(frozen=True)
class SurrogateSpec:
    """How the conducive-gradient surrogates q_s are built and refreshed.

    kind:
      'none'   — no surrogate: the sampler runs DSGLD (or centralized
                 SGLD, see ``FSGLD`` method resolution).
      'diag'   — per-dimension Gaussian precisions (flat-vector params).
      'scalar' — per-tensor isotropic Gaussians (pytree params; the
                 billion-parameter format).
      'linear' — control-variate surrogates (bounded conducive term).
      'full'   — dense precision (paper-scale models only).

    fit (used when ``bank`` is None):
      'auto'       — 'refresh' for diag, 'local_sgld' for scalar.
      'refresh'    — gradient-matching Fisher fit at theta0
                     (``repro.core.refresh_bank``; no RNG, diag only).
      'fisher'     — Fisher–Laplace fit at theta0 (diag only).
      'local_sgld' — short per-client SGLD runs against the local
                     likelihood + moment fits (paper Sec 3.1; the
                     large-model phase 1). Uses fit_steps/fit_minibatch/
                     fit_step_size.

    ``refresh_every`` re-fits the bank every that many rounds at the
    current chain mean (adaptive refresh — diag banks only).
    """
    kind: str = "diag"
    bank: Optional[SurrogateBank] = None
    fit: str = "auto"
    refresh_every: Optional[int] = None
    fit_steps: int = 200
    fit_minibatch: int = 32
    fit_step_size: Optional[float] = None

    def __post_init__(self):
        assert self.kind in ("none", "diag", "scalar", "linear", "full"), \
            self.kind
        assert self.fit in ("auto", "refresh", "fisher", "local_sgld"), \
            self.fit


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The communication schedule of Algorithm 1.

    rounds x local_steps Langevin updates per chain; ``reassign`` is the
    chain->client rule ('categorical' = the paper's i.i.d. draw,
    'permutation' = the collision-free SPMD variant); ``thin`` keeps every
    thin-th local step in the trace.
    """
    rounds: int
    local_steps: int = 40
    n_chains: int = 1
    reassign: str = "categorical"
    thin: int = 1

    def __post_init__(self):
        assert self.reassign in ("categorical", "permutation"), self.reassign


@dataclasses.dataclass(frozen=True)
class Execution:
    """Where and how the chains run.

    mesh: a ('data', 'model') jax mesh (None -> the 1x1 host mesh).
    executor:
      'vmap'     — the reference executor (pure-jnp update, vmapped chain
                   blocks inside shard_map; bit-identical to the legacy
                   ``run_vmap`` oracle).
      'per_leaf' — chain-batched fused Pallas kernel, one pallas_call per
                   leaf per step.
      'packed'   — single-launch packed executor: ONE pallas_call per step
                   for the whole chain block (any floating param dtypes;
                   non-fp32 leaves quantize back per step).
      'auto'     — 'packed' on TPU backends, 'vmap' elsewhere (the Pallas
                   kernels run interpreted off-TPU, which is for
                   correctness work, not speed).
    dtype: surrogate STORAGE dtype override (e.g. jnp.bfloat16): the bank
      means are stored at this dtype — the large-model memory format.
    collect: False returns final chain states instead of a trace (the
      trace of a billion-parameter posterior does not fit anywhere).
    recovery: a :class:`Recovery` policy (``repro.core.health``) — turns
      on the in-scan chain health check; ``sample`` then returns
      ``(result, RunHealth)``. None = no health tracking (bit-identical
      to before).
    snapshot_every / snapshot_path: atomically checkpoint the full scan
      carry every that many rounds into the directory (preemption-safe;
      resumable). resume: continue from the newest valid snapshot in
      ``snapshot_path`` — traces are bitwise identical to an
      uninterrupted run.
    stream: a :class:`repro.fed.Stream` — the streamed client axis: only
      ``stream.resident`` clients live on device, with host prefetch of
      the next window's shards overlapping the scan. Fault-free streamed
      runs are bitwise identical to the resident path; requires
      ``Schedule(reassign='permutation')`` and does not compose with
      refresh_every / snapshots / recovery (the engine refuses loudly).
    telemetry: a :class:`repro.obs.Telemetry` spec — per-round per-chain
      metric rows (grad/drift/conducive norms, noise scale,
      participation, wire bytes, health words) lowered into the scanned
      round body; ``sample`` then additionally returns a
      :class:`repro.obs.MetricsFrame`. Telemetry-off runs stay bitwise
      identical, and telemetry probes draw from a salted key stream so
      telemetry-on traces are bitwise identical too. Does not compose
      with ``stream``.
    """
    mesh: Any = None
    executor: str = "auto"
    dtype: Any = None
    collect: bool = True
    recovery: Optional[Recovery] = None
    snapshot_every: Optional[int] = None
    snapshot_path: Optional[str] = None
    resume: bool = False
    stream: Optional[Stream] = None
    telemetry: Optional[Telemetry] = None

    def __post_init__(self):
        assert self.executor in _EXECUTORS, self.executor
        if (self.snapshot_every or self.resume) \
                and not self.snapshot_path:
            raise ValueError(
                "Execution.snapshot_every/resume need snapshot_path")


@dataclasses.dataclass(frozen=True)
class Serving:
    """How the posterior is SERVED: K draws as one Bayesian ensemble.

    The sampler's product is a posterior, not a point estimate;
    :meth:`FSGLD.serve` turns this spec plus a draw source into a running
    :class:`repro.serve.EnsembleServer` — one shared prefill per request,
    per-token decode fan-out over the ``draws`` axis, next token from the
    predictive mean. ``draws=1`` is bit-identical to the legacy
    single-draw path (tests/test_serving.py pins this).

    arch / smoke: which transformer config the draws parameterize
    (``repro.configs``); draw banks record their arch and the server
    REFUSES a mismatched bank instead of shape-erroring.
    batch / prompt_len / gen: the request shape drivers default to.
    mesh: optional ('data', 'model') mesh — the draw axis rides 'data'
    (``repro.sharding.rules.ensemble_shardings``) when K divides it.
    collect: which per-token uncertainty signals drivers report —
    subset of ('mean', 'entropy', 'mutual_info', 'variance'). Every
    signal is always computed (they share one softmax); ``collect`` is
    the declared output contract, mirroring ``Execution.collect``.
    """
    draws: int = 1
    arch: str = "qwen3-1.7b"
    smoke: bool = True
    batch: int = 4
    prompt_len: int = 32
    gen: int = 16
    mesh: Any = None
    collect: tuple = ("mean", "entropy", "mutual_info", "variance")

    def __post_init__(self):
        if self.draws < 1:
            raise ValueError(f"draws must be >= 1, got {self.draws}")
        bad = [c for c in self.collect if c not in _COLLECT_SIGNALS]
        if bad:
            raise ValueError(
                f"unknown collect signals {bad}; pick from "
                f"{_COLLECT_SIGNALS}")


class FSGLD:
    """The unified sampler: one constructor, one ``sample``.

    data: client shards — either a pytree with stacked (S, n, ...) leaves
    or a list of per-client pytrees (ragged clients are padded with
    ``pad_shards`` and the pad rows are provably dead). ``method``
    selects the sampling method from the ``repro.rivals`` table:
    'fsgld' (the source paper; needs a surrogate kind other than
    'none'), 'dsgld'/'sgld' (baselines, surrogates ignored), or 'fald'
    (FA-LD, arXiv:2112.05120 — DSGLD clients whose states the engine
    server-averages at every communication round, each client's noise
    amplified sqrt(C); Langevin kernel only). ``kernel`` selects
    the transition dynamics: 'sgld' (the Langevin family above) or
    'sghmc' (federated SGHMC with the SAME conducive estimator stack —
    see repro.core.sghmc; ``friction`` is its alpha_f knob). Both
    dynamics compose with every executor — packed SGHMC carries the
    momenta in a second chain-major buffer and is bit-identical to the
    run_vmap oracle (tests/test_parity_matrix.py).

    ``federation`` selects the federation scenario (``repro.fed``): a
    :class:`Federation` spec or a registry name. With a partition spec
    the ``data`` argument is POOLED (N, ...) arrays and the partitioner
    splits it onto clients; the schedule/compression axes lower into the
    engine's scanned round body (identity == the oracle, bitwise).
    """

    def __init__(self, posterior: Posterior, data: PyTree, *,
                 minibatch: int, step_size: float = 1e-4,
                 method: str = "fsgld", kernel: str = "sgld",
                 alpha: float = 1.0, friction: float = 0.1,
                 surrogate: Optional[SurrogateSpec] = None,
                 schedule: Optional[Schedule] = None,
                 execution: Optional[Execution] = None,
                 shard_probs: Optional[tuple] = None,
                 sizes: Optional[tuple] = None,
                 federation: Any = None):
        meth = get_method(method)
        if kernel not in ("sgld", "sghmc"):
            raise ValueError(kernel)
        if meth.aggregation == "fald" and kernel == "sghmc":
            raise ValueError(
                "method='fald' is a Langevin algorithm (FA-LD averages "
                "overdamped clients); it does not compose with "
                "kernel='sghmc'")
        self.method = meth
        self.posterior = posterior
        self.surrogate = surrogate if surrogate is not None \
            else (SurrogateSpec() if meth.needs_surrogate
                  else SurrogateSpec(kind="none"))
        if meth.needs_surrogate and self.surrogate.kind == "none":
            raise ValueError("method='fsgld' needs a surrogate kind other "
                             "than 'none' (that's DSGLD)")
        self.schedule = schedule if schedule is not None \
            else Schedule(rounds=100)
        self.execution = execution if execution is not None else Execution()
        self.kernel = kernel
        self.friction = friction
        self.federation = (get_scenario(federation)
                           if federation is not None else None)

        if is_client_source(data):
            # lazy per-client source (the streamed-scale data contract):
            # the engine materializes only the clients a run touches
            if self.federation is not None and \
                    self.federation.partition is not None:
                raise ValueError(
                    "a ClientSource is already partitioned per client; "
                    "it does not compose with a Federation partition "
                    "spec (wrap the pooled data in PartitionedSource "
                    "instead)")
            if sizes is not None:
                raise ValueError("a ClientSource carries its own sizes")
            num_shards = int(data.num_clients)
        else:
            if self.federation is not None and \
                    self.federation.partition is not None:
                # with a partition spec the data contract flips:
                # ``data`` is POOLED (pytree of (N, ...) leaves) and the
                # partitioner splits it onto clients (padded + masked,
                # ragged ok). The partition RNG comes from the spec's
                # own seed — changing the scenario never perturbs the
                # sampling stream.
                data, sizes = partition_clients(
                    None, data, self.federation.partition)
            elif isinstance(data, (list, tuple)):
                data, inferred = pad_shards(list(data))
                sizes = sizes if sizes is not None else inferred
            num_shards = jax.tree.leaves(data)[0].shape[0]
        self.data = data
        self.sizes = sizes
        if isinstance(shard_probs, str):
            # partition-aware preset ('uniform', 'size-proportional',
            # 'sqrt-size') resolved against the true client sizes via
            # the hierarchical (cross-silo) host reductions
            if is_client_source(data):
                true_sizes = np.asarray(data.sizes)
            elif sizes is not None:
                true_sizes = np.asarray(sizes)
            else:
                true_sizes = np.full(
                    (num_shards,), jax.tree.leaves(data)[0].shape[1])
            shard_probs = resolve_shard_probs(shard_probs, true_sizes)
        self.cfg = SamplerConfig(
            method=meth.cfg_method, step_size=step_size,
            num_shards=num_shards,
            shard_probs=shard_probs,
            local_updates=self.schedule.local_steps, alpha=alpha,
            surrogate=(self.surrogate.kind
                       if self.surrogate.kind != "none" else "diag"),
            prior_precision=posterior.prior_precision,
            temperature=posterior.temperature)
        self.minibatch = minibatch
        self.bank = (self.surrogate.bank if self.execution.dtype is None
                     or self.surrogate.bank is None
                     else self.surrogate.bank.astype(self.execution.dtype))
        self._engine = None

    # -- surrogate fitting (phase 1: computed once, communicated once) ----

    def fit(self, key: jax.Array, theta0: PyTree) -> SurrogateBank:
        """Fit the surrogate bank per the spec and install it. Called
        automatically by ``sample`` when needed; exposed so drivers can
        time / inspect phase 1. ``key`` feeds only the stochastic fit
        methods ('local_sgld'); deterministic fits ignore it."""
        spec = self.surrogate
        if spec.kind == "none":
            raise ValueError("surrogate kind 'none': nothing to fit")
        if is_client_source(self.data):
            raise ValueError(
                "surrogate fitting needs materialized (S, n, ...) shard "
                "data; with a ClientSource pass a prefit bank "
                "(SurrogateSpec(bank=...)) or a surrogate-free method "
                "('dsgld')")
        fit = spec.fit
        if fit == "auto":
            fit = "local_sgld" if spec.kind == "scalar" else "refresh"
        if fit == "refresh":
            bank = refresh_bank(self.posterior.log_lik, self.data, theta0)
        elif fit == "fisher":
            S = self.cfg.num_shards
            means = jnp.broadcast_to(theta0[None], (S,) + theta0.shape)
            bank = fit_bank_fisher(self.posterior.log_lik, self.data, means)
        elif fit == "local_sgld":
            bank = fit_bank_local_sgld(
                self.posterior.log_lik, self.data, theta0, key,
                fit_steps=spec.fit_steps, minibatch=spec.fit_minibatch,
                step_size=(spec.fit_step_size if spec.fit_step_size
                           is not None else self.cfg.step_size),
                kind=spec.kind)
        else:
            raise ValueError(fit)
        if self.execution.dtype is not None:
            bank = bank.astype(self.execution.dtype)
        self.bank = bank
        self._engine = None
        return bank

    # -- engine resolution -------------------------------------------------

    def _resolve_executor(self) -> tuple[bool, Optional[bool]]:
        """executor name -> (use_kernel, packed) engine knobs. Every
        executor composes with both transition kernels ('sgld'/'sghmc');
        the packed executor takes any mix of floating parameter dtypes
        (non-fp32 leaves quantize back per step)."""
        ex = self.execution.executor
        if ex == "auto":
            if jax.default_backend() == "tpu":
                # engine auto mode: packed for floating params, silent
                # per-leaf fallback for non-float leaves (packed=None) —
                # 'auto' must never crash on an exotic parameter tree
                return True, None
            ex = "vmap"
        if ex == "vmap":
            return False, None
        if ex == "per_leaf":
            return True, False
        return True, True  # 'packed' (strict: raises on non-float leaves)

    @property
    def engine(self) -> MeshChainEngine:
        """The (cached) chain engine every workload routes through."""
        if self._engine is None:
            use_kernel, packed = self._resolve_executor()
            sghmc = None
            if self.kernel == "sghmc":
                from repro.core.sghmc import SGHMCConfig
                sghmc = SGHMCConfig(friction=self.friction,
                                    temperature=self.posterior.temperature)
            self._engine = MeshChainEngine(
                self.posterior.log_lik, self.cfg, self.data,
                self.minibatch,
                bank=self.bank if self.cfg.method == "fsgld" else None,
                use_kernel=use_kernel, mesh=self.execution.mesh,
                sizes=self.sizes, packed=packed,
                dynamics=("sghmc" if self.kernel == "sghmc"
                          else "langevin"),
                sghmc=sghmc, aggregation=self.method.aggregation)
        return self._engine

    # -- phase 2: sampling -------------------------------------------------

    def sample(self, key: jax.Array, theta0: PyTree, *,
               rounds: Optional[int] = None,
               n_chains: Optional[int] = None,
               federation: Any = None,
               stream: Optional[Stream] = None,
               telemetry: Optional[Telemetry] = None):
        """Run the full schedule and return stacked samples with leading
        axes (n_chains, rounds * local_steps / thin, ...) — or the final
        chain states when ``Execution.collect`` is False.

        ``key`` drives sampling only (surrogate fitting, if still needed,
        uses a folded sub-key), so a prefit-bank run consumes exactly the
        oracle's RNG stream. ``rounds``/``n_chains`` override the
        schedule for sweep drivers; everything else is fixed at
        construction.

        ``federation`` — a ``repro.fed.Federation`` spec or a registry
        name (``'delayed-5x'``, ``'topk-1%'``, ...) — overrides the
        constructor's scenario for this run. Only the ENGINE axes
        (communication schedule, compression) can change per call: the
        partition fixed the data at construction, so an override whose
        partition differs is refused. The identity scenario is
        bit-identical to ``federation=None`` on every executor.

        ``stream`` — a ``repro.fed.Stream`` — overrides
        ``Execution.stream`` for this run (the streamed client axis:
        only ``resident`` clients on device, host prefetch overlapping
        the scan, bitwise identical to the resident path).

        ``telemetry`` — a ``repro.obs.Telemetry`` — overrides
        ``Execution.telemetry`` for this run; the return value then
        gains a trailing ``repro.obs.MetricsFrame`` of per-round
        per-chain metric rows.
        """
        if (self.cfg.method == "fsgld" and self.bank is None):
            self.fit(jax.random.fold_in(key, 0x5357), theta0)
        fed = self.federation
        if federation is not None:
            fed = get_scenario(federation)
            base = (self.federation.partition
                    if self.federation is not None else None)
            if fed.partition is not None and fed.partition != base:
                raise ValueError(
                    "sample(federation=...) cannot re-partition: the "
                    "data was split at construction; pass the partition "
                    "scenario to the FSGLD constructor instead")
        sched = self.schedule
        exe = self.execution
        return self.engine.run(
            key, theta0, rounds if rounds is not None else sched.rounds,
            n_chains=(n_chains if n_chains is not None
                      else sched.n_chains),
            reassign=sched.reassign, collect_every=sched.thin,
            refresh_every=self.surrogate.refresh_every,
            collect=exe.collect, federation=fed,
            recovery=exe.recovery, snapshot_every=exe.snapshot_every,
            snapshot_path=exe.snapshot_path, resume=exe.resume,
            stream=stream if stream is not None else exe.stream,
            telemetry=(telemetry if telemetry is not None
                       else exe.telemetry))

    # -- phase 3: serving the posterior ------------------------------------

    @staticmethod
    def serve(spec: Serving, *, bank: Optional[str] = None,
              draws: Any = None, seed: int = 0):
        """Stand up an ensemble server for this posterior (phase 3).

        Exactly one draw source: ``bank=`` a draw-bank directory written
        by ``repro.launch.train --draw-bank`` (a legacy single-checkpoint
        dir also works, served as one draw) — the server keeps tracking
        it and ``refresh()`` hot-swaps fresh draws in between requests;
        ``draws=`` an already-stacked (K, ...) params pytree (e.g. from
        :meth:`load_bank`); neither — ``spec.draws`` fresh inits (shape
        smoke, no posterior). Static: serving needs draws, not the
        sampler's data, so no FSGLD instance is required."""
        from repro.configs import get_config, get_smoke_config
        from repro.serve import EnsembleServer
        cfg = (get_smoke_config(spec.arch) if spec.smoke
               else get_config(spec.arch))
        n = None if (bank is None and draws is not None) else spec.draws
        return EnsembleServer(cfg, bank=bank, draws=draws, n_draws=n,
                              mesh=spec.mesh, seed=seed)

    @staticmethod
    def load_bank(path: str, like: PyTree, *, k: Optional[int] = None,
                  expect_arch: Optional[str] = None):
        """Load the freshest ``k`` draws from a draw bank as one stacked
        (K, ...) pytree plus their :class:`repro.checkpoint.DrawMeta`
        provenance. Fingerprint-checks every draw against ``like`` (and
        ``expect_arch`` when given) — a mismatched bank is refused with
        a ValueError, never a shape error."""
        from repro import checkpoint
        return checkpoint.load_bank(path, like, k=k,
                                    expect_arch=expect_arch)


# ---------------------------------------------------------------------------
# generic per-client local-SGLD surrogate fitting (paper Sec 3.1 phase 1)
# ---------------------------------------------------------------------------

def fit_bank_local_sgld(log_lik_fn: LogLikFn, shard_data: PyTree,
                        theta0: PyTree, key: jax.Array, *,
                        fit_steps: int, minibatch: int, step_size: float,
                        kind: str = "scalar",
                        lam_floor: float = 1e-8) -> SurrogateBank:
    """Short SGLD runs per client against the LOCAL likelihood + moment
    fits — the generic form of the large-model phase 1 (previously a
    private helper in launch/train.py). Works on any parameter pytree;
    ``kind='scalar'`` fits per-tensor isotropic Gaussians from the second
    half of each local trace, ``kind='diag'`` per-dimension ones (flat
    vector params only)."""
    leaf = jax.tree.leaves(shard_data)[0]
    S, n_s = leaf.shape[0], leaf.shape[1]

    def local_sgld(data_s, k):
        def body(theta, kk):
            k1, k2 = jax.random.split(kk)
            idx = jax.random.randint(k1, (minibatch,), 0, n_s)
            batch = jax.tree.map(lambda d: d[idx], data_s)
            g = jax.grad(log_lik_fn)(theta, batch)
            leaves, tdef = jax.tree.flatten(theta)
            gl = jax.tree.leaves(g)
            ks = jax.random.split(k2, len(leaves))
            new = [t + (step_size / 2) * (n_s / minibatch)
                   * gg.astype(t.dtype)
                   + jnp.sqrt(step_size)
                   * jax.random.normal(nk, t.shape, t.dtype)
                   for t, gg, nk in zip(leaves, gl, ks)]
            theta = jax.tree.unflatten(tdef, new)
            return theta, theta

        _, trace = jax.lax.scan(body, theta0,
                                jax.random.split(k, fit_steps))
        # keep the second half of the trace (burn-in discarded)
        return jax.tree.map(lambda t: t[fit_steps // 2:], trace)

    traces = jax.jit(jax.vmap(local_sgld))(shard_data,
                                           jax.random.split(key, S))
    if kind == "scalar":
        # per-shard per-tensor isotropic fits; vmap keeps the shard axis
        means, precs = jax.vmap(
            lambda tr: fit_scalar_tree(tr, jitter=lam_floor))(traces)
        return make_bank(means, precs, "scalar")
    if kind == "diag":
        flat = jax.tree.leaves(traces)
        assert len(flat) == 1 and flat[0].ndim == 3, \
            "diag fits need flat-vector parameters"
        mu = flat[0].mean(1)
        precs = 1.0 / (flat[0].var(1) + lam_floor)
        return make_bank(mu, precs, "diag")
    raise ValueError(kind)
