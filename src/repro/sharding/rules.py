"""Partition rules: map parameter / activation pytrees to PartitionSpecs.

Mesh axes (launch/mesh.py):
    single pod : ("data", "model") = (16, 16)
    multi-pod  : ("pod", "data", "model") = (2, 16, 16)

Policy (DESIGN.md Sec 4):
  * "model"  — tensor parallel: heads / d_ff / vocab.
  * "data"   — the FEDERATED axis: batch sharding AND FSDP for params.
               Each data-group is one logical client shard.
  * "pod"    — pure data parallel across pods (params replicated over pod;
               gradients all-reduce over it). Batch shards over (pod, data).

Rules are name-based over the param dict keys produced by models/model.py.
Dims that don't divide the axis size fall back to replication for that dim
(whisper's 20 heads / 51866 vocab on a 16-way model axis) — recorded by the
caller for DESIGN.md notes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# param-name -> (dim -> logical axis); logical axes: 'fsdp' | 'mdl' | None
_RULES = {
    # embeddings / head
    "embed": ("mdl", "fsdp"),
    "head": ("fsdp", "mdl"),
    # attention
    "wq": ("fsdp", "mdl"),
    "wk": ("fsdp", "mdl"),
    "wv": ("fsdp", "mdl"),
    "wo": ("mdl", "fsdp"),
    # dense ffn
    "wi_gate": ("fsdp", "mdl"),
    "wi_up": ("fsdp", "mdl"),
    # moe
    "router": ("fsdp", None),
    "experts_wi_gate": (None, "fsdp", "mdl"),
    "experts_wi_up": (None, "fsdp", "mdl"),
    "experts_wo": (None, "mdl", "fsdp"),
    # rglru
    "w_x": ("fsdp", "mdl"),
    "w_gate": ("fsdp", "mdl"),
    "w_out": ("mdl", "fsdp"),
    "w_rec": ("fsdp", "mdl"),
    "w_inp": ("fsdp", "mdl"),
    "conv_w": (None, "mdl"),
    "lam": ("mdl",),
    # rwkv
    "w_r": ("fsdp", "mdl"),
    "w_k": ("fsdp", "mdl"),
    "w_v": ("fsdp", "mdl"),
    "w_o": ("mdl", "fsdp"),
    "w_lora_a": ("fsdp", None),
    "w_lora_b": (None, None),
    "u": ("mdl", None),
}

# ffn 'wo' is (F, D) -> ('mdl', 'fsdp'); attention 'wo' is (q_dim, D) ->
# same rule, so one entry suffices.


def logical_axes(mesh: Mesh):
    """Resolve logical axis names to mesh axes for this mesh."""
    axes = {"mdl": "model", "fsdp": "data"}
    batch = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return axes, batch


def _leaf_spec(path, leaf, mesh: Mesh, axes) -> P:
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = str(entry.key)
            break
    rule = _RULES.get(name)
    shape = leaf.shape
    if rule is None:
        return P()  # norms, scalars, mix vectors, gates: replicate
    # stacked layer dims (scan) prepend extra leading axes: right-align rule
    offset = len(shape) - len(rule)
    spec = [None] * len(shape)
    if offset < 0:  # e.g. (1,)-shaped gate param hit a 2-D rule: replicate
        return P()
    for i, ax in enumerate(rule):
        if ax is None:
            continue
        mesh_axis = axes[ax]
        if mesh_axis is None or mesh_axis not in mesh.shape:
            continue  # axis disabled (e.g. serving layout drops 'fsdp')
        size = mesh.shape[mesh_axis]
        if shape[offset + i] % size == 0:
            spec[offset + i] = mesh_axis
        # else: leave replicated on that dim (uneven; e.g. whisper heads)
    return P(*spec)


def param_specs(params: PyTree, mesh: Mesh, *, serve: bool = False,
                serve_hbm_budget: float = 8 * 2**30) -> PyTree:
    """serve=True applies the SERVING layout: when the whole model (bf16)
    fits per device with model-axis-only sharding, the FSDP ('data') axis
    is dropped — weights stay resident and only (tiny) decode activations
    cross the ICI, instead of re-all-gathering every weight every token
    step (§Perf iteration 3). Models too big for that (grok, vision-90b)
    keep the 2-D layout."""
    axes, _ = logical_axes(mesh)
    if serve:
        total_bf16 = sum(
            int(np.prod(l.shape)) * 2 for l in jax.tree.leaves(params))
        if total_bf16 / mesh.shape["model"] <= serve_hbm_budget:
            axes = dict(axes, fsdp=None)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, mesh, axes), params)


def param_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


def batch_specs(batch: PyTree, mesh: Mesh) -> PyTree:
    """Shard the leading (global batch) dim over (pod?, data), when it
    divides; otherwise replicate (long_500k has batch 1)."""
    _, baxes = logical_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))

    def spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % bsize == 0:
            return P(baxes)
        return P()
    return jax.tree.map(spec, batch)


def cache_specs(cache: PyTree, mesh: Mesh) -> PyTree:
    """KV caches / recurrent states: (layers, B, ...) — batch on dim 1 for
    stacked block caches, dim 0 for remainder-layer caches. We detect the
    stacked case by path prefix 'blocks'."""
    _, baxes = logical_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))

    def leaf_spec(path, leaf):
        top = str(path[0].key) if isinstance(path[0],
                                             jax.tree_util.DictKey) else ""
        bdim = 1 if top == "blocks" else 0
        spec = [None] * leaf.ndim
        if leaf.ndim > bdim and leaf.shape[bdim] % bsize == 0:
            spec[bdim] = baxes
        # shard kv-heads / rwkv heads over model when they divide
        name = str(path[-1].key) if isinstance(path[-1],
                                               jax.tree_util.DictKey) else ""
        if name in ("k", "v") and leaf.ndim == bdim + 4:
            kdim, sdim = bdim + 2, bdim + 1
            if leaf.shape[kdim] % mesh.shape["model"] == 0:
                spec[kdim] = "model"
            elif leaf.shape[sdim] % mesh.shape["model"] == 0:
                # GQA kv-heads < model axis: shard the cache SEQ dim instead
                # (32k/16 = 2k per device; attention reduces over it with a
                # distributed softmax the compiler lowers to all-reduces).
                spec[sdim] = "model"
        if name == "S" and leaf.ndim == bdim + 4:  # rwkv state (B,H,hd,hd)
            hdim = bdim + 1
            if leaf.shape[hdim] % mesh.shape["model"] == 0:
                spec[hdim] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def surrogate_specs(params_specs: PyTree) -> PyTree:
    """Surrogate means shard exactly like the params they mirror; scalar
    precisions replicate."""
    return params_specs


# ---------------------------------------------------------------------------
# chain-parallel (federated) layout: the mesh chain runtime (core/engine.py)
# and the large-model federated round (launch/steps.py) both place chains
# along the 'data' axis — one source of truth for that convention here.
# ---------------------------------------------------------------------------

CHAIN_AXIS = "data"


def chain_spec() -> P:
    """PartitionSpec prefix placing a leading chain axis on 'data'."""
    return P(CHAIN_AXIS)


def packed_chain_spec() -> P:
    """Layout CONVENTION for PACKED chain-state buffers
    (kernels.ops.PackedChains), recorded for the launch/steps.py
    migration onto the chain engine (ROADMAP open item). Today nothing
    uses it: packed buffers are created and consumed entirely INSIDE the
    engine's shard_map block and never cross a sharding boundary. When
    one does, this is its spec: the (C * rows_total, 128) row axis is
    CHAIN-MAJOR, so sharding dim 0 over the chain axis keeps every
    chain's whole segment on one data group — the same placement the
    unpacked (C, ...) tree gets from ``chain_spec`` (requires
    C % |data| == 0, which the engine already enforces). EVERY
    chain-major segment buffer of the multi-segment state shares this
    spec — the SGHMC momentum buffer rides the same segment table and
    the same chain-major row order as the parameter buffer."""
    return P(CHAIN_AXIS, None)


def stream_window_spec() -> P:
    """Spec for streamed-client WINDOW operands (core/engine.py's
    ``stream=`` path): the resident client-id vector and the (K,)
    sizes/probs metadata rows, plus the (K, max_n, ...) resident shard
    data, are all REPLICATED — every data group must see the same resident
    window because any chain can be reassigned to any resident client
    within it (the same reason the full (S, ...) shard stack replicates on
    the resident path). The chain axis stays on 'data'; streaming changes
    WHICH client rows are on device, never how chains are placed."""
    return P()


def fed_carry_spec() -> P:
    """Spec for the engine's federated-round carry: the resident sids
    (C,) and every compression-state row — server-view reference,
    primal error feedback, dual error feedback — are PER-CHAIN (C,) /
    (C, P) arrays, so they shard over 'data' exactly like the chain
    states they mirror. The FA-LD server average is the one cross-chain
    reduction over this carry, and it runs as an in-scan masked psum
    over the same axis rather than a relayout."""
    return P(CHAIN_AXIS)


def chain_specs(tree: PyTree) -> PyTree:
    """Per-leaf chain-axis specs for a pytree of (C, ...) chain states."""
    return jax.tree.map(lambda _: P(CHAIN_AXIS), tree)


def chain_shardings(tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P(CHAIN_AXIS)), tree)


# ---------------------------------------------------------------------------
# ensemble-serving layout: K posterior draws served as a batched ensemble.
# The draw axis rides the SAME mesh axis the chains sampled on ('data') —
# a K-draw serving fleet is placed exactly like a K-chain sampling run, so
# the streaming chain→server path hands draws across without relayout.
# Params, decode caches, and recurrent states all lead with (K, ...);
# within a draw the serving layout (param_specs(serve=True)) still
# applies on 'model'.
# ---------------------------------------------------------------------------

ENSEMBLE_AXIS = CHAIN_AXIS


def ensemble_spec() -> P:
    """PartitionSpec prefix placing a leading draw axis on 'data'."""
    return P(ENSEMBLE_AXIS)


def ensemble_specs(tree: PyTree) -> PyTree:
    """Per-leaf draw-axis specs for (K, ...) stacked draws / caches."""
    return jax.tree.map(lambda _: P(ENSEMBLE_AXIS), tree)


def ensemble_shardings(tree: PyTree, mesh: Mesh) -> PyTree:
    """NamedShardings for a stacked-draw tree; requires
    K % mesh.shape['data'] == 0 (callers fall back to replication
    otherwise — an uneven ensemble never crashes the server)."""
    return jax.tree.map(
        lambda _: NamedSharding(mesh, P(ENSEMBLE_AXIS)), tree)
