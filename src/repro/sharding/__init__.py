from repro.sharding.rules import (  # noqa: F401
    batch_specs,
    cache_specs,
    logical_axes,
    param_shardings,
    param_specs,
    surrogate_specs,
)
