from repro.testing.chaos import (  # noqa: F401
    ChaosSpec,
    corrupt_draw,
    flaky_io,
    truncate_file,
)
