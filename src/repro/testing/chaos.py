"""Deterministic fault injectors — the chaos harness behind the CI
``chaos`` lane.

Two families, matching the two places the runtime can break:

  * :class:`ChaosSpec` — IN-SCAN faults, lowered by the chain engine
    into its jitted round body exactly like a federation scenario
    (``MeshChainEngine.run(..., chaos=spec)``): NaN-poisoned updates on
    chosen chains at chosen rounds (the observable effect of a client
    returning a NaN gradient — the chain's post-round state is NaN),
    and NaN-corrupted compressed payloads at the round boundary (the
    server view a chain continues from goes bad). Fully deterministic:
    the fault set is static configuration, not RNG, so a chaos run is
    reproducible bit for bit and comparable chain-by-chain against a
    fault-free run.

  * Host-side IO injectors — :func:`corrupt_draw`, :func:`truncate_file`
    and :func:`flaky_io` break the checkpoint/draw-bank layer the way
    preemptions and flaky filesystems do: garbled or truncated array
    files (torn writes), and reads that fail transiently N times before
    succeeding (the retry-with-backoff path in ``repro.serve``).

The engine deliberately does NOT import this module: it duck-types the
spec (static tuples of chain/round indices), so production code carries
no test-harness dependency.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Static in-scan fault plan (hashable: the engine caches one
    executor per (config, chaos) — a chaos run never retraces a clean
    executor).

    nan_chains / nan_rounds: the cross product of these chain indices
      and (absolute) round indices gets its post-round chain state
      NaN-poisoned — the deterministic stand-in for a NaN gradient on
      that client at that round.
    payload_nan_chains / payload_nan_rounds: with a compressed
      federation scenario active, the compressed payload (the delta the
      server applies) of these chains is NaN-corrupted at these
      communication rounds before the server view updates.
    """
    nan_chains: tuple = ()
    nan_rounds: tuple = ()
    payload_nan_chains: tuple = ()
    payload_nan_rounds: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "nan_chains",
                           tuple(int(c) for c in self.nan_chains))
        object.__setattr__(self, "nan_rounds",
                           tuple(int(r) for r in self.nan_rounds))
        object.__setattr__(self, "payload_nan_chains",
                           tuple(int(c) for c in self.payload_nan_chains))
        object.__setattr__(self, "payload_nan_rounds",
                           tuple(int(r) for r in self.payload_nan_rounds))

    @property
    def poisons_state(self) -> bool:
        return bool(self.nan_chains) and bool(self.nan_rounds)

    @property
    def poisons_payload(self) -> bool:
        return bool(self.payload_nan_chains) and \
            bool(self.payload_nan_rounds)

    @property
    def active(self) -> bool:
        return self.poisons_state or self.poisons_payload


# ---------------------------------------------------------------------------
# host-side IO fault injectors
# ---------------------------------------------------------------------------

def truncate_file(path: str, keep_bytes: int = 16) -> str:
    """Truncate a file to its first ``keep_bytes`` bytes — the on-disk
    shape of a write preempted mid-flush. Returns the path."""
    with open(path, "rb") as f:
        head = f.read(keep_bytes)
    with open(path, "wb") as f:
        f.write(head)
    return path


def corrupt_draw(draw_dir: str, mode: str = "truncate") -> str:
    """Break one draw/checkpoint directory the way real faults do.

    mode:
      'truncate' — cut arrays.npz short (torn write; np.load fails or
                   the manifest's content hash mismatches).
      'garbage'  — overwrite arrays.npz with non-npz bytes.
      'missing'  — delete arrays.npz, keep the manifest (the draw looks
                   complete to the directory listing).
    Returns ``draw_dir``.
    """
    arrays = os.path.join(draw_dir, "arrays.npz")
    if mode == "truncate":
        truncate_file(arrays)
    elif mode == "garbage":
        with open(arrays, "wb") as f:
            f.write(b"not an npz archive, chaos was here")
    elif mode == "missing":
        os.remove(arrays)
    else:
        raise ValueError(mode)
    return draw_dir


@contextlib.contextmanager
def flaky_io(n_failures: int, exc: type = OSError,
             match: str = ".npz"):
    """Make ``open()`` raise ``exc`` for the first ``n_failures`` READ
    opens whose path contains ``match`` (writes and unrelated paths
    always pass through) — deterministic transient-IO chaos for the
    retry-with-backoff reader paths. Yields a one-element list holding
    the number of injected failures so far."""
    import builtins
    orig, count = builtins.open, [0]

    def fake_open(file, mode="r", *a, **k):
        if count[0] < n_failures and "r" in mode \
                and isinstance(file, (str, os.PathLike)) \
                and match in os.fspath(file):
            count[0] += 1
            raise exc(f"chaos: injected transient IO failure "
                      f"{count[0]}/{n_failures} on {os.fspath(file)}")
        return orig(file, mode, *a, **k)

    builtins.open = fake_open
    try:
        yield count
    finally:
        builtins.open = orig
