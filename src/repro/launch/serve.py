"""Thin CLI over the serving facade: K posterior draws, one ensemble.

All the mechanics live behind ``repro.api.Serving`` + ``FSGLD.serve``
(shared prefill, per-token decode fan-out, predictive-mean tokens,
per-token uncertainty, hot-swappable draw banks); this driver just turns
flags into a spec and prints the served stream.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --draws 4 \
        [--bank /bank/from/train]

``--bank`` points at a draw-bank directory written by
``repro.launch.train --draw-bank``; ``--watch N`` re-polls it N extra
times, hot-swapping fresh draws in between requests (the streaming
chain->server path). The legacy ``--ckpt`` flag still works (warns once)
and serves the single checkpoint as a one-draw bank.

Progress goes through the structured event log (``repro.obs.trace``):
hot-swaps, refresh retries/backoffs (with timestamps + attempt counts),
and per-request prefill/decode spans are echoed as one-line events and —
with ``--log-jsonl PATH`` — appended to a trace JSONL for later
inspection.
"""
from __future__ import annotations

import argparse
import warnings

from repro.api import FSGLD, Serving
from repro.obs import trace as obs_trace

_ckpt_warned = False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--draws", type=int, default=1,
                    help="ensemble size K (freshest K draws of the bank)")
    ap.add_argument("--bank", default=None,
                    help="draw-bank directory from "
                         "repro.launch.train --draw-bank")
    ap.add_argument("--watch", type=int, default=0,
                    help="extra bank polls: serve, refresh(), repeat")
    ap.add_argument("--ckpt", default=None,
                    help="DEPRECATED: single checkpoint; use --bank "
                         "(served as a one-draw legacy bank)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-jsonl", default=None,
                    help="also append structured trace events/spans "
                         "(refreshes, prefill/decode) to this JSONL file")
    args = ap.parse_args(argv)
    obs_trace.configure(args.log_jsonl, echo=True)
    try:
        return _serve(args)
    finally:
        obs_trace.configure()  # don't leak the echo tracer to callers


def _serve(args):
    global _ckpt_warned
    bank = args.bank
    if args.ckpt:
        if bank is not None:
            raise SystemExit("pass --bank or --ckpt, not both")
        if not _ckpt_warned:
            warnings.warn(
                "--ckpt is deprecated; point --bank at a draw-bank "
                "directory (repro.launch.train --draw-bank). Serving "
                "the checkpoint as a one-draw legacy bank.",
                DeprecationWarning, stacklevel=2)
            _ckpt_warned = True
        bank = args.ckpt

    spec = Serving(draws=args.draws, arch=args.arch, smoke=args.smoke,
                   batch=args.batch, prompt_len=args.prompt_len,
                   gen=args.gen)
    server = FSGLD.serve(spec, bank=bank, seed=args.seed)
    if bank is not None:
        meta = server.metas[0]
        prov = (f"round {meta.round}, method={meta.method}, "
                f"scenario={meta.scenario}" if meta is not None
                else "legacy checkpoint, no DrawMeta")
        print(f"serving {server.n_draws} draw(s) from {bank} ({prov})")

    for req in range(1 + max(0, args.watch)):
        if req > 0:
            # a watching server must outlive a flaky bank: refresh()
            # already degrades to the previous ensemble on read errors,
            # and anything it still raises is logged, not fatal
            try:
                if server.refresh():
                    obs_trace.event("serve.hot_swap", request=req,
                                    n_draws=server.n_draws)
            except Exception as e:  # noqa: BLE001
                obs_trace.event("serve.refresh_error", request=req,
                                error=str(e), n_draws=server.n_draws)
        res = server.generate(gen=args.gen, batch=args.batch,
                              prompt_len=args.prompt_len)
        for t in range(res.tokens.shape[1]):
            line = f"step {t}: tokens {res.tokens[:, t].tolist()}"
            if "mean" in spec.collect:
                line += f" logp {res.mean_logprob[:, t].tolist()}"
            if "entropy" in spec.collect:
                line += f" H {res.entropy[:, t].tolist()}"
            if "mutual_info" in spec.collect:
                line += f" MI {res.mutual_info[:, t].tolist()}"
            if "variance" in spec.collect:
                line += f" var {res.token_var[:, t].tolist()}"
            print(line, flush=True)
        B, G = args.batch, args.gen
        print(f"prefilled {B}x{args.prompt_len} once for "
              f"{res.n_draws} draw(s) in {res.prefill_s:.2f}s; served "
              f"{B} seqs x {G} new tokens in {res.decode_s:.2f}s "
              f"({B*G/max(res.decode_s,1e-9):.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
