"""Batched serving driver: prefill (runs the full forward) + decode loop
against the KV cache / recurrent state, serving a posterior sample.

The sample comes from the ``repro.api`` facade: point ``--ckpt`` at a
checkpoint written by ``repro.launch.train`` (one draw from the FSGLD
weight posterior) and this driver serves it; without ``--ckpt`` it serves
freshly initialised weights (shape smoke).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--ckpt /path/from/train]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_serve_step
from repro.models import (decode_step, encoder_forward, init_cache,
                          init_params, prefill_with_cache)
from repro.models.model import ACT_DTYPE


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt", default=None,
                    help="posterior-sample checkpoint from "
                         "repro.launch.train (repro.api.FSGLD output); "
                         "omitted -> fresh init_params")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    if args.ckpt:
        params, step, extra = checkpoint.restore(args.ckpt, params)
        # np_checkpoint restores host numpy arrays; device-put them so
        # tracer-indexed gathers (embed lookup) stay jittable
        params = jax.tree.map(jnp.asarray, params)
        print(f"serving posterior sample from {args.ckpt} "
              f"(round {step}, method={extra.get('method')})")
    B = args.batch
    total = args.prompt_len + args.gen

    enc_out = None
    if cfg.family == "vlm":
        enc_out = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), ACT_DTYPE)
    elif cfg.family == "audio":
        enc_in = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        enc_out = encoder_forward(params, cfg, enc_in)

    prompt = jax.random.randint(key, (B, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)

    if enc_out is not None:
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p,
                                                   enc_out=enc_out))
        kw = {"enc_embeds": (enc_out if cfg.family == "vlm" else enc_in)}
    else:
        step = jax.jit(lambda c, t, p: decode_step(params, cfg, c, t, p))
        kw = {}

    # prefill: ONE forward pass fills the decode cache (models.
    # prefill_with_cache) — the production path the dry-run lowers.
    t0 = time.time()
    logits, cache = prefill_with_cache(params, cfg, prompt, total, **kw)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for t in range(args.prompt_len, total - 1):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = step(cache, tok, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        print(f"step {t}: tokens {tok[:, 0].tolist()}", flush=True)
    dt = time.time() - t0
    print(f"prefilled {B}x{args.prompt_len} in {t_prefill:.2f}s; served "
          f"{B} seqs x {args.gen} new tokens in {dt:.2f}s "
          f"({B*args.gen/max(dt,1e-9):.1f} tok/s on CPU)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
