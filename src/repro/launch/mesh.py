"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see
the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the real local device — used by smoke tests/examples
    so the same pjit code path runs on this CPU container."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_sim_mesh(data: int = 1, model: int = 1):
    """(data, model) mesh over however many devices are visible — the chain
    runtime's mesh for subprocess SPMD tests (XLA_FLAGS-forced host devices)
    and for right-sized slices of a real cluster. data = chain groups,
    model = shard-parallel surrogate/gradient work (core/engine.py)."""
    return jax.make_mesh((data, model), ("data", "model"))
