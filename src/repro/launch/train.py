"""End-to-end FSGLD training driver (large-model mode).

Phases (paper Algorithm 1 + Sec 3.1):
  1. local surrogate fitting — short SGLD runs per client shard against the
     local likelihood, fit per-tensor scalar-precision Gaussians, combine
     into the global product q (computed once, communicated once);
  2. FSGLD sampling — per round the scheduler draws a client
     s ~ Categorical(f), feeds that client's minibatches, and the chain
     takes ``local_updates`` Langevin steps with the conducive correction.

On this CPU container run with ``--smoke`` (reduced config, 1x1 mesh); on a
real cluster the same script drives the 16x16 / 2x16x16 production meshes.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --rounds 10 --method fsgld
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint
from repro.configs import SamplerConfig, get_config, get_smoke_config
from repro.core.surrogate import make_bank
from repro.data import token_shards
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import (init_surrogate_state, make_train_step)
from repro.models import init_params, log_lik_fn
from repro.sharding import batch_specs, param_shardings


def fit_surrogates(cfg, sampler: SamplerConfig, params, shards, key, *,
                   fit_steps: int, minibatch: int, lam_floor=1e-8):
    """Phase 1: per-client SGLD against the local likelihood + per-tensor
    isotropic Gaussian fits (DESIGN.md Sec 4.2). Returns a 'scalar' bank."""
    S = sampler.num_shards
    n_s = shards["tokens"].shape[1]

    def local_sgld(data_s, k):
        def body(theta, kk):
            k1, k2 = jax.random.split(kk)
            idx = jax.random.randint(k1, (minibatch,), 0, n_s)
            batch = jax.tree.map(lambda d: d[idx], data_s)
            g = jax.grad(lambda p: log_lik_fn(p, cfg, batch))(theta)
            h = sampler.step_size
            leaves, tdef = jax.tree.flatten(theta)
            gl = jax.tree.leaves(g)
            ks = jax.random.split(k2, len(leaves))
            new = [t + (h / 2) * (n_s / minibatch) * gg.astype(t.dtype)
                   + jnp.sqrt(h) * jax.random.normal(nk, t.shape, t.dtype)
                   for t, gg, nk in zip(leaves, gl, ks)]
            theta = jax.tree.unflatten(tdef, new)
            return theta, theta
        _, trace = jax.lax.scan(body, params, jax.random.split(k, fit_steps))
        # keep the second half of the trace
        return jax.tree.map(lambda t: t[fit_steps // 2:], trace)

    traces = jax.jit(jax.vmap(local_sgld))(
        shards, jax.random.split(key, S))
    means = jax.tree.map(lambda t: t.mean(1), traces)          # (S, ...)
    precs = jax.tree.map(
        lambda t: 1.0 / (t.var(1).reshape(S, -1).mean(-1) + lam_floor),
        traces)                                                 # (S,)
    return make_bank(means, precs, "scalar")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + 1x1 mesh (CPU container)")
    ap.add_argument("--method", default="fsgld",
                    choices=["sgld", "dsgld", "fsgld"])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--chains", type=int, default=1,
                    help=">1 runs the mesh-parallel chain engine "
                         "(core/engine.py): chains shard over the mesh "
                         "'data' axis, reassignment is the collision-free "
                         "SPMD permutation")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route chain updates through the chain-batched "
                         "fused Pallas kernel")
    ap.add_argument("--packed", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="with --use-kernel: packed single-launch steps "
                         "(one pallas_call per step for the whole chain "
                         "block; default auto — on for fp32 params). "
                         "--no-packed keeps the per-leaf kernel path")
    ap.add_argument("--local-updates", type=int, default=4)
    ap.add_argument("--num-shards", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--shard-size", type=int, default=64)
    ap.add_argument("--step-size", type=float, default=1e-5)
    ap.add_argument("--fit-steps", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke \
        else make_production_mesh(multi_pod=args.multi_pod)
    sampler = SamplerConfig(method=args.method, step_size=args.step_size,
                            num_shards=args.num_shards,
                            local_updates=args.local_updates,
                            surrogate="scalar")
    key = jax.random.PRNGKey(args.seed)
    k_param, k_data, k_fit, k_run = jax.random.split(key, 4)

    print(f"arch={cfg.name} method={args.method} shards={args.num_shards} "
          f"mesh={dict(mesh.shape)}")
    params = init_params(cfg, k_param)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    shards = token_shards(
        k_data, num_shards=args.num_shards, shard_size=args.shard_size,
        seq_len=args.seq, vocab_size=cfg.vocab_size)

    # ---- phase 1: surrogates (once, before sampling) ----
    if args.method == "fsgld":
        t0 = time.time()
        bank = fit_surrogates(cfg, sampler, params, shards, k_fit,
                              fit_steps=args.fit_steps,
                              minibatch=min(args.batch,
                                            args.shard_size))
        print(f"surrogates fitted in {time.time()-t0:.1f}s "
              f"(communicated once)")
    else:
        bank = None

    # ---- phase 2 (multi-chain): mesh-parallel chain engine ----
    if args.chains > 1:
        from repro.core.engine import MeshChainEngine

        eng = MeshChainEngine(
            lambda p, b: log_lik_fn(p, cfg, b), sampler, shards,
            min(args.batch, args.shard_size), bank=bank,
            use_kernel=args.use_kernel, mesh=mesh, packed=args.packed)
        reassign = ("permutation" if args.chains <= args.num_shards
                    else "categorical")
        t0 = time.time()
        finals = eng.run(k_run, params, args.rounds, n_chains=args.chains,
                         reassign=reassign, collect=False)
        dt = time.time() - t0
        probe = jax.tree.map(lambda d: d[0][:args.batch], shards)
        lls = jax.vmap(lambda p: log_lik_fn(p, cfg, probe))(finals)
        lls = np.asarray(lls) / probe["tokens"].size
        for c, ll in enumerate(lls):
            print(f"chain {c:3d} ll/token={float(ll):8.4f}")
        steps = args.rounds * args.local_updates * args.chains
        print(f"{args.chains} chains x {args.rounds} rounds "
              f"({steps} chain-steps) in {dt:.1f}s "
              f"= {steps / dt:.1f} steps/s "
              f"[reassign={reassign} kernel={args.use_kernel} "
              f"packed={args.packed if args.packed is not None else 'auto'}]")
        if args.ckpt:
            checkpoint.save(args.ckpt,
                            jax.tree.map(lambda t: t[0], finals),
                            step=args.rounds,
                            extra={"method": args.method, "arch": cfg.name,
                                   "chains": args.chains})
            print(f"checkpoint -> {args.ckpt}")
        print(f"final ll/token {float(np.mean(lls)):.4f}")
        return 0

    # ---- phase 2: FSGLD rounds ----
    N_s = args.shard_size  # sequences per client
    f_s = 1.0 / args.num_shards
    scale = N_s / (f_s * args.batch)
    step = make_train_step(cfg, sampler, scale=scale, f_s=f_s)
    pshard = param_shardings(params, mesh)
    step_jit = jax.jit(step, in_shardings=(
        pshard, None, None, None), out_shardings=(pshard, None))

    if bank is not None:
        mu_g = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                            bank.global_.mean)
        lam_g = bank.global_.prec
    else:
        surr0 = init_surrogate_state(params, lam=0.0)

    probs = jnp.full((args.num_shards,), f_s)
    lls = []
    t0 = time.time()
    for r in range(args.rounds):
        k_run, k_shard, k_steps = jax.random.split(k_run, 3)
        s = int(jax.random.categorical(k_shard, jnp.log(probs)))
        if bank is not None:
            qs = bank.shard(s)
            surr = {"mu_g": mu_g,
                    "mu_s": jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                                         qs.mean),
                    "lam_g": lam_g, "lam_s": qs.prec}
        else:
            surr = surr0
        for t in range(args.local_updates):
            k_steps, k_b, k_u = jax.random.split(k_steps, 3)
            idx = jax.random.randint(k_b, (args.batch,), 0, N_s)
            batch = jax.tree.map(lambda d: d[s][idx], shards)
            params, metrics = step_jit(params, surr, batch, k_u)
        ll = float(metrics["ll_per_token"])
        lls.append(ll)
        print(f"round {r:3d} client={s:2d} ll/token={ll:8.4f} "
              f"({time.time()-t0:.1f}s)", flush=True)

    if args.ckpt:
        checkpoint.save(args.ckpt, params, step=args.rounds,
                        extra={"method": args.method, "arch": cfg.name})
        print(f"checkpoint -> {args.ckpt}")
    print(f"final ll/token {np.mean(lls[-max(1, len(lls)//4):]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
