"""End-to-end FSGLD training driver (large-model mode).

Phases (paper Algorithm 1 + Sec 3.1):
  1. local surrogate fitting — short SGLD runs per client shard against the
     local likelihood, fit per-tensor scalar-precision Gaussians (bf16
     storage), combine into the global product q (computed once,
     communicated once);
  2. FSGLD sampling — EVERY chain count (1..C) runs on the mesh-parallel
     chain engine through the ``repro.api`` facade: chains shard over the
     mesh 'data' axis, the scheduler reassigns chains to clients in-scan,
     and the chain takes ``local_updates`` Langevin steps per round with
     the conducive correction. The old single-chain host loop and the
     ppermute federated round are retired — both scales share one
     reassignment/collective path.

On this CPU container run with ``--smoke`` (reduced config, 1x1 mesh); on a
real cluster the same script drives the 16x16 / 2x16x16 production meshes.

KNOWN LIMIT (ROADMAP open item): the chain engine places chains on the
mesh 'data' axis and keeps parameters REPLICATED over 'model' (that axis
carries surrogate-refresh work only), so truly-billion-parameter archs
that need tensor-parallel weights per chain do not fit yet — the
model-axis param sharding lives in the pjit ``make_train_step`` lowering
path (launch/dryrun.py) and still has to be nested under the engine's
data-axis shard_map.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --rounds 10 --method fsgld
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api, checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import token_shards
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params, log_lik_fn
from repro.obs import trace as obs_trace
from repro.obs import write_metrics_jsonl, write_prometheus


def _sample_into_bank(fsgld, key, params, cfg, args, federation):
    """Streaming chain→server sampling: run the schedule in SEGMENTS of
    ``--bank-every`` rounds, carrying the stacked per-chain states across
    segments (``engine.run(stacked=True)``), and append chain 0's
    parameters to the versioned draw bank after every segment — one
    thinned posterior draw per segment, served hot by any
    ``repro.launch.serve --bank`` process watching the directory.

    Each segment is its own engine dispatch with a folded sub-key, so
    the total stream is NOT bit-identical to one monolithic run (the
    reassignment permutation and, for sghmc, the momenta restart per
    segment) — the price of draws becoming visible while sampling runs.
    Returns the final stacked (C, ...) parameter states."""
    seg = max(1, args.bank_every)
    state, stacked = params, False
    done, i = 0, 0
    while done < args.rounds:
        r = min(seg, args.rounds - done)
        finals = fsgld.engine.run(
            jax.random.fold_in(key, i), state, r, n_chains=args.chains,
            reassign="permutation", collect=False, stacked=stacked,
            federation=federation, stream=fsgld.execution.stream)
        # sghmc returns (theta, momentum) chain-state pairs; the bank
        # stores parameters only (a draw is a draw, not a chain state)
        theta = finals[0] if args.kernel == "sghmc" else finals
        done += r
        i += 1
        state, stacked = theta, True
        draw = jax.tree.map(lambda t: t[0], theta)
        meta = checkpoint.DrawMeta(
            method=args.method, round=done,
            scenario=(args.federation or "identity"), seed=args.seed,
            dtype=str(jax.tree.leaves(draw)[0].dtype), arch=cfg.name,
            chain=0)
        path = checkpoint.save_draw(args.draw_bank, draw, meta, step=done)
        print(f"draw {i - 1} (round {done}) -> {path}", flush=True)
    return theta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + 1x1 mesh (CPU container)")
    ap.add_argument("--method", default="fsgld",
                    choices=["sgld", "dsgld", "fsgld", "fald"])
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--chains", type=int, default=1,
                    help="parallel chains on the mesh chain engine "
                         "(core/engine.py); chains shard over the mesh "
                         "'data' axis (any count — odd counts are padded "
                         "over the axis), reassignment is the "
                         "collision-free SPMD permutation")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route chain updates through the fused Pallas "
                         "kernel executors")
    ap.add_argument("--packed", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="with --use-kernel: packed single-launch steps "
                         "(one pallas_call per step for the whole chain "
                         "block; any floating param dtypes — non-fp32 "
                         "leaves quantize back per step). --no-packed "
                         "keeps the per-leaf kernel path")
    ap.add_argument("--kernel", default="sgld",
                    choices=["sgld", "sghmc"],
                    help="transition dynamics: 'sgld' (Langevin) or "
                         "'sghmc' (federated SGHMC — momenta ride the "
                         "chain state; composes with every executor, "
                         "packed included)")
    ap.add_argument("--friction", type=float, default=0.1,
                    help="SGHMC friction alpha_f (with --kernel sghmc)")
    ap.add_argument("--federation", default=None,
                    help="named federation scenario from the "
                         "repro.fed registry (e.g. 'delayed-5x', "
                         "'partial-50%%', 'topk-1%%'): communication "
                         "schedule + payload compression lowered into "
                         "the engine's scan. Partition scenarios are for "
                         "pooled-data drivers; token shards here are "
                         "already per-client, so schedule/compression "
                         "scenarios only")
    ap.add_argument("--local-updates", type=int, default=4)
    ap.add_argument("--num-shards", type=int, default=4)
    ap.add_argument("--clients", type=int, default=None,
                    help="streamed client axis: synthesize this many LAZY "
                         "clients (repro.fed.SyntheticClientSource — each "
                         "client's rows are a pure function of (seed, id), "
                         "generated on demand) instead of materializing "
                         "--num-shards token shards up front. Scales to "
                         "~10^6 clients; pair with --resident to bound "
                         "device memory")
    ap.add_argument("--resident", type=int, default=None,
                    help="streamed client axis: keep only this many "
                         "clients resident on device; the host prefetches "
                         "the next window's shards while the scan segment "
                         "runs. Fault-free streamed runs are bitwise "
                         "identical to the resident path. Must not exceed "
                         "the client count (--clients / --num-shards)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--shard-size", type=int, default=64)
    ap.add_argument("--step-size", type=float, default=1e-5)
    ap.add_argument("--fit-steps", type=int, default=20)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--draw-bank", default=None,
                    help="versioned draw-bank DIRECTORY: sampling runs in "
                         "segments of --bank-every rounds, writing chain "
                         "0's parameters as one DrawMeta-enveloped draw "
                         "per segment — a server pointed at the same "
                         "directory (repro.launch.serve --bank) hot-swaps "
                         "the fresh draws in between requests, while "
                         "sampling is still running")
    ap.add_argument("--bank-every", type=int, default=1,
                    help="rounds per draw-bank segment (thinning: one "
                         "draw every this many rounds)")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="preemption safety: atomically snapshot the "
                         "full scan carry (chains, key, federation "
                         "state, health, trace) every N rounds into "
                         "--snapshot-dir; a killed run resumes with "
                         "--resume, bitwise identical to uninterrupted")
    ap.add_argument("--snapshot-dir", default=None,
                    help="directory for --snapshot-every / --resume "
                         "snapshots")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the newest valid snapshot in "
                         "--snapshot-dir (fresh run when none exists)")
    ap.add_argument("--metrics-dir", default=None,
                    help="observability: run with in-scan telemetry "
                         "(repro.obs.Telemetry — bitwise identical to a "
                         "telemetry-off run) and write metrics.jsonl, "
                         "metrics.prom (Prometheus textfile), and "
                         "trace.jsonl (host spans/events) into this "
                         "directory")
    ap.add_argument("--log-every", type=int, default=None,
                    help="periodic progress: echo one engine.progress "
                         "line (round counter, steps/s, per-metric "
                         "means) every N rounds during the run — "
                         "segmentation is bitwise-lossless")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    obs = args.metrics_dir is not None or args.log_every is not None
    if obs and args.draw_bank:
        raise SystemExit(
            "--metrics-dir/--log-every instrument the facade's one "
            "engine dispatch; --draw-bank runs its own segment loop — "
            "pick one")
    if obs and args.resident is not None:
        raise SystemExit(
            "--metrics-dir/--log-every (in-scan telemetry) do not "
            "compose with --resident (streamed clients) yet — drop one")
    if args.log_every is not None and args.snapshot_every:
        raise SystemExit(
            "--log-every and --snapshot-every both segment the run — "
            "pick ONE segmentation driver (snapshots already log a "
            "span per segment)")
    if (args.snapshot_every or args.resume) and not args.snapshot_dir:
        raise SystemExit("--snapshot-every/--resume need --snapshot-dir")
    if (args.snapshot_every or args.resume) and args.draw_bank:
        raise SystemExit(
            "--snapshot-every/--resume run the schedule as one resumable "
            "engine dispatch; --draw-bank runs its own segment loop — "
            "pick one")
    n_clients = args.clients if args.clients is not None else args.num_shards
    if args.resident is not None and args.resident > n_clients:
        flag = "--clients" if args.clients is not None else "--num-shards"
        raise SystemExit(
            f"--resident {args.resident} exceeds the client count "
            f"({n_clients}): the resident set is the on-device SUBSET of "
            f"clients — lower --resident to at most {n_clients}, or raise "
            f"{flag} (did you mean {flag} {args.resident}?)")
    if args.resident is not None and (args.snapshot_every or args.resume):
        raise SystemExit(
            "--resident (streamed clients) does not compose with "
            "--snapshot-every/--resume: snapshots capture the full scan "
            "carry and the resident window is host-managed — drop "
            "--resident to snapshot")
    if args.clients is not None and args.method == "fsgld":
        raise SystemExit(
            "--clients streams lazy synthetic clients; surrogate fitting "
            "(--method fsgld) needs materialized shard data — pick "
            "--method dsgld or fald, or pass a prefit bank through the "
            "api facade")

    telemetry = api.Telemetry(log_every=args.log_every) if obs else None
    if args.metrics_dir is not None:
        os.makedirs(args.metrics_dir, exist_ok=True)
        obs_trace.configure(
            os.path.join(args.metrics_dir, "trace.jsonl"),
            echo=args.log_every is not None)
    elif args.log_every is not None:
        obs_trace.configure(echo=True)
    try:
        return _train(args, telemetry)
    finally:
        obs_trace.configure()  # don't leak the tracer to callers


def _train(args, telemetry):
    obs = telemetry is not None
    n_clients = args.clients if args.clients is not None else args.num_shards
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh() if args.smoke \
        else make_production_mesh(multi_pod=args.multi_pod)
    key = jax.random.PRNGKey(args.seed)
    k_param, k_data, k_fit, k_run = jax.random.split(key, 4)

    print(f"arch={cfg.name} method={args.method} shards={n_clients} "
          f"mesh={dict(mesh.shape)}"
          + (f" resident={args.resident}" if args.resident else ""))
    params = init_params(cfg, k_param)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    if args.clients is not None:
        # lazy per-client source: only the resident window is ever
        # materialized (the streamed-scale data contract)
        from repro.fed import SyntheticClientSource
        shards = SyntheticClientSource(
            k_data, num_clients=args.clients,
            shard_size=args.shard_size, seq_len=args.seq,
            vocab_size=cfg.vocab_size)
    else:
        shards = token_shards(
            k_data, num_shards=args.num_shards, shard_size=args.shard_size,
            seq_len=args.seq, vocab_size=cfg.vocab_size)

    # ---- the one front door: declarative facade over the chain engine ----
    minibatch = min(args.batch, args.shard_size)
    if not args.use_kernel:
        executor = "vmap"
    elif args.packed is False:
        executor = "per_leaf"
    else:
        executor = "packed"
    federation = None
    if args.federation:
        federation = api.get_scenario(args.federation)
        if federation.partition is not None:
            raise SystemExit(
                f"--federation {args.federation}: partition scenarios "
                "need pooled data; this driver builds per-client token "
                "shards — pick a schedule/compression scenario")
    # block-cyclic visiting supports any chain count in permutation mode
    reassign = "permutation"
    fsgld = api.FSGLD(
        api.Posterior(lambda p, b: log_lik_fn(p, cfg, b),
                      prior_precision=1.0),
        shards, minibatch=minibatch, step_size=args.step_size,
        method=args.method, kernel=args.kernel, friction=args.friction,
        surrogate=(api.SurrogateSpec(
            kind="scalar", fit="local_sgld", fit_steps=args.fit_steps,
            fit_minibatch=minibatch) if args.method == "fsgld"
            else api.SurrogateSpec(kind="none")),
        schedule=api.Schedule(
            rounds=args.rounds, local_steps=args.local_updates,
            n_chains=args.chains, reassign=reassign),
        execution=api.Execution(
            mesh=mesh, executor=executor, collect=False,
            dtype=jnp.dtype(cfg.surrogate_dtype),
            snapshot_every=args.snapshot_every,
            snapshot_path=args.snapshot_dir, resume=args.resume,
            stream=(api.Stream(resident=args.resident)
                    if args.resident is not None else None),
            telemetry=telemetry),
        federation=federation)

    # ---- phase 1: surrogates (once, before sampling) ----
    if args.method == "fsgld":
        t0 = time.time()
        fsgld.fit(k_fit, params)
        print(f"surrogates fitted in {time.time()-t0:.1f}s "
              f"(communicated once; means stored as "
              f"{cfg.surrogate_dtype})")

    # ---- phase 2: FSGLD rounds on the chain engine ----
    t0 = time.time()
    if args.draw_bank:
        finals = _sample_into_bank(fsgld, k_run, params, cfg, args,
                                   federation)
    else:
        finals = fsgld.sample(k_run, params)
        frame = None
        if obs:
            finals, frame = finals
        if args.kernel == "sghmc":
            # collect=False sghmc returns (theta, momentum) chain-state
            # pairs; the ll probe (and the checkpoint) wants parameters
            finals = finals[0]
        if args.metrics_dir is not None:
            mj = os.path.join(args.metrics_dir, "metrics.jsonl")
            mp = os.path.join(args.metrics_dir, "metrics.prom")
            write_metrics_jsonl(frame, mj)
            write_prometheus(frame, mp)
            print(f"metrics -> {mj} + {mp} "
                  f"({frame.rounds} rounds x {frame.n_chains} chains x "
                  f"{len(frame.names)} metrics)")
    dt = time.time() - t0
    probe_rows = (shards.rows(np.arange(1)) if args.clients is not None
                  else shards)
    probe = jax.tree.map(lambda d: d[0][:args.batch], probe_rows)
    lls = jax.vmap(lambda p: log_lik_fn(p, cfg, probe))(finals)
    lls = np.asarray(lls) / probe["tokens"].size
    for c, ll in enumerate(lls):
        print(f"chain {c:3d} ll/token={float(ll):8.4f}")
    steps = args.rounds * args.local_updates * args.chains
    print(f"{args.chains} chain(s) x {args.rounds} rounds "
          f"({steps} chain-steps) in {dt:.1f}s "
          f"= {steps / dt:.1f} steps/s "
          f"[reassign={reassign} executor={executor}"
          f"{' federation=' + args.federation if args.federation else ''}]")
    if args.ckpt:
        checkpoint.save(args.ckpt,
                        jax.tree.map(lambda t: t[0], finals),
                        step=args.rounds,
                        extra={"method": args.method, "arch": cfg.name,
                               "chains": args.chains})
        print(f"checkpoint -> {args.ckpt}")
    print(f"final ll/token {float(np.mean(lls)):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
