"""pjit-able step functions for the production runtime.

LARGE-MODEL mode (DESIGN.md Sec 4): one global chain; the FSGLD update for
the full transformer posterior with per-tensor scalar-precision surrogates.
``train_step`` is what the multi-pod dry-run lowers for every architecture.

Serving lowers ``serve_step`` (one token against a KV cache / recurrent
state) and ``prefill_step``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SamplerConfig
from repro.models import decode_step, forward, init_params, log_lik_fn
from repro.models.model import ACT_DTYPE

PyTree = Any


def make_surrogate_state(params_shape: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """Shape skeleton of the surrogate operands streamed into train_step:
    global + resident-shard means (like params, bf16) and per-tensor scalar
    precisions (DESIGN.md Sec 4.2)."""
    means = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype), params_shape)
    lams = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((), jnp.float32), params_shape)
    return {"mu_g": means, "mu_s": means, "lam_g": lams, "lam_s": lams}


def init_surrogate_state(params: PyTree, *, lam: float = 1e-4,
                         dtype=jnp.bfloat16) -> PyTree:
    """Concrete surrogate state centred on the current params — the warm
    'identity' surrogate used before local fits are communicated."""
    means = jax.tree.map(lambda p: p.astype(dtype), params)
    lams = jax.tree.map(lambda p: jnp.float32(lam), params)
    return {"mu_g": means, "mu_s": means, "lam_g": lams, "lam_s": lams}


def make_train_step(cfg: ArchConfig, sampler: SamplerConfig, *,
                    scale: float, f_s: float):
    """FSGLD train step: one Langevin update of the model-posterior chain.

    scale = N_s / (f_s * m) — the DSGLD unbiasing factor, precomputed by the
    scheduler (N_s = client corpus size, m = global batch).
    """
    alpha = sampler.alpha if sampler.method == "fsgld" else 0.0
    prior = sampler.prior_precision
    temp = sampler.temperature

    def train_step(params, surr, batch, key):
        ll, gll = jax.value_and_grad(
            lambda p: log_lik_fn(p, cfg, batch))(params)

        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(key, len(leaves))
        keytree = jax.tree.unflatten(treedef, list(keys))
        h = sampler.step_size
        sig = jnp.sqrt(h * temp)

        def upd(th, g, mu_g, mu_s, lam_g, lam_s, k):
            g = g.astype(jnp.float32)
            th32 = th.astype(jnp.float32)
            drift = -prior * th32 + scale * g
            if alpha:
                cond = lam_g * (mu_g.astype(jnp.float32) - th32) \
                    - (lam_s / f_s) * (mu_s.astype(jnp.float32) - th32)
                drift = drift + alpha * cond
            xi = jax.random.normal(k, th.shape, jnp.float32)
            return (th32 + (h / 2) * drift + sig * xi).astype(th.dtype)

        new_params = jax.tree.map(
            upd, params, gll, surr["mu_g"], surr["mu_s"], surr["lam_g"],
            surr["lam_s"], keytree)
        metrics = {"log_lik": ll,
                   "ll_per_token": ll / batch["tokens"].size}
        return new_params, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        hidden, _ = forward(params, cfg, batch["tokens"],
                            enc_embeds=batch.get("enc_embeds"))
        logits = jnp.einsum("bd,dv->bv", hidden[:, -1],
                            params["head"].astype(ACT_DTYPE),
                            preferred_element_type=jnp.float32)
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return prefill_step


def make_serve_step(cfg: ArchConfig, *, with_enc: Optional[bool] = None):
    with_enc = (cfg.family in ("vlm", "audio")) if with_enc is None \
        else with_enc

    if with_enc:
        def serve_step(params, cache, token, pos, enc_out):
            logits, cache = decode_step(params, cfg, cache, token, pos,
                                        enc_out=enc_out)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    else:
        def serve_step(params, cache, token, pos):
            logits, cache = decode_step(params, cfg, cache, token, pos)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    return serve_step


# ---------------------------------------------------------------------------
# FEDERATED mode: C = |data axis| parallel chains, T_local in-client steps,
# chain reassignment as one collective-permute over the data axis.
# ---------------------------------------------------------------------------

def make_federated_round(cfg: ArchConfig, sampler: SamplerConfig, mesh, *,
                         scale: float, n_chains: int):
    """One communication round in federated mode (DESIGN.md Sec 4.1).

    chains: params pytree with a leading chain axis (C,) sharded over
    'data' — each data-group hosts ONE chain resident at ONE client.
    surr: per-client surrogate state stacked over the same axis (each
    client stores its own q_s locally; the global q is replicated inside).
    After T_local local FSGLD steps, chains rotate to the next client via
    ``jax.lax.ppermute`` — the paper's 'Reassign_chain' as one ICI hop.
    The ring schedule visits every client equally often, preserving the
    uniform f_s = 1/S marginal of Algorithm 1 (ppermute permutations are
    compile-time static, so the i.i.d.-categorical variant lives only in
    the simulator; see DESIGN.md Sec 4.1).
    """
    from jax.experimental.shard_map import shard_map

    from repro.sharding.rules import chain_spec

    f_s = 1.0 / n_chains
    step = make_train_step(cfg, sampler, scale=scale, f_s=f_s)
    perm = [(i, int((i + 1) % n_chains)) for i in range(n_chains)]

    def local_round(chain, surr, batches, seed):
        # leading sharded axis C becomes a local size-1 block: squeeze it.
        chain = jax.tree.map(lambda x: x[0], chain)
        surr = jax.tree.map(lambda x: x[0], surr)
        batches = jax.tree.map(lambda x: x[0], batches)
        key = jax.random.PRNGKey(seed[0, 0])  # local block: (1, 1) uint32

        def body(carry, batch):
            chain, key = carry
            key, k = jax.random.split(key)
            chain, metrics = step(chain, surr, batch, k)
            return (chain, key), metrics["ll_per_token"]

        (chain, _), lls = jax.lax.scan(body, (chain, key), batches)
        chain = jax.tree.map(lambda x: jax.lax.ppermute(x, "data", perm),
                             chain)
        return (jax.tree.map(lambda x: x[None], chain), lls[None])

    pspec = chain_spec()  # chains ride the 'data' axis (sharding/rules.py)
    return shard_map(
        local_round, mesh=mesh,
        in_specs=(pspec, pspec, pspec, pspec),
        out_specs=(pspec, pspec),
        check_rep=False)
