"""pjit-able step functions for the production runtime.

LARGE-MODEL mode (DESIGN.md Sec 4): the FSGLD update for the full
transformer posterior with per-tensor scalar-precision surrogates.
``train_step`` is what the multi-pod dry-run lowers for every
architecture; the actual sampling loop (single- and multi-chain) runs on
the chain engine via ``repro.api.FSGLD``. (The ppermute federated
round that used to live here — and the ``make_federated_round``
deprecation shim that replaced it — are gone; see the README
migration table.)

Surrogates everywhere are ``repro.core.surrogate.SurrogateBank`` (with a
bf16 storage option); the flat ``{mu_g, mu_s, lam_g, lam_s}`` dict these
step functions consume is just the bank's per-round lowering operand
(``bank_round_state`` / ``init_surrogate_state``).

Serving lowers ``serve_step`` (one token against a KV cache / recurrent
state) and ``prefill_step``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SamplerConfig
from repro.models import decode_step, forward, init_params, log_lik_fn
from repro.models.model import ACT_DTYPE

PyTree = Any


def make_surrogate_state(params_shape: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """Shape skeleton of the surrogate operands streamed into train_step:
    global + resident-shard means (like params, bf16) and per-tensor scalar
    precisions (DESIGN.md Sec 4.2)."""
    means = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, dtype), params_shape)
    lams = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((), jnp.float32), params_shape)
    return {"mu_g": means, "mu_s": means, "lam_g": lams, "lam_s": lams}


def init_surrogate_state(params: PyTree, *, lam: float = 1e-4,
                         dtype=jnp.bfloat16) -> PyTree:
    """Concrete surrogate state centred on the current params — the warm
    'identity' surrogate used before local fits are communicated (the
    round-state a one-shard SurrogateBank at ``params`` would lower to,
    built directly to stay bit-stable)."""
    means = jax.tree.map(lambda p: p.astype(dtype), params)
    lams = jax.tree.map(lambda p: jnp.float32(lam), params)
    return {"mu_g": means, "mu_s": means, "lam_g": lams, "lam_s": lams}


def bank_round_state(bank, s, dtype=jnp.bfloat16) -> PyTree:
    """SurrogateBank -> the flat per-round operand ``make_train_step``
    consumes: global + resident-client ('scalar' kind) means at the
    storage dtype, fp32 scalar precisions. The bridge between the ONE
    surrogate protocol (repro.core.surrogate.SurrogateBank) and the
    lowering-time dict the pjit step functions take."""
    assert bank.kind == "scalar", bank.kind
    q_s = bank.shard(s)
    cast = lambda t: jax.tree.map(  # noqa: E731
        lambda l: l.astype(dtype), t)
    return {"mu_g": cast(bank.global_.mean), "mu_s": cast(q_s.mean),
            "lam_g": jax.tree.map(jnp.float32, bank.global_.prec),
            "lam_s": jax.tree.map(jnp.float32, q_s.prec)}


def make_train_step(cfg: ArchConfig, sampler: SamplerConfig, *,
                    scale: float, f_s: float):
    """FSGLD train step: one Langevin update of the model-posterior chain.

    scale = N_s / (f_s * m) — the DSGLD unbiasing factor, precomputed by the
    scheduler (N_s = client corpus size, m = global batch).
    """
    alpha = sampler.alpha if sampler.method == "fsgld" else 0.0
    prior = sampler.prior_precision
    temp = sampler.temperature

    def train_step(params, surr, batch, key):
        ll, gll = jax.value_and_grad(
            lambda p: log_lik_fn(p, cfg, batch))(params)

        leaves, treedef = jax.tree.flatten(params)
        keys = jax.random.split(key, len(leaves))
        keytree = jax.tree.unflatten(treedef, list(keys))
        h = sampler.step_size
        sig = jnp.sqrt(h * temp)

        def upd(th, g, mu_g, mu_s, lam_g, lam_s, k):
            g = g.astype(jnp.float32)
            th32 = th.astype(jnp.float32)
            drift = -prior * th32 + scale * g
            if alpha:
                cond = lam_g * (mu_g.astype(jnp.float32) - th32) \
                    - (lam_s / f_s) * (mu_s.astype(jnp.float32) - th32)
                drift = drift + alpha * cond
            xi = jax.random.normal(k, th.shape, jnp.float32)
            return (th32 + (h / 2) * drift + sig * xi).astype(th.dtype)

        new_params = jax.tree.map(
            upd, params, gll, surr["mu_g"], surr["mu_s"], surr["lam_g"],
            surr["lam_s"], keytree)
        metrics = {"log_lik": ll,
                   "ll_per_token": ll / batch["tokens"].size}
        return new_params, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        hidden, _ = forward(params, cfg, batch["tokens"],
                            enc_embeds=batch.get("enc_embeds"))
        logits = jnp.einsum("bd,dv->bv", hidden[:, -1],
                            params["head"].astype(ACT_DTYPE),
                            preferred_element_type=jnp.float32)
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return prefill_step


def make_serve_step(cfg: ArchConfig, *, with_enc: Optional[bool] = None):
    with_enc = (cfg.family in ("vlm", "audio")) if with_enc is None \
        else with_enc

    if with_enc:
        def serve_step(params, cache, token, pos, enc_out):
            logits, cache = decode_step(params, cfg, cache, token, pos,
                                        enc_out=enc_out)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    else:
        def serve_step(params, cache, token, pos):
            logits, cache = decode_step(params, cfg, cache, token, pos)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache
    return serve_step
