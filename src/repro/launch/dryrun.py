import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: prove the distribution config is coherent without real
# hardware. For every (architecture x input shape) the step function is
# lowered + compiled against the production mesh; memory_analysis() proves
# the per-device footprint, cost_analysis() feeds the roofline table.
#
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Do not set this flag globally: smoke tests and
# benches must see the single real CPU device.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, SamplerConfig, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (input_specs, long_context_eligible,  # noqa: E402
                                params_shape, train_batch_specs)
from repro.launch.steps import (make_serve_step, make_surrogate_state,  # noqa: E402
                                make_train_step)
from repro.sharding import (batch_specs, cache_specs, param_specs,  # noqa: E402
                            param_shardings)

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


def _shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)


def lower_one(arch: str, shape_name: str, mesh, sampler: SamplerConfig):
    """Lower + compile one (arch, shape, mesh) combination.

    Returns (lowered, compiled) or the string 'skip' for ineligible pairs.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not long_context_eligible(cfg):
        return "skip"

    pshape = params_shape(cfg)
    pspecs = param_specs(pshape, mesh, serve=(shape.kind == "decode"))
    pshard = _shardings(pspecs, mesh)

    if shape.kind == "decode":
        # serving consumes bf16 checkpoints (posterior samples are cast
        # once at export): halves resident weight bytes + gather traffic.
        pshape = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, pshape)
        pshard = _shardings(param_specs(pshape, mesh, serve=True), mesh)
        ins = input_specs(cfg, shape)
        cache_shard = _shardings(cache_specs(ins["cache"], mesh), mesh)
        tok_shard = _shardings(batch_specs(
            {"token": ins["token"], "pos": ins["pos"]}, mesh), mesh)
        serve = make_serve_step(cfg)
        args = [pshape, ins["cache"], ins["token"], ins["pos"]]
        in_sh = [pshard, cache_shard, tok_shard["token"], tok_shard["pos"]]
        if "enc_out" in ins:
            args.append(ins["enc_out"])
            in_sh.append(_shardings(batch_specs(
                {"e": ins["enc_out"]}, mesh), mesh)["e"])
        with mesh:
            lowered = jax.jit(
                serve, in_shardings=tuple(in_sh),
                out_shardings=(tok_shard["pos"], cache_shard),
            ).lower(*args)
    elif shape.kind == "prefill":
        # inference-prefill: forward-only (no grads / surrogates / remat
        # residuals). Lowers make_prefill_step.
        from repro.launch.steps import make_prefill_step
        batch = train_batch_specs(cfg, shape)
        batch.pop("labels")
        bshard = _shardings(batch_specs(batch, mesh), mesh)
        prefill = make_prefill_step(cfg)
        out_shard = _shardings(batch_specs(
            {"t": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)},
            mesh), mesh)["t"]
        with mesh:
            lowered = jax.jit(
                prefill, in_shardings=(pshard, bshard),
                out_shardings=out_shard,
            ).lower(pshape, batch)
    else:
        batch = train_batch_specs(cfg, shape)
        bshard = _shardings(batch_specs(batch, mesh), mesh)
        surr = make_surrogate_state(pshape)
        surr_shard = {"mu_g": pshard, "mu_s": pshard,
                      "lam_g": jax.tree.map(
                          lambda _: NamedSharding(mesh, P()), surr["lam_g"]),
                      "lam_s": jax.tree.map(
                          lambda _: NamedSharding(mesh, P()), surr["lam_s"])}
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        step = make_train_step(cfg, sampler, scale=1_000_000.0,
                               f_s=1.0 / sampler.num_shards)

        def step_key(params, surr, batch, key_data):
            return step(params, surr, batch,
                        jax.random.wrap_key_data(key_data))

        with mesh:
            lowered = jax.jit(
                step_key,
                in_shardings=(pshard, surr_shard, bshard,
                              NamedSharding(mesh, P())),
                out_shardings=(pshard, NamedSharding(mesh, P())),
            ).lower(pshape, surr, batch, key)

    with mesh:
        compiled = lowered.compile()
    return lowered, compiled


def collective_bytes_from_text(txt: str) -> dict:
    """Body-once collective bytes (text occurrence, NOT loop-scaled; the
    loop-scaled numbers come from roofline.hlo_analysis)."""
    totals = {}
    for line in txt.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # output shape(s) of the op: f32[128,1024]{...} possibly tuple
        lhs = line.split("=", 1)[1]
        nbytes = 0
        for t, dims in re.findall(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred)"
                                  r"\[([0-9,]*)\]", lhs.split("(")[0]):
            size = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                    "u32": 4, "s8": 1, "u8": 1, "pred": 1}[t]
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * size
        totals[kind] = totals.get(kind, 0) + nbytes
    return totals


def analyze(lowered, compiled) -> dict:
    from repro.roofline.hlo_analysis import analyze_text
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per program
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_bytes_from_text(txt)
    static = analyze_text(txt)
    # NOTE: memory_analysis numbers are PER DEVICE. On XLA:CPU,
    # temp_size_in_bytes is cumulative transient allocation, while
    # peak_memory_in_bytes is the true high-water mark (the quantity that
    # must fit in the 16 GiB of a v5e chip).
    return {
        # raw XLA numbers (scan bodies counted once — see hlo_analysis doc)
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        # loop-scaled static analysis (the roofline inputs)
        "static_flops": static["flops"],
        "static_hbm_bytes": static["hbm_bytes"],
        "static_collective_bytes": static["collective_bytes"],
        "static_collective_total": static["collective_total"],
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    sampler = SamplerConfig(method="fsgld", num_shards=16)
    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    results = {}
    fail = 0
    for arch in archs:
        for shp in shapes:
            tag = f"{arch}|{shp}|{'pod2' if args.multi_pod else 'pod1'}"
            t0 = time.time()
            try:
                out = lower_one(arch, shp, mesh, sampler)
                if out == "skip":
                    print(f"SKIP  {tag} (full attention at 524k)",
                          flush=True)
                    results[tag] = {"status": "skip"}
                    continue
                lowered, compiled = out
                info = analyze(lowered, compiled)
                info["status"] = "ok"
                info["compile_s"] = round(time.time() - t0, 1)
                results[tag] = info
                print(f"OK    {tag} compile={info['compile_s']}s "
                      f"flops={info['static_flops']:.3e} "
                      f"hbm={info['static_hbm_bytes']:.3e} "
                      f"coll={info['static_collective_total']:.3e} "
                      f"args/dev={info['argument_size_bytes']/2**30:.2f}GiB "
                      f"peak/dev={info['peak_bytes']/2**30:.2f}GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                fail += 1
                results[tag] = {"status": "fail", "error": str(e)[:500]}
                print(f"FAIL  {tag}: {type(e).__name__}: {str(e)[:300]}",
                      flush=True)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1)
    print(f"done: {sum(1 for r in results.values() if r['status']=='ok')} ok,"
          f" {sum(1 for r in results.values() if r['status']=='skip')} skip,"
          f" {fail} fail")
    return 1 if fail else 0


if __name__ == "__main__":
    sys.exit(main())
