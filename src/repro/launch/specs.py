"""ShapeDtypeStruct stand-ins for every model input — the dry-run never
allocates. Modality frontends ([vlm]/[audio]) enter here as precomputed
patch/frame embeddings (the assignment's one sanctioned stub)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import init_cache, init_params
from repro.models.model import ACT_DTYPE


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_shape(cfg: ArchConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


def train_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), jnp.int32),
             "labels": sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["enc_embeds"] = sds((B, cfg.num_patches, cfg.d_model),
                                  ACT_DTYPE)
    if cfg.family == "audio":
        batch["enc_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                  ACT_DTYPE)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    return train_batch_specs(cfg, shape)


def decode_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """serve_step operands: cache of seq_len, one new token, positions."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    out = {"cache": cache,
           "token": sds((B, 1), jnp.int32),
           "pos": sds((B,), jnp.int32)}
    if cfg.family == "vlm":
        out["enc_out"] = sds((B, cfg.num_patches, cfg.d_model), ACT_DTYPE)
    if cfg.family == "audio":
        out["enc_out"] = sds((B, cfg.encoder_seq, cfg.d_model), ACT_DTYPE)
    return out


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return train_batch_specs(cfg, shape)


def long_context_eligible(cfg: ArchConfig) -> bool:
    """long_500k runs only for sub-quadratic architectures: SSM / hybrid /
    sliding-window. Pure full-attention archs are skipped (DESIGN.md Sec 5)."""
    return all(k in ("swa", "rglru", "rwkv") for k in cfg.layer_pattern)
