"""Roofline report generator.

Reads the dry-run JSON (results/dryrun_pod1.json — per-DEVICE, loop-scaled
static analysis) and emits the §Roofline table: the three terms in seconds,
the dominant bottleneck, MODEL_FLOPS = 6*N_active*D, and the useful-compute
ratio, per (arch x shape).

TPU v5e hardware constants (per chip):
    197 TFLOP/s bf16   |   819 GB/s HBM   |   ~50 GB/s/link ICI
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_NAMES, SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256  # single-pod 16x16


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D for training (fwd 2ND + bwd 4ND); 2*N*D for inference-forward;
    2*N_active per generated token for decode. MoE uses active params.
    Global across the mesh."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: ONE token per sequence
    return 2.0 * n * shape.global_batch


def row_terms(info: dict) -> dict:
    """Per-device seconds for each roofline term."""
    t_c = info["static_flops"] / PEAK_FLOPS
    t_m = info["static_hbm_bytes"] / HBM_BW
    t_i = info["static_collective_total"] / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_i, "collective"))[1]
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_i,
            "dominant": dom}


def build_table(results: dict, mesh_tag: str = "pod1") -> list:
    rows = []
    for arch in ARCH_NAMES:
        for shp in SHAPES:
            tag = f"{arch}|{shp}|{mesh_tag}"
            info = results.get(tag)
            if info is None:
                continue
            if info["status"] == "skip":
                rows.append({"arch": arch, "shape": shp, "status": "skip"})
                continue
            if info["status"] != "ok":
                rows.append({"arch": arch, "shape": shp, "status": "fail"})
                continue
            terms = row_terms(info)
            mf = model_flops(arch, shp)
            hlo_global = info["static_flops"] * CHIPS
            rows.append({
                "arch": arch, "shape": shp, "status": "ok", **terms,
                "model_flops": mf,
                "hlo_flops_global": hlo_global,
                "useful_ratio": mf / hlo_global if hlo_global else 0.0,
                "peak_gib": info["peak_bytes"] / 2 ** 30,
                "step_time_bound_ms": 1e3 * max(
                    terms["t_compute"], terms["t_memory"],
                    terms["t_collective"]),
            })
    return rows


def render(rows: list) -> str:
    hdr = ("| arch | shape | t_comp(ms) | t_mem(ms) | t_coll(ms) | "
           "bottleneck | MODEL_FLOPs | useful | peak GiB |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"{r['status']} | - | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {1e3*r['t_compute']:.2f} | "
            f"{1e3*r['t_memory']:.2f} | {1e3*r['t_collective']:.2f} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['peak_gib']:.2f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun_pod1.json")
    ap.add_argument("--mesh-tag", default="pod1")
    args = ap.parse_args(argv)
    with open(args.json) as f:
        results = json.load(f)
    rows = build_table(results, args.mesh_tag)
    print(render(rows))
    # summary: most interesting hillclimb candidates
    ok = [r for r in rows if r["status"] == "ok"]
    worst = min(ok, key=lambda r: r["useful_ratio"])
    coll = max(ok, key=lambda r: r["t_collective"]
               / max(r["t_compute"], 1e-12))
    print(f"\nworst useful-ratio: {worst['arch']}|{worst['shape']} "
          f"({worst['useful_ratio']:.2f})")
    print(f"most collective-bound: {coll['arch']}|{coll['shape']} "
          f"(t_coll/t_comp={coll['t_collective']/max(coll['t_compute'],1e-12):.2f})")


if __name__ == "__main__":
    main()
