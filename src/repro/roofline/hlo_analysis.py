"""Static roofline analyzer over optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop (lax.scan) body ONCE —
useless for scan-over-layers models where >95% of the work sits inside
loops. This module re-derives the three roofline inputs by parsing
``compiled.as_text()`` and scaling each computation by its execution
multiplicity:

  * FLOPs            — from dot ops: 2 * |output| * |contracting dims|
  * HBM bytes        — per top-level op: operand + result bytes (fusion
                       internals never touch HBM; parameter/constant/tuple
                       plumbing skipped). Operand shapes are resolved
                       through a per-computation name -> result-shape map
                       (optimized HLO prints operands without types).
  * collective bytes — all-gather / all-reduce / reduce-scatter /
                       all-to-all / collective-permute result bytes

Multiplicity: ENTRY = 1; a while body/cond inherits parent_mult * trip_count
(trip recovered from the largest constant feeding the loop-condition
compare — JAX scans lower to ``i < length``); fusion / call / conditional
bodies inherit the caller's multiplicity.

Known approximations (documented in EXPERIMENTS.md §Roofline):
  - elementwise FLOPs ignored (dots dominate transformer steps);
  - copy ops count as traffic even when XLA elides them;
  - conditional branches all counted (upper bound).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32"
                       r"|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_CALLED = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)"
                     r"=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose operands/results do NOT represent HBM traffic
_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "iota", "while", "conditional",
                 "call", "partition-id", "replica-id", "domain",
                 "opt-barrier"}


def _shape_bytes(text: str) -> int:
    total = 0
    for t, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[t]
    return total


def _result_type(rhs: str) -> str:
    depth = 0
    for i, ch in enumerate(rhs):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == " " and depth == 0:
            return rhs[:i]
    return rhs


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    rhs: str
    result: str
    args: str  # text inside the opcode's parentheses (operand list)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    shapes: Dict[str, int]  # op name -> result bytes


def _split_args(after: str) -> str:
    """Extract the operand list: text inside the first balanced parens."""
    i = after.find("(")
    if i < 0:
        return ""
    depth = 0
    for j in range(i, len(after)):
        if after[j] == "(":
            depth += 1
        elif after[j] == ")":
            depth -= 1
            if depth == 0:
                return after[i + 1:j]
    return after[i + 1:]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        s = line.strip()
        if not s or line.startswith(("HloModule", "FileNames",
                                     "FunctionNames", "FileLocations",
                                     "StackFrames")):
            continue
        hdr = _COMP_HDR.match(line)
        if hdr:
            cur = Computation(hdr.group(2), [], {})
            comps[cur.name] = cur
            if hdr.group(1):
                entry_name = cur.name
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        result = _result_type(rhs)
        after = rhs[len(result):].strip()
        opcode = after.split("(")[0].strip()
        cur.ops.append(Op(name, opcode, rhs, result, _split_args(after)))
        cur.shapes[name] = _shape_bytes(result)
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Largest integer constant reachable in the cond computation (+ its
    callees). JAX scans: `i < length` with length the only big constant."""
    best = 1
    direction = "LT"
    stack, seen = [cond.name], set()
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        for op in comps[cname].ops:
            m = re.search(r"constant\((-?\d+)\)", op.rhs)
            if m:
                best = max(best, int(m.group(1)))
            d = re.search(r"direction=(\w+)", op.rhs)
            if d and op.opcode == "compare":
                direction = d.group(1)
            cm = _CALLED.search(op.rhs)
            if cm:
                stack.extend(re.split(r",\s*%?", cm.group(1)))
    if direction in ("LE", "GE"):
        best += 1
    return max(best, 1)


def _multiplicities(comps: Dict[str, Computation]) -> Dict[str, float]:
    mult: Dict[str, float] = {}
    entry = comps["__entry__"]
    mult[entry.name] = 1.0
    for _ in range(64):
        changed = False
        for cname, comp in comps.items():
            if cname == "__entry__" or mult.get(cname, 0.0) == 0.0:
                continue
            m = mult[cname]
            for op in comp.ops:
                if op.opcode == "while":
                    mm = re.search(r"condition=%?([\w\.\-]+)", op.rhs)
                    bb = re.search(r"body=%?([\w\.\-]+)", op.rhs)
                    if not (mm and bb) or mm.group(1) not in comps:
                        continue
                    trip = _trip_count(comps[mm.group(1)], comps)
                    for target, factor in ((bb.group(1), trip),
                                           (mm.group(1), trip + 1)):
                        new = m * factor
                        if mult.get(target, 0.0) < new:
                            mult[target] = new
                            changed = True
                else:
                    cm = _CALLED.search(op.rhs)
                    if cm:
                        for target in re.split(r",\s*%?", cm.group(1)):
                            if target in comps and mult.get(target, 0.) < m:
                                mult[target] = m
                                changed = True
        if not changed:
            break
    return mult


def _dot_flops(op: Op, comp: Computation,
               comps: Dict[str, Computation]) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(op.result)
    if m and m.group(2):
        for d in m.group(2).split(","):
            out_elems *= int(d)
    cdims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
    lhs_name_m = _OPERAND.search(op.args)
    if not (cdims_m and lhs_name_m):
        return 2.0 * out_elems
    # resolve lhs operand shape (dims, not bytes)
    lhs_dims: List[int] = []
    lhs = lhs_name_m.group(1)
    for search in (comp, *comps.values()):
        for o in search.ops:
            if o.name == lhs:
                sm = _SHAPE_RE.search(o.result)
                if sm and sm.group(2):
                    lhs_dims = [int(d) for d in sm.group(2).split(",")]
                break
        if lhs_dims:
            break
    contract = 1
    for ci in cdims_m.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            contract *= lhs_dims[int(ci)]
    return 2.0 * out_elems * contract


def _param_effective_bytes(comp: Computation) -> Dict[int, int]:
    """For a fused computation, the HBM bytes actually read per parameter.

    A parameter consumed ONLY by dynamic-slice / gather ops reads just the
    sliced rows, not the whole buffer (scan residual stacks, embedding
    tables). Returns {param_index: effective_bytes}; absent = full size.
    """
    param_names = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.rhs)
            if m:
                param_names[op.name] = int(m.group(1))
    eff: Dict[int, int] = {}
    for pname, pidx in param_names.items():
        consumers = [op for op in comp.ops
                     if pname in _OPERAND.findall(op.args)]
        if not consumers:
            continue
        sliced = 0
        ok = True
        for op in consumers:
            if op.opcode in ("dynamic-slice", "gather"):
                first = _OPERAND.search(op.args)
                if first and first.group(1) == pname:
                    sliced += comp.shapes.get(op.name, 0)
                    continue
            ok = False
            break
        if ok and sliced:
            eff[pidx] = sliced
    return eff


def _effective_traffic(op: Op, comp: Computation,
                       comps: Dict[str, Computation]) -> int:
    """Operand+result HBM bytes with slice-awareness:
      * gather / dynamic-slice read only the slice (result size);
      * dynamic-update-slice writes only the update;
      * fusions whose params are consumed solely by dynamic-slice/gather
        read only the slices; fusion roots that are dynamic-update-slice
        write only the update."""
    operands = _OPERAND.findall(op.args)
    result_b = comp.shapes.get(op.name, 0)
    if op.opcode in ("gather", "dynamic-slice"):
        return 2 * result_b  # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = comp.shapes.get(operands[1], 0) if len(operands) > 1 else 0
        return 2 * upd
    if op.opcode.startswith("fusion"):
        cm = _CALLED.search(op.rhs)
        fused = None
        if cm:
            first = re.split(r",\s*", cm.group(1))[0].strip().lstrip("%")
            fused = comps.get(first)
        if fused is not None:
            eff = _param_effective_bytes(fused)
            total = 0
            for i, name in enumerate(operands):
                total += eff.get(i, comp.shapes.get(name, 0))
            dus = [o for o in fused.ops
                   if o.opcode == "dynamic-update-slice"]
            if dus:
                # in-place residual-stack append: writes only the update
                total += sum(
                    fused.shapes.get(_OPERAND.findall(o.args)[1], 0)
                    for o in dus if len(_OPERAND.findall(o.args)) > 1)
            else:
                total += result_b
            return total
    return result_b + sum(comp.shapes.get(o, 0) for o in operands)


def analyze_text(text: str) -> dict:
    comps = parse_hlo(text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    mult = _multiplicities(comps)

    fusion_names = set()
    for comp in comps.values():
        for op in comp.ops:
            cm = _CALLED.search(op.rhs)
            if cm and ("fusion" in op.opcode or op.opcode == "reduce"
                       or op.opcode == "scatter" or op.opcode == "map"
                       or op.opcode == "sort" or op.opcode == "select-and-scatter"
                       or "reduce" in op.opcode):
                fusion_names.update(re.split(r",\s*%?", cm.group(1)))

    flops = 0.0
    traffic = 0.0
    coll: Dict[str, float] = {}
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op, comp, comps)
            for kind in _COLLECTIVES:
                if op.opcode.startswith(kind):
                    coll[kind] = coll.get(kind, 0.0) \
                        + m * _shape_bytes(op.result)
            if cname in fusion_names:
                continue  # fusion internals don't touch HBM
            if op.opcode in _SKIP_TRAFFIC or \
                    any(op.opcode.startswith(s) for s in
                        ("get-tuple-element", "custom-call")):
                continue
            traffic += m * _effective_traffic(op, comp, comps)
    return {"flops": flops, "hbm_bytes": traffic,
            "collective_bytes": coll,
            "collective_total": sum(coll.values())}


def analyze_compiled(compiled) -> dict:
    return analyze_text(compiled.as_text())


def breakdown_text(text: str, top: int = 25) -> dict:
    """Top contributors by HBM traffic / flops / collective bytes —
    the §Perf napkin-math input."""
    comps = parse_hlo(text)
    mult = _multiplicities(comps)
    fusion_names = set()
    for comp in comps.values():
        for op in comp.ops:
            cm = _CALLED.search(op.rhs)
            if cm and ("fusion" in op.opcode or "reduce" in op.opcode):
                fusion_names.update(re.split(r",\s*%?", cm.group(1)))
    traffic_rows, flop_rows, coll_rows = [], [], []
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.opcode == "dot":
                flop_rows.append((m * _dot_flops(op, comp, comps), m,
                                  cname, op.name, op.result))
            for kind in _COLLECTIVES:
                if op.opcode.startswith(kind):
                    coll_rows.append((m * _shape_bytes(op.result), m,
                                      cname, op.name, op.result))
            if cname in fusion_names or op.opcode in _SKIP_TRAFFIC or \
                    any(op.opcode.startswith(s) for s in
                        ("get-tuple-element", "custom-call")):
                continue
            b = m * _effective_traffic(op, comp, comps)
            traffic_rows.append((b, m, cname, op.name,
                                 f"{op.opcode} {op.result[:40]}"))
    traffic_rows.sort(reverse=True)
    flop_rows.sort(reverse=True)
    coll_rows.sort(reverse=True)
    return {"traffic": traffic_rows[:top], "flops": flop_rows[:top],
            "collectives": coll_rows[:top]}
