"""Before/after roofline comparison: paper-faithful baseline JSON vs
optimized JSON -> markdown delta table for EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.roofline.compare \
        results/dryrun_pod1.json results/dryrun_pod1_opt.json
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_NAMES, SHAPES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("optimized")
    ap.add_argument("--mesh-tag", default="pod1")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.optimized) as f:
        opt = json.load(f)

    print("| arch | shape | flops o/b | hbm o/b | coll o/b | peak o/b |")
    print("|---|---|---|---|---|---|")
    tot = {"static_flops": [0.0, 0.0], "static_hbm_bytes": [0.0, 0.0],
           "static_collective_total": [0.0, 0.0]}
    for arch in ARCH_NAMES:
        for shp in SHAPES:
            tag = f"{arch}|{shp}|{args.mesh_tag}"
            b, o = base.get(tag), opt.get(tag)
            if not (b and o and b.get("status") == "ok"
                    and o.get("status") == "ok"):
                continue

            def ratio(k):
                denom = b[k] if b[k] else 1.0
                return o[k] / denom
            for k in tot:
                tot[k][0] += b[k]
                tot[k][1] += o[k]
            print(f"| {arch} | {shp} | {ratio('static_flops'):.2f} | "
                  f"{ratio('static_hbm_bytes'):.2f} | "
                  f"{ratio('static_collective_total'):.2f} | "
                  f"{o['peak_bytes']/max(b['peak_bytes'],1):.2f} |")
    print()
    for k, (bsum, osum) in tot.items():
        print(f"grid total {k}: {bsum:.3e} -> {osum:.3e} "
              f"({bsum/max(osum,1e-9):.2f}x better)" if osum < bsum else
              f"grid total {k}: {bsum:.3e} -> {osum:.3e} "
              f"({osum/max(bsum,1e-9):.2f}x worse)")


if __name__ == "__main__":
    main()
