"""Quickstart: conducive gradients in ~50 lines.

Reproduces the paper's core phenomenon on the Sec 5.1 model: with delayed
communication (100 local updates) DSGLD drifts toward a mixture of local
posteriors; FSGLD stays on the true posterior.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import SamplerConfig
from repro.core import (FederatedSampler,
                        analytic_gaussian_likelihood_surrogate, make_bank,
                        summarize)

key = jax.random.PRNGKey(0)
S, N_s, D = 10, 200, 2

# federated non-IID data: each client's data centred at its own mu_s
client_means = jax.random.uniform(key, (S, D), minval=-6, maxval=6)
data = client_means[:, None, :] + jax.random.normal(
    jax.random.fold_in(key, 1), (S, N_s, D))

# model: p(mu | x) ∝ N(mu|0, I) * prod_i N(x_i | mu, I)
def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

N = S * N_s
true_posterior_mean = data.reshape(-1, D).sum(0) / (1 + N)

# each client fits its likelihood surrogate ONCE and communicates it once
mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(data)
bank = make_bank(mu_s, prec_s, "diag")

for method in ("dsgld", "fsgld"):
    cfg = SamplerConfig(method=method, step_size=1e-4, num_shards=S,
                        local_updates=100, prior_precision=1.0)
    sampler = FederatedSampler(log_lik, cfg, {"x": data}, minibatch=10,
                               bank=bank)
    chains = sampler.run(jax.random.PRNGKey(2), jnp.zeros(D),
                         num_rounds=300, n_chains=4, collect_every=10)
    chains = chains[:, chains.shape[1] // 2:]
    est = chains.mean(axis=(0, 1))
    mse = float(jnp.sum((est - true_posterior_mean) ** 2))
    diag = summarize(chains)
    print(f"{method:5s} (100 local updates): posterior-mean MSE = {mse:.5f}"
          f"  max R-hat = {diag['max_rhat']:.3f}"
          f"  min ESS = {diag['min_ess']:.0f}")
print("FSGLD should be ~100x closer with R-hat ~1 — conducive gradients "
      "at work.")
