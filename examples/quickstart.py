"""Quickstart: conducive gradients in ~50 lines, through the one front
door (``repro.api``).

Reproduces the paper's core phenomenon on the Sec 5.1 model: with delayed
communication (100 local updates) DSGLD drifts toward a mixture of local
posteriors; FSGLD stays on the true posterior. The same four declarative
pieces (Posterior / SurrogateSpec / Schedule / Execution) drive every
scale in this repo — swap the toy log-lik for a transformer's and the
sampler code does not change.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import api
from repro.core import (analytic_gaussian_likelihood_surrogate, make_bank,
                        summarize)

key = jax.random.PRNGKey(0)
S, N_s, D = 10, 200, 2

# federated non-IID data: each client's data centred at its own mu_s
client_means = jax.random.uniform(key, (S, D), minval=-6, maxval=6)
data = client_means[:, None, :] + jax.random.normal(
    jax.random.fold_in(key, 1), (S, N_s, D))

# model: p(mu | x) ∝ N(mu|0, I) * prod_i N(x_i | mu, I)
def log_lik(theta, batch):
    return -0.5 * jnp.sum((batch["x"] - theta) ** 2)

N = S * N_s
true_posterior_mean = data.reshape(-1, D).sum(0) / (1 + N)

# each client fits its likelihood surrogate ONCE and communicates it once
mu_s, prec_s = jax.vmap(analytic_gaussian_likelihood_surrogate)(data)
bank = make_bank(mu_s, prec_s, "diag")

for method in ("dsgld", "fsgld"):
    sampler = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0),
        {"x": data}, minibatch=10, step_size=1e-4, method=method,
        surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                   if method == "fsgld"
                   else api.SurrogateSpec(kind="none")),
        schedule=api.Schedule(rounds=300, local_steps=100, n_chains=4,
                              thin=10))
    chains = sampler.sample(jax.random.PRNGKey(2), jnp.zeros(D))
    chains = chains[:, chains.shape[1] // 2:]
    est = chains.mean(axis=(0, 1))
    mse = float(jnp.sum((est - true_posterior_mean) ** 2))
    diag = summarize(chains)
    print(f"{method:5s} (100 local updates): posterior-mean MSE = {mse:.5f}"
          f"  max R-hat = {diag['max_rhat']:.3f}"
          f"  min ESS = {diag['min_ess']:.0f}")
print("FSGLD should be ~100x closer with R-hat ~1 — conducive gradients "
      "at work.")
