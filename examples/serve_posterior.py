"""Serve a posterior sample: batched greedy decode against the KV cache /
recurrent state (the paper's models are samplers; serving = running one
draw from the weight posterior).

    PYTHONPATH=src python examples/serve_posterior.py --arch rwkv6-7b
"""
import argparse

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()
    return serve_mod.main(["--arch", args.arch, "--smoke",
                           "--batch", str(args.batch),
                           "--prompt-len", "8", "--gen", str(args.gen)])


if __name__ == "__main__":
    raise SystemExit(main())
