"""FSGLD posterior sampling of a transformer language model — the
large-model-mode end-to-end driver. Defaults to a ~25M-param qwen3-family
config that trains a few hundred steps on this CPU container; pass
--preset 100m on real hardware (same code path as the production mesh).

    PYTHONPATH=src python examples/train_lm.py --rounds 20 --local-updates 5
"""
import argparse
import dataclasses
import sys

from repro.configs import get_smoke_config
from repro.launch import train as train_mod


PRESETS = {
    # (layers, d_model, heads, kv, d_ff, vocab) — param counts incl. embeds
    "tiny": (2, 256, 4, 2, 512, 512),          # ~1.4M  (CI)
    "25m": (6, 384, 6, 2, 1536, 8192),         # ~25M
    "100m": (12, 768, 12, 4, 2048, 32768),     # ~110M (few hundred steps
                                               #  on real hardware)
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-updates", type=int, default=4)
    ap.add_argument("--method", default="fsgld")
    args = ap.parse_args()

    L, d, h, kv, f, v = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_smoke_config("qwen3-1.7b"), num_layers=L, d_model=d,
        num_heads=h, num_kv_heads=kv, head_dim=64, d_ff=f, vocab_size=v)

    # monkey-patch the driver's config resolution to inject the preset
    orig = train_mod.get_smoke_config
    train_mod.get_smoke_config = lambda _name: cfg
    try:
        rc = train_mod.main([
            "--arch", "qwen3-1.7b", "--smoke", "--method", args.method,
            "--rounds", str(args.rounds),
            "--local-updates", str(args.local_updates),
            "--seq", "128", "--batch", "8", "--shard-size", "64",
            "--fit-steps", "16",
        ])
    finally:
        train_mod.get_smoke_config = orig
    sys.exit(rc)


if __name__ == "__main__":
    main()
