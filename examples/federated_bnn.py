"""End-to-end driver of the paper's kind (Sec 5.3): Bayesian MLP posterior
sampling over federated label-imbalanced shards.

Pipeline: synthesize non-IID clients -> per-client SGLD surrogate fits
(communicated once) -> FSGLD/DSGLD rounds with 40 local updates -> held-out
average log-likelihood from the posterior-sample ensemble.

    PYTHONPATH=src python examples/federated_bnn.py --rounds 150
"""
import argparse

import jax
import jax.numpy as jnp

from benchmarks.table1_bnn import P, avg_loglik, log_lik
from repro import api
from repro.core import fit_bank_fisher, sample_local_likelihood
from repro.data import susy_shards, susy_test_set


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--shards", type=int, default=10)
    ap.add_argument("--shard-size", type=int, default=20_000)
    ap.add_argument("--beta-a", type=float, default=0.5,
                    help="0.5 = non-IID (paper), 100 = IID")
    ap.add_argument("--scenarios",
                    default="identity,delayed-5x,partial-50%,topk-1%",
                    help="comma-separated repro.fed registry names: the "
                         "FSGLD run is repeated under each federation "
                         "scenario (schedule/compression lowered into "
                         "the engine scan)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    shards, pi = susy_shards(key, num_shards=args.shards,
                             shard_size=args.shard_size, beta_a=args.beta_a)
    test = susy_test_set(jax.random.fold_in(key, 7), size=4000)
    print(f"client positive-label proportions: "
          f"{[round(float(p), 2) for p in pi]}")

    theta0 = 0.1 * jax.random.normal(key, (P,))
    print("phase 1: per-client surrogate fitting (communicated once)...")
    samples = sample_local_likelihood(
        log_lik, shards, theta0, jax.random.fold_in(key, 2), minibatch=50,
        step_size=1e-5, num_steps=400, burn_in=200, thin=2,
        prior_precision=1.0)
    means = jax.tree.leaves(samples)[0].reshape(args.shards, -1, P).mean(1)
    bank = fit_bank_fisher(log_lik, shards, means)

    print("phase 2: sampling...")
    for method in ("dsgld", "fsgld"):
        samp = api.FSGLD(
            api.Posterior(log_lik, prior_precision=1.0), shards,
            minibatch=50, step_size=1e-5, method=method,
            surrogate=(api.SurrogateSpec(kind="diag", bank=bank)
                       if method == "fsgld"
                       else api.SurrogateSpec(kind="none")),
            schedule=api.Schedule(rounds=args.rounds, local_steps=40,
                                  thin=20))
        tr = samp.sample(jax.random.PRNGKey(20), theta0)[0]
        ll = avg_loglik(tr[tr.shape[0] // 2:], test)
        print(f"  {method:5s}: held-out avg log-lik = {ll:.4f}")

    print("phase 3: FSGLD under named federation scenarios...")
    samp = api.FSGLD(
        api.Posterior(log_lik, prior_precision=1.0), shards,
        minibatch=50, step_size=1e-5, method="fsgld",
        surrogate=api.SurrogateSpec(kind="diag", bank=bank),
        schedule=api.Schedule(rounds=args.rounds, local_steps=40,
                              thin=20))
    for name in args.scenarios.split(","):
        tr = samp.sample(jax.random.PRNGKey(20), theta0,
                         federation=name)[0]
        ll = avg_loglik(tr[tr.shape[0] // 2:], test)
        print(f"  fsgld @ {name:12s}: held-out avg log-lik = {ll:.4f}")


if __name__ == "__main__":
    main()
